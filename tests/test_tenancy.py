"""TAPMS-style tenancy + RBAC-lite federation."""

import pytest

from repro.core import Cluster, ClusterSpec, IAM, Role, TenantManager


def setup():
    cluster = Cluster(ClusterSpec("t", nodes_per_pod=8, num_pods=2))
    iam = IAM(token_ttl=100.0, clock=lambda: 0.0)
    mgr = TenantManager(cluster, iam)
    admin_tok = iam.federated_login("admin@bristol.ac.uk", "uob-idp")
    iam.grant("admin@bristol.ac.uk", Role.INFRA_ADMIN)
    return cluster, iam, mgr, admin_tok


def test_tenant_lifecycle_and_rcn():
    cluster, iam, mgr, tok = setup()
    t = mgr.create_tenant("ai-safety", quota_nodes=4, admin="alice@inst.ac.uk", token=tok)
    mgr.grow_tenant("ai-safety", 3, token=tok)
    assert len(t.nodes) == 3
    assert t.rcn == t.nodes[0]  # first node repurposed as login frontend
    assert t.chips == 12


def test_quota_enforced():
    cluster, iam, mgr, tok = setup()
    mgr.create_tenant("small", quota_nodes=2, admin="bob@x", token=tok)
    with pytest.raises(PermissionError):
        mgr.grow_tenant("small", 3, token=tok)


def test_rbac_denies_non_admin():
    cluster, iam, mgr, tok = setup()
    user_tok = iam.federated_login("mallory@other", "idp")
    with pytest.raises(PermissionError):
        mgr.create_tenant("evil", quota_nodes=1, admin="mallory@other", token=user_tok)


def test_token_expiry():
    now = [0.0]
    iam = IAM(token_ttl=10.0, clock=lambda: now[0])
    tok = iam.federated_login("a@b", "idp")
    iam.resolve(tok)
    now[0] = 11.0
    with pytest.raises(PermissionError):
        iam.resolve(tok)


def test_isolation_invariant():
    cluster, iam, mgr, tok = setup()
    mgr.create_tenant("t1", quota_nodes=4, admin="a@x", token=tok)
    mgr.create_tenant("t2", quota_nodes=4, admin="b@y", token=tok)
    mgr.grow_tenant("t1", 2, token=tok)
    mgr.grow_tenant("t2", 2, token=tok)
    assert mgr.check_isolation() == []
    t1_nodes = set(mgr.tenants["t1"].nodes)
    t2_nodes = set(mgr.tenants["t2"].nodes)
    assert not (t1_nodes & t2_nodes)


def test_tenant_submesh_shape():
    cluster, iam, mgr, tok = setup()
    mgr.create_tenant("t1", quota_nodes=4, admin="a@x", token=tok)
    mgr.grow_tenant("t1", 4, token=tok)
    assert mgr.tenant_submesh_shape("t1", model_parallel=4) == (4, 4)
    with pytest.raises(ValueError):
        mgr.tenant_submesh_shape("t1", model_parallel=5)
