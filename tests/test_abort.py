"""Abort/cancel paths: resource release, deadline enforcement, async cancel.

``engine.abort`` must be callable at every point of a request's life —
queued, mid-chunked-prefill, decoding — and afterwards the engine must hold
*zero* residue: the slot clears, tail blocks free, committed blocks route
through the prefix index (parked in the evictable cached pool, so
``num_free`` still equals ``capacity``), and surviving requests produce
exactly the tokens they would have without the abort.

Deadline enforcement rides the same path: ``deadline_s`` is a TTFT SLO, so
a request whose deadline passes with no first token aborts with
``finish_reason="deadline_exceeded"`` (it is worthless to its interactive
caller), while one that got its first token in time always runs to
completion — an overrun then only counts into ``deadline_violations``.
"""

import asyncio

import jax
import jax.numpy as jnp
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import AsyncEngine, InferenceEngine, ManualClock, RequestState


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def make_engine(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("cache_kind", "paged")
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_budget", 8)
    return InferenceEngine(cfg, params, **kw)


def assert_no_residue(eng):
    """After a drain every resource must be back: blocks (cached blocks are
    evictable, so they count as free), slots and queue."""
    assert eng.allocator.num_free == eng.allocator.capacity
    assert all(s is None for s in eng.slots)
    assert not eng.queue


# ---- abort at each lifecycle stage, under both policies -------------------


@pytest.mark.parametrize("policy", ["slo", "fcfs"])
def test_abort_queued_request(setup, policy):
    cfg, params = setup
    eng = make_engine(cfg, params, max_batch=1, policy=policy)
    runner = eng.submit([5, 9, 12, 7], max_new_tokens=4)
    queued = eng.submit([21, 22, 23], max_new_tokens=4)
    assert queued.state is RequestState.WAITING
    assert eng.abort(queued, "cancelled")
    assert queued.state is RequestState.DONE
    assert queued.finish_reason == "cancelled" and queued.generated == []
    eng.run_until_drained()
    assert runner.state is RequestState.DONE and len(runner.generated) == 4
    s = eng.stats()
    assert s["requests_aborted"] == 1 and s["requests_done"] == 2
    assert_no_residue(eng)


@pytest.mark.parametrize("policy", ["slo", "fcfs"])
def test_abort_mid_prefill_releases_blocks(setup, policy):
    """Abort while the victim is inside chunked prefill: its partial blocks
    must free and the survivor must be token-identical to an undisturbed
    run."""
    cfg, params = setup
    survivor_prompt = [4, 4, 8, 6]
    ref = make_engine(cfg, params, policy=policy)
    ref_req = ref.submit(survivor_prompt, max_new_tokens=5)
    ref.run_until_drained()

    eng = make_engine(cfg, params, prefill_budget=4, policy=policy)
    victim = eng.submit(list(range(2, 26)), max_new_tokens=4)  # 24-token prompt
    survivor = eng.submit(survivor_prompt, max_new_tokens=5)
    eng.step()
    assert victim.prefilling, "victim must still be mid-chunked-prefill"
    held = eng.allocator.blocks_in_use
    assert eng.abort(victim.req_id, "cancelled")  # by id, not handle
    assert eng.allocator.blocks_in_use < held
    assert victim.finish_reason == "cancelled"
    eng.run_until_drained()
    assert survivor.generated == ref_req.generated
    assert_no_residue(eng)


@pytest.mark.parametrize("prefix_cache", [True, False])
def test_abort_mid_decode_parks_committed_blocks(setup, prefix_cache):
    """Abort a decoding request: with the prefix cache on, its committed
    blocks park in the index (a follower still hits them); off, everything
    frees outright.  Either way the pool returns to full capacity."""
    cfg, params = setup
    eng = make_engine(cfg, params, prefix_cache=prefix_cache)
    prompt = [7, 3, 20, 21, 22, 23, 24, 25]
    req = eng.submit(prompt, max_new_tokens=8)
    for _ in range(4):
        eng.step()
    assert req.state is RequestState.ACTIVE and len(req.generated) >= 2
    assert eng.abort(req, "cancelled")
    assert_no_residue(eng)
    assert not eng.has_work
    follower = eng.submit(prompt + [30], max_new_tokens=3)
    eng.run_until_drained()
    if prefix_cache:
        assert follower.prefix_hit_tokens >= eng.block_size, (
            "an abort must not throw away indexed prefix work"
        )
    assert_no_residue(eng)
    names = [e.name for e in eng.tracer.events_for(req.req_id)]
    assert "abort" in names


def test_abort_unknown_or_finished_is_noop(setup):
    cfg, params = setup
    eng = make_engine(cfg, params)
    req = eng.submit([5, 9, 12], max_new_tokens=2)
    eng.run_until_drained()
    assert not eng.abort(req), "finished request: abort must report False"
    assert not eng.abort(9999), "unknown id: abort must report False"
    assert eng.stats()["requests_aborted"] == 0


# ---- deadline enforcement -------------------------------------------------


def test_deadline_aborts_before_first_token(setup):
    """A request whose TTFT deadline passes while still queued must abort
    with deadline_exceeded — not burn blocks finishing a worthless answer."""
    cfg, params = setup
    clock = ManualClock(tick=0.05)
    eng = make_engine(cfg, params, clock=clock)
    doomed = eng.submit([1, 2, 3], max_new_tokens=4, deadline_s=0.001)
    healthy = eng.submit([5, 9, 12], max_new_tokens=4)
    eng.run_until_drained()
    assert doomed.state is RequestState.DONE
    assert doomed.finish_reason == "deadline_exceeded" and doomed.generated == []
    assert healthy.finish_reason == "length" and len(healthy.generated) == 4
    s = eng.stats()
    assert s["deadline_violations"] == 1 and s["requests_aborted"] == 1
    assert "engine_deadline_violations_total 1" in eng.metrics.render_text()
    assert_no_residue(eng)


def test_deadline_never_aborts_after_first_token(setup):
    """Post-first-token the SLO is already met or missed; the request runs
    to completion either way (an overrun only counts, never aborts)."""
    cfg, params = setup
    clock = ManualClock(tick=0.01)
    eng = make_engine(cfg, params, clock=clock)
    req = eng.submit([5, 9, 12, 7], max_new_tokens=6, deadline_s=1e9)
    while not req.generated:
        eng.step()
    # shrink the deadline under the current clock: deadline_t is now firmly
    # in the past, but the first token already landed inside it
    req.deadline_s = clock.now - req.submit_t
    assert clock.now > req.deadline_t or clock.now == req.deadline_t
    eng.run_until_drained()
    assert req.finish_reason in ("length", "eos") and len(req.generated) >= 1
    assert eng.stats()["requests_aborted"] == 0


# ---- async cancel / stream abandonment ------------------------------------


def test_async_cancel_mid_stream(setup):
    """cancel() between steps must abort the request, free its resources
    and deliver a finish event with the cancel reason to the stream."""
    cfg, params = setup
    eng = make_engine(cfg, params)

    async def go():
        async with AsyncEngine(eng) as aeng:
            events = []
            async for ev in aeng.submit_stream([5, 9, 12, 7], max_new_tokens=32):
                events.append(ev)
                if ev.kind == "token" and len(events) == 2:
                    aeng.cancel(ev.req_id)
            return events

    events = asyncio.run(go())
    finish = events[-1]
    assert finish.kind == "finish" and finish.reason == "cancelled"
    assert 0 < finish.n_tokens < 32, "cancel must land mid-generation"
    assert eng.stats()["requests_aborted"] == 1
    assert_no_residue(eng)


def test_abandoned_stream_cancels_request(setup):
    """A consumer that walks away mid-stream (dead SSE socket) must not
    keep its request decoding: generator teardown cancels it."""
    cfg, params = setup
    eng = make_engine(cfg, params)

    async def go():
        async with AsyncEngine(eng) as aeng:
            async for ev in aeng.submit_stream([7, 3, 20], max_new_tokens=32):
                if ev.kind == "token":
                    break  # client disconnected
            await aeng.drain()

    asyncio.run(go())
    assert eng.stats()["requests_aborted"] == 1
    assert not eng.has_work
    assert_no_residue(eng)
