"""Tensor-parallel paged serving: TP=n must be invisible to the tokens.

Runs only on a multi-device jax (the CI lane forces a 2-device CPU via
``XLA_FLAGS=--xla_force_host_platform_device_count=2``; a plain tier-1 run
skips cleanly).  Three invariants:

* **Token equivalence** — greedy decode under TP=2 is token-identical to the
  single-device engine across dense / moe / sliding-window archs, with
  prefix caching, chunked prefill and ngram speculative decoding enabled,
  on both attention backends (Pallas runs per-shard under ``shard_map``).
* **Sharding layout** — paged K/V pool leaves carry a NamedSharding
  partitioned on the kv-head axis; block tables stay replicated, and both
  survive engine steps (explicit jit out-specs, not propagation luck).
* **Host state is mesh-invariant** — allocator / prefix-index counters and
  the global ``cache_bytes()`` don't depend on mesh size; only
  ``cache_bytes(per_device=True)`` shrinks with TP.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InferenceEngine

if jax.device_count() < 2:
    pytest.skip(
        "needs >= 2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=2)",
        allow_module_level=True,
    )

from repro.launch.mesh import make_serving_mesh  # noqa: E402  (after the skip guard)

# prompts with repetitive suffixes (the ngram drafter proposes real windows)
# and a shared leading prefix (the prefix cache registers and re-serves it)
SHARED = [11, 12, 13, 14, 15, 16, 17, 18]
PROMPTS = [
    SHARED + [7, 3, 9, 4] * 3 + [5],
    SHARED + [5, 9, 12, 5, 9, 12, 2],
    SHARED + [21, 22, 23, 24],
    SHARED + [7, 3, 9, 4] * 3 + [5],  # repeat: exercises a full prefix hit
]


def _make(arch, window=0):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    if window:
        cfg = cfg.replace(sliding_window=window)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _run(cfg, params, mesh=None, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        eng = InferenceEngine(
            cfg,
            params,
            max_batch=2,
            max_seq=64,
            block_size=8,
            cache_dtype=jnp.float32,
            mesh=mesh,
            **kw,
        )
        reqs = [eng.submit(p, max_new_tokens=6) for p in PROMPTS]
        eng.run_until_drained()
    return [r.generated for r in reqs], eng


# dense / moe / sliding-window x {plain, prefix+chunked+ngram-spec} x backend
TP_CASES = [
    ("olmo-1b", 0, "xla", {}),
    ("olmo-1b", 0, "pallas", {}),
    ("olmo-1b", 0, "xla", dict(spec_decode="ngram", spec_k=3, prefill_budget=8)),
    ("olmo-1b", 0, "pallas", dict(spec_decode="ngram", spec_k=3, prefill_budget=8)),
    ("qwen3-moe-235b-a22b", 0, "xla", dict(spec_decode="ngram", spec_k=3, prefill_budget=8)),
    ("olmo-1b", 8, "xla", dict(spec_decode="ngram", spec_k=3, prefill_budget=8)),
    ("olmo-1b", 8, "pallas", dict(spec_decode="ngram", spec_k=3)),
    # hybrid: blocking prefill+graft admission under the mesh (its odd head
    # count also exercises the replicated-pool divisibility fallback)
    ("hymba-1.5b", 0, "xla", {}),
]


@pytest.mark.parametrize("arch,window,impl,kw", TP_CASES)
def test_tp2_token_identical_to_tp1(arch, window, impl, kw):
    cfg, params = _make(arch, window)
    base, _ = _run(cfg, params, attn_impl=impl, **kw)
    tp, _ = _run(cfg, params, mesh=make_serving_mesh(2), attn_impl=impl, **kw)
    assert base == tp, f"{arch}/w{window}/{impl}/{kw}: TP=2 changed greedy tokens"


def test_mqa_pallas_falls_back_and_matches():
    """num_kv_heads=1 can't shard over model=2: the engine warns, the Pallas
    path falls back to the XLA reference per-shard logic, tokens unchanged."""
    cfg, _ = _make("olmo-1b")
    cfg = cfg.replace(num_kv_heads=1)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    base, _ = _run(cfg, params, attn_impl="pallas")
    with pytest.warns(RuntimeWarning, match="head counts"):
        eng = InferenceEngine(
            cfg,
            params,
            max_batch=2,
            max_seq=64,
            block_size=8,
            cache_dtype=jnp.float32,
            mesh=make_serving_mesh(2),
            attn_impl="pallas",
        )
    reqs = [eng.submit(p, max_new_tokens=6) for p in PROMPTS]
    eng.run_until_drained()
    assert [r.generated for r in reqs] == base
    # indivisible head count -> divisibility fallback replicates the pool
    assert eng.cache["k"].sharding.spec == jax.sharding.PartitionSpec(None, None, None, None, None)


def test_kv_pools_head_sharded_tables_replicated():
    cfg, params = _make("olmo-1b")
    mesh = make_serving_mesh(2)
    _, eng = _run(cfg, params, mesh=mesh)
    P = jax.sharding.PartitionSpec
    for name in ("k", "v"):
        sh = eng.cache[name].sharding
        assert isinstance(sh, jax.sharding.NamedSharding)
        # (L, num_blocks, block_size, kv_heads, head_dim): kv_heads partitioned
        assert sh.spec == P(None, None, None, "model", None), (name, sh.spec)
    assert eng.cache["tbl"].sharding.spec == P(None, None, None)
    # params: attention head projections shard over the model axis
    wq = eng.params["blocks"]["attn"]["wq"]
    flat = [a for e in wq.sharding.spec if e for a in (e if isinstance(e, tuple) else (e,))]
    assert "model" in flat, wq.sharding.spec


def test_cache_bytes_global_vs_per_device():
    cfg, params = _make("olmo-1b")
    _, base = _run(cfg, params)
    _, tp = _run(cfg, params, mesh=make_serving_mesh(2))
    # global (logical) bytes are mesh-invariant; per-device bytes shrink by
    # the pool shard and the two are consistent leaf-by-leaf
    assert tp.cache_bytes() == base.cache_bytes()
    assert tp.cache_bytes(per_device=True) < tp.cache_bytes()
    for name in ("k", "v"):
        leaf = tp.cache[name]
        import numpy as np

        shard = int(np.prod(leaf.sharding.shard_shape(leaf.shape))) * leaf.dtype.itemsize
        assert shard * 2 == leaf.size * leaf.dtype.itemsize
    s = tp.stats()
    assert s["tp"] == 2
    assert s["cache_bytes_per_device"] == tp.cache_bytes(per_device=True)


def test_allocator_and_prefix_counters_mesh_invariant():
    cfg, params = _make("olmo-1b")
    _, base = _run(cfg, params, spec_decode="ngram", spec_k=3, prefill_budget=8)
    _, tp = _run(cfg, params, mesh=make_serving_mesh(2), spec_decode="ngram", spec_k=3, prefill_budget=8)
    sb, st = base.stats(), tp.stats()
    keys = [k for k in sb if k.startswith(("alloc_", "prefix_"))]
    keys += ["prefill_tokens", "prefill_chunks", "evictions", "verify_tokens", "tokens_out"]
    for k in keys:
        assert sb[k] == st[k], f"{k}: {sb[k]} != {st[k]} under TP=2"
