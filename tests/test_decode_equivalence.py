"""Decode-by-steps must reproduce the teacher-forced forward logits.

Validates: KV caches (incl. sliding-window ring buffers), RWKV/SSM recurrent
states vs their chunked-parallel training forms, rope positions, VLM cross
caches.  MoE archs use a high capacity factor so GShard token-dropping (a
batch-composition effect, not a bug) doesn't enter the comparison.  The
speculative-decoding section holds the same bar at the engine level: greedy
speculative decode must be token-identical to the non-speculative engine.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import ASSIGNED, get_config
from repro.models import decode_step, forward, init_cache, init_params, prefill
from repro.serving import InferenceEngine

B, S = 1, 24

DECODE_ARCHS = [a for a in ASSIGNED if a != "hubert-xlarge"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key, jnp.float32)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["vision_tokens"] = jax.random.normal(key, (B, cfg.vision.num_image_tokens, cfg.d_model))
    logits_tf, _ = forward(cfg, params, batch)

    cache = init_cache(cfg, B, S, jnp.float32)
    if cfg.family == "vlm":
        _, raw = prefill(cfg, params, {"tokens": tokens[:, :1], "vision_tokens": batch["vision_tokens"]})
        cache["cross"] = raw["cross"]
    dec = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q))
    errs = []
    for t in range(S):
        lg, cache = dec(params, cache, tokens[:, t : t + 1], jnp.full((B,), t, jnp.int32))
        errs.append(float(np.max(np.abs(np.asarray(lg) - np.asarray(logits_tf[:, t])))))
    assert max(errs) < 5e-4, f"{arch}: decode diverges from teacher forcing by {max(errs)}"


def test_sliding_window_ring_buffer():
    """Decode past the window: ring slots must overwrite oldest entries."""
    cfg = reduce_for_smoke(get_config("hymba-1.5b"))
    assert cfg.sliding_window == 32
    S_long = 48  # > window
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key, jnp.float32)
    tokens = jax.random.randint(key, (B, S_long), 0, cfg.vocab_size)
    logits_tf, _ = forward(cfg, params, {"tokens": tokens})
    cache = init_cache(cfg, B, S_long, jnp.float32)
    dec = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q))
    errs = []
    for t in range(S_long):
        lg, cache = dec(params, cache, tokens[:, t : t + 1], jnp.full((B,), t, jnp.int32))
        errs.append(float(np.max(np.abs(np.asarray(lg) - np.asarray(logits_tf[:, t])))))
    assert max(errs) < 5e-4, f"ring-buffer decode diverges by {max(errs)}"


# ---------------------------------------------------------------------------
# speculative decode: greedy token identity at the engine level
# ---------------------------------------------------------------------------

# dense / moe take the real verify path; hybrid safely disables speculation
# internally (recurrent states can't roll back) and must still match
SPEC_EQUIV_ARCHS = ["olmo-1b", "qwen3-moe-235b-a22b", "hymba-1.5b"]


@pytest.mark.parametrize("arch", SPEC_EQUIV_ARCHS)
@pytest.mark.parametrize("mode", ["ngram", "draft"])
def test_speculative_engine_token_identical(arch, mode):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = [[7, 3, 9, 4] * 3 + [5], [5, 9, 12, 5, 9, 12, 2]]

    def run(**kw):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            eng = InferenceEngine(
                cfg, params, max_batch=2, max_seq=64, block_size=8,
                cache_dtype=jnp.float32, **kw,
            )
            outs = []
            for p in prompts:
                r = eng.submit(p, max_new_tokens=6)
                eng.run_until_drained()
                outs.append(r.generated)
            return outs

    kw = dict(spec_decode=mode, spec_k=3)
    if mode == "draft":
        kw.update(draft_cfg=cfg, draft_params=params)  # self-draft: max acceptance
    assert run(**kw) == run(), f"{arch}/{mode}: speculative decode changed greedy tokens"
