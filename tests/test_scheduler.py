"""QoS scheduler: the paper's four usage patterns + flex-start + calendar.

Includes hypothesis property tests over random job streams asserting the
system invariants (no double-booking, guaranteed completion, bounded rollback).
"""

import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import (
    CHIPS_PER_NODE,
    Cluster,
    ClusterSpec,
    Job,
    JobState,
    QoS,
    Reservation,
    Scheduler,
)


def make_sched(nodes=8, pods=1):
    cluster = Cluster(ClusterSpec("test", nodes_per_pod=nodes, num_pods=pods))
    return Scheduler(cluster), cluster


def test_priority_order_inference_first():
    sched, cluster = make_sched(nodes=2)
    train = sched.submit(Job("t", "acme", QoS.TRAINING, chips=8, duration=100))
    infer = sched.submit(Job("i", "acme", QoS.INFERENCE, chips=8, duration=100))
    sched.tick(1)
    assert infer.state == JobState.RUNNING
    assert train.state == JobState.PENDING  # inference claimed the capacity


def test_flex_start_preemption_and_guaranteed_completion():
    sched, cluster = make_sched(nodes=2)
    train = sched.submit(Job("t", "acme", QoS.TRAINING, chips=8, duration=50, checkpoint_interval=10))
    sched.tick(1)
    assert train.state == JobState.RUNNING
    sched.tick(26)  # progress 25, checkpoints at 10 and 20
    infer = sched.submit(Job("i", "acme", QoS.INFERENCE, chips=8, duration=10))
    sched.tick(27)
    assert infer.state == JobState.RUNNING
    assert train.state == JobState.PENDING  # preempted, requeued
    assert train.progress == 20  # rolled back to last checkpoint (flex-start)
    sched.tick(40)  # inference done at ~37 -> train restarts
    assert train.state == JobState.RUNNING
    sched.tick(100)
    assert train.state == JobState.COMPLETED  # guaranteed completion


def test_calendar_reservation_auto_start_stop():
    sched, cluster = make_sched(nodes=4)
    sched.reserve(Reservation("r1", "uob", chips=8, start=10, end=30))
    filler = sched.submit(Job("f", "acme", QoS.TRAINING, chips=16, duration=100))
    sched.tick(1)
    assert filler.state == JobState.RUNNING
    sched.tick(10)  # window opens: reservation must start (may preempt flex)
    res_job = sched.running.get("res:r1")
    assert res_job is not None and res_job.state == JobState.RUNNING
    sched.tick(31)  # window closed
    assert "res:r1" not in sched.running


def test_node_failure_requeues_with_rollback():
    sched, cluster = make_sched(nodes=2)
    j = sched.submit(Job("t", "acme", QoS.TRAINING, chips=8, duration=100, checkpoint_interval=7))
    sched.tick(1)
    sched.tick(17)  # progress 16, checkpoints at 7, 14
    nid = j.nodes[0]
    cluster.fail_node(nid)
    assert j.state == JobState.PENDING
    assert j.progress == 14  # rolled back to checkpoint
    assert j.restarts == 1
    cluster.repair_node(nid)
    sched.tick(18)
    assert j.state == JobState.RUNNING


def test_elastic_shrink_start():
    sched, cluster = make_sched(nodes=4)
    blocker = sched.submit(Job("b", "acme", QoS.TRAINING, chips=8, duration=1000))
    sched.tick(1)
    elastic = sched.submit(Job("e", "acme", QoS.TRAINING, chips=16, duration=10, min_chips=4))
    sched.tick(2)
    assert elastic.state == JobState.RUNNING
    assert elastic.chips == 8  # shrunk to the free capacity


def test_pod_local_placement_preferred():
    sched, cluster = make_sched(nodes=4, pods=2)
    j = sched.submit(Job("j", "acme", QoS.TRAINING, chips=16, duration=10))
    sched.tick(1)
    pods = {cluster.nodes[n].pod for n in j.nodes}
    assert len(pods) == 1  # fits in one pod -> stays in one pod


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

job_strategy = st.builds(
    lambda i, qos, nodes, dur: Job(f"j{i}", "t", qos, chips=nodes * CHIPS_PER_NODE, duration=float(dur)),
    st.integers(0, 10**6),
    st.sampled_from(list(QoS)),
    st.integers(1, 4),
    st.integers(1, 40),
)


@settings(max_examples=50, deadline=None)
@given(st.lists(job_strategy, min_size=1, max_size=12, unique_by=lambda j: j.job_id))
def test_no_double_booking_and_completion(jobs):
    sched, cluster = make_sched(nodes=6)
    for j in jobs:
        sched.submit(j)
    for t in range(1, 400):
        sched.tick(float(t))
        # invariant: a node never serves two jobs
        owners = [n.job for n in cluster.nodes.values() if n.job is not None]
        assert len(owners) == len(set(owners)) or all(
            owners.count(o) == len([x for x in sched.running.values() if x.job_id == o][0].nodes)
            for o in owners
        )
        busy = sum(len(j.nodes) for j in sched.running.values())
        assert busy <= len(cluster.nodes)
    # every job that fits the cluster eventually completes (guaranteed completion)
    for j in jobs:
        if j.nodes_needed <= 6:
            assert j.state == JobState.COMPLETED, f"{j.job_id} ended {j.state}"


@settings(max_examples=30, deadline=None)
@given(
    st.integers(5, 25),  # checkpoint interval
    st.lists(st.integers(10, 120), min_size=1, max_size=4),  # preemption times
)
def test_rollback_never_exceeds_checkpoint_interval(ckpt_interval, preempt_times):
    sched, cluster = make_sched(nodes=2)
    j = sched.submit(
        Job("t", "acme", QoS.TRAINING, chips=8, duration=1e9, checkpoint_interval=float(ckpt_interval))
    )
    clock = 0.0  # last time actually ticked (keep simulation monotonic)
    for pt in sorted(set(preempt_times)):
        if float(pt) <= clock:
            continue
        t = float(pt)
        clock = t + 2.5
        sched.tick(t)
        if j.state != JobState.RUNNING:
            continue
        before = j.progress
        hi = sched.submit(Job(f"i{pt}", "x", QoS.INFERENCE, chips=8, duration=1.0))
        sched.tick(t + 0.5)
        if j.state == JobState.PENDING:
            # progress advanced (up to) 0.5 inside the preempting tick before
            # rollback; the flex-start property is: work lost <= one interval
            lost = (before + 0.5) - j.progress
            assert -1e-9 <= lost <= ckpt_interval + 0.5, f"lost {lost} vs interval {ckpt_interval}"
        sched.tick(t + 2.5)  # let the inference job finish
