"""Paged KV-cache serving: allocator invariants, kernel-vs-oracle, paged-vs-
dense decode equivalence, block-count admission backpressure, and the
concurrency-per-byte acceptance property (paged admits strictly more
concurrent requests than dense under the same cache-byte budget)."""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.kernels import paged_attention
from repro.kernels.paged_attention_ref import paged_attention_ref
from repro.models import decode_step, forward, init_paged_cache, init_params
from repro.serving import BlockAllocator, InferenceEngine, OutOfBlocks, RequestState, blocks_needed


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------


def test_allocator_invariants():
    a = BlockAllocator(9)  # 1 null + 8 usable
    assert a.capacity == 8 and a.num_free == 8 and a.blocks_in_use == 0
    b1 = a.alloc(3)
    b2 = a.alloc(2)
    assert len(set(b1) | set(b2)) == 5, "allocations must not overlap"
    assert 0 not in b1 + b2, "null block must never be allocated"
    assert a.blocks_in_use == 5 and a.num_free == 3
    assert a.peak_in_use == 5
    a.free(b1)
    assert a.blocks_in_use == 2 and a.num_free == 6
    b3 = a.alloc(6)  # freed blocks are reusable
    assert set(b3).isdisjoint(b2)
    with pytest.raises(OutOfBlocks):
        a.alloc(1)
    a.free(b2)
    with pytest.raises(ValueError):
        a.free(b2)  # double free


def test_allocator_defrag_accounting():
    a = BlockAllocator(17)
    blocks = a.alloc(16)
    a.free([b for b in blocks if b % 2 == 0])  # free every other block
    assert a.fragmentation() > 0.5
    a.defrag()
    a.free([b for b in blocks if b % 2 == 1])
    a.defrag()
    assert a.fragmentation() == 0.0
    assert a.alloc(3) == sorted(a._ref)  # post-defrag allocs are contiguous


def test_blocks_needed():
    assert blocks_needed(1, 16) == 1
    assert blocks_needed(16, 16) == 1
    assert blocks_needed(17, 16) == 2
    assert blocks_needed(0, 16) == 1  # a live request always owns >= 1 block


# ---------------------------------------------------------------------------
# Pallas kernel vs jnp oracle
# ---------------------------------------------------------------------------

KERNEL_CASES = [
    # B, nb, bs, H, KV, hd, window, softcap, dtype
    (2, 4, 8, 4, 2, 16, 0, 0.0, jnp.float32),
    (3, 3, 16, 8, 2, 32, 0, 0.0, jnp.float32),
    (2, 4, 8, 4, 4, 16, 12, 0.0, jnp.float32),  # sliding window
    (1, 2, 8, 2, 1, 64, 0, 30.0, jnp.float32),  # MQA + softcap
    (2, 4, 8, 4, 2, 16, 0, 0.0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", KERNEL_CASES)
def test_paged_attention_kernel_matches_oracle(case):
    B, nb, bs, H, KV, hd, win, cap, dt = case
    N = 1 + B * nb
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dt)
    kp = jax.random.normal(ks[1], (N, bs, KV, hd), dt)
    vp = jax.random.normal(ks[2], (N, bs, KV, hd), dt)
    # non-trivial tables: each sequence's blocks shuffled through the pool
    perm = jax.random.permutation(jax.random.PRNGKey(7), N - 1) + 1
    tbl = perm[: B * nb].reshape(B, nb).astype(jnp.int32)
    lens = jnp.array([1 + (7 * b) % (nb * bs) for b in range(B)], jnp.int32)
    out = paged_attention(q, kp, vp, tbl, lens, softcap=cap, window=win)
    ref = paged_attention_ref(q, kp, vp, tbl, lens, softcap=cap, window=win)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < tol, f"{case}: err={err}"


def test_paged_attention_int8_pools_close_to_fp():
    B, nb, bs, H, KV, hd = 2, 3, 8, 4, 2, 16
    from repro.serving.kvquant import quantize

    N = 1 + B * nb
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (N, bs, KV, hd))
    vp = jax.random.normal(ks[2], (N, bs, KV, hd))
    tbl = jnp.arange(1, 1 + B * nb, dtype=jnp.int32).reshape(B, nb)
    lens = jnp.full((B,), nb * bs, jnp.int32)
    kq, kscale = quantize(kp)
    vq, vscale = quantize(vp)
    fp = paged_attention_ref(q, kp, vp, tbl, lens)
    q8 = paged_attention_ref(q, kq, vq, tbl, lens, k_scale=kscale, v_scale=vscale)
    err = float(jnp.max(jnp.abs(fp - q8)))
    assert err < 5e-2, f"int8 paged attention drifted {err} from fp"


# ---------------------------------------------------------------------------
# paged decode == teacher forcing (dense / moe / hybrid, both impls)
# ---------------------------------------------------------------------------

B, S, BS = 1, 24, 8

PAGED_DECODE_CASES = [
    ("olmo-1b", "xla"),
    ("olmo-1b", "pallas"),
    ("qwen3-moe-235b-a22b", "xla"),
    ("hymba-1.5b", "xla"),  # sliding window + ssm states pass-through
    ("hymba-1.5b", "pallas"),
]


@pytest.mark.parametrize("arch,impl", PAGED_DECODE_CASES)
def test_paged_decode_matches_teacher_forcing(arch, impl):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key, jnp.float32)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_tf, _ = forward(cfg, params, {"tokens": tokens})

    nb = S // BS
    cache = init_paged_cache(cfg, 1 + B * nb, BS, B, nb, jnp.float32)
    tbl = jnp.arange(1, 1 + B * nb, dtype=jnp.int32).reshape(B, nb)
    cache["tbl"] = jnp.broadcast_to(tbl[None], (cfg.num_layers, B, nb))
    dec = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q, attn_impl=impl))
    errs = []
    for t in range(S):
        lg, cache = dec(params, cache, tokens[:, t : t + 1], jnp.full((B,), t, jnp.int32))
        errs.append(float(np.max(np.abs(np.asarray(lg) - np.asarray(logits_tf[:, t])))))
    assert max(errs) < 5e-4, f"{arch}/{impl}: paged decode diverges by {max(errs)}"


# ---------------------------------------------------------------------------
# engine: paged vs dense end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


ENGINE_SMOKE_ARCHS = ["olmo-1b", "qwen3-moe-235b-a22b", "hymba-1.5b"]


@pytest.mark.parametrize("arch", ENGINE_SMOKE_ARCHS)
def test_paged_engine_matches_dense_engine(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    prompts = [[5, 9, 12], [7, 3], [20, 21, 22, 23], [4, 4, 8]]
    outs = {}
    for kind in ("dense", "paged"):
        eng = InferenceEngine(
            cfg,
            params,
            max_batch=3,
            max_seq=64,
            cache_kind=kind,
            block_size=8,
            cache_dtype=jnp.float32,
        )
        reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.run_until_drained()
        assert all(r.state == RequestState.DONE for r in reqs)
        outs[kind] = [r.generated for r in reqs]
    assert outs["paged"] == outs["dense"], f"{arch}: paged decode diverged from dense"


def test_paged_admits_more_concurrency_same_byte_budget(setup):
    """Acceptance: under the same cache-byte budget, the paged engine
    sustains strictly more concurrent requests than the dense engine."""
    cfg, params = setup
    dense = InferenceEngine(
        cfg, params, max_batch=2, max_seq=64, cache_kind="dense", cache_dtype=jnp.float32
    )
    # 16 blocks x 8 = 128 positions (incl. the null block) <= the dense
    # engine's 2 x 64 lines — same byte budget, slots decoupled from max_seq
    paged = InferenceEngine(
        cfg,
        params,
        max_batch=8,
        max_seq=64,
        cache_kind="paged",
        block_size=8,
        num_blocks=16,
        cache_dtype=jnp.float32,
    )
    assert paged.cache_bytes() <= dense.cache_bytes(), (
        f"paged budget {paged.cache_bytes()} exceeds dense {dense.cache_bytes()}"
    )
    for eng in (dense, paged):
        for i in range(8):
            eng.submit([3 + i, 4, 5], max_new_tokens=5)  # 8 tokens -> 1 block each
        eng.run_until_drained()
        assert len(eng.done) == 8
    assert dense.stats()["peak_active"] == 2  # slot-capped
    assert paged.stats()["peak_active"] > dense.stats()["peak_active"]
    assert paged.stats()["decode_steps"] < dense.stats()["decode_steps"]


def test_out_of_blocks_backpressure(setup):
    cfg, params = setup
    # 4 usable blocks of 8 = 32 positions; each request needs 2 blocks
    eng = InferenceEngine(
        cfg, params, max_batch=4, max_seq=64, cache_kind="paged", block_size=8, num_blocks=5
    )
    reqs = [eng.submit([1 + i, 2, 3], max_new_tokens=6) for i in range(4)]
    eng.step()
    states = [r.state for r in reqs]
    assert states.count(RequestState.ACTIVE) == 2, "only 2 requests fit the pool"
    assert states.count(RequestState.WAITING) == 2, "admission must backpressure"
    assert eng.allocator.num_free == 0
    eng.run_until_drained()
    assert all(r.state == RequestState.DONE for r in reqs), "freed blocks must recycle"
    assert eng.allocator.blocks_in_use == 0


def test_sliding_window_blocks_reclaimed_mid_decode(setup):
    """Window archs must free blocks that slide out of the window while the
    request is still decoding (paged footprint stays O(window), like the
    dense ring) — and still decode the exact same tokens."""
    cfg, params = setup
    cfg = cfg.replace(sliding_window=8)
    outs = {}
    for kind in ("dense", "paged"):
        eng = InferenceEngine(
            cfg,
            params,
            max_batch=1,
            max_seq=64,
            cache_kind=kind,
            block_size=4,
            cache_dtype=jnp.float32,
        )
        r = eng.submit([5, 9, 12], max_new_tokens=21)  # 24 tokens = 6 blocks
        if kind == "paged":
            for _ in range(16):
                eng.step()
            assert r.state == RequestState.ACTIVE
            assert r.freed_blocks > 0, "no blocks reclaimed after sliding past window"
            assert eng.tbl[0, 0] == 0, "reclaimed table entries must point at null"
            assert eng.allocator.blocks_in_use < 6
        eng.run_until_drained()
        assert eng.allocator is None or eng.allocator.blocks_in_use == 0
        outs[kind] = r.generated
    assert outs["paged"] == outs["dense"]


def test_oversized_request_rejected(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=32, cache_kind="paged", block_size=8)
    with pytest.raises(ValueError):
        eng.submit(list(range(2, 30)), max_new_tokens=16)  # 28 + 16 > 32
    dense = InferenceEngine(cfg, params, max_batch=2, max_seq=32, cache_kind="dense")
    with pytest.raises(ValueError):
        dense.submit(list(range(2, 30)), max_new_tokens=16)  # would wrap the ring


def test_quantized_block_pool_runs_and_saves_bytes(setup):
    cfg, params = setup
    fp = InferenceEngine(
        cfg, params, max_batch=2, max_seq=64, block_size=8, cache_dtype=jnp.float32
    )
    q8 = InferenceEngine(
        cfg,
        params,
        max_batch=2,
        max_seq=64,
        block_size=8,
        cache_dtype=jnp.float32,
        quantize_kv=True,
    )
    r_fp = fp.submit([5, 9, 12], max_new_tokens=6)
    r_q8 = q8.submit([5, 9, 12], max_new_tokens=6)
    fp.run_until_drained()
    q8.run_until_drained()
    assert len(r_q8.generated) == 6
    # int8 + fp32 scales vs fp32 pools: > 2x KV-byte saving
    assert q8.cache_bytes() < fp.cache_bytes() / 2
    assert r_q8.generated == r_fp.generated, "int8 KV flipped greedy tokens at smoke scale"


# ---------------------------------------------------------------------------
# serving-path bugfix satellites
# ---------------------------------------------------------------------------


def test_prefill_trace_count_bounded(setup):
    """Mixed prompt lengths must hit a bounded number of prefill traces
    (power-of-two buckets), not one XLA compile per distinct length."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=4, max_seq=64, cache_dtype=jnp.float32)
    lengths = list(range(2, 18))  # 16 distinct lengths
    for n in lengths:
        eng.submit([(3 + i) % cfg.vocab_size for i in range(n)], max_new_tokens=2)
    eng.run_until_drained()
    assert len(eng.done) == len(lengths)
    traces = eng._prefill._cache_size()
    assert traces <= 3, f"{traces} prefill traces for buckets of {lengths}"  # 8/16/32
    assert traces < len(set(lengths))


def test_bucketed_prefill_is_exact(setup):
    """Padding the prompt to a bucket must not change the first sampled
    token or any subsequent decode (causal masking + last_index logits)."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64, cache_dtype=jnp.float32)
    prompt = [11, 7, 5]  # length 3 -> bucket 8
    r = eng.submit(prompt, max_new_tokens=5)
    eng.run_until_drained()
    toks = list(prompt)
    ref = []
    for _ in range(5):
        logits, _ = forward(cfg, params, {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        t = int(jnp.argmax(logits[0, -1]))
        ref.append(t)
        toks.append(t)
    assert r.generated == ref


def test_run_until_drained_warns_on_truncation(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64)
    eng.submit([1, 2, 3], max_new_tokens=30)
    eng.submit([4, 5, 6], max_new_tokens=30)
    with pytest.warns(RuntimeWarning, match="queued.*active.*unfinished"):
        eng.run_until_drained(max_steps=2)


def test_run_until_drained_no_warning_when_drained(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64)
    eng.submit([1, 2, 3], max_new_tokens=3)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.run_until_drained()


def test_top_k_one_matches_greedy(setup):
    cfg, params = setup
    greedy = InferenceEngine(cfg, params, max_batch=1, max_seq=64, cache_dtype=jnp.float32)
    topk1 = InferenceEngine(cfg, params, max_batch=1, max_seq=64, cache_dtype=jnp.float32)
    rg = greedy.submit([5, 9, 12], max_new_tokens=6, temperature=0.0)
    rk = topk1.submit([5, 9, 12], max_new_tokens=6, temperature=1.0, top_k=1)
    greedy.run_until_drained()
    topk1.run_until_drained()
    assert rk.generated == rg.generated, "top_k=1 sampling must reduce to greedy"


def test_top_k_restricts_support(setup):
    """With top_k=k, every sampled token must be in the top-k of the step's
    logits — verified indirectly: k=1 is deterministic across seeds."""
    cfg, params = setup
    outs = set()
    for seed in range(3):
        eng = InferenceEngine(
            cfg, params, max_batch=1, max_seq=64, seed=seed, cache_dtype=jnp.float32
        )
        r = eng.submit([8, 6, 4], max_new_tokens=4, temperature=0.7, top_k=1)
        eng.run_until_drained()
        outs.add(tuple(r.generated))
    assert len(outs) == 1


def test_cache_dtype_knob(setup):
    cfg, params = setup
    bf16 = InferenceEngine(cfg, params, max_batch=2, max_seq=64)  # default bf16
    fp32 = InferenceEngine(cfg, params, max_batch=2, max_seq=64, cache_dtype=jnp.float32)
    assert bf16.cache["k"].dtype == jnp.bfloat16
    assert fp32.cache["k"].dtype == jnp.float32
    assert bf16.cache_bytes() < fp32.cache_bytes()
    r = bf16.submit([5, 9, 12], max_new_tokens=4)
    bf16.run_until_drained()
    assert len(r.generated) == 4


# ---------------------------------------------------------------------------
# tiered allocator core: SpillPool unit behaviour + Hypothesis state machine
# ---------------------------------------------------------------------------


def _tiny_rows(tag: float) -> dict:
    """A recognizable 8-byte payload standing in for one block's K/V rows."""
    return {"k": np.full((2,), tag, np.float32)}


def test_spill_pool_roundtrip_and_budget():
    from repro.serving import SpillPool

    drops = []
    pool = SpillPool(16, mode="cache", staging_depth=0, on_drop=drops.append)
    h1 = pool.put(_tiny_rows(1.0))
    h2 = pool.put(_tiny_rows(2.0))
    assert h1 < 0 and h2 < 0 and h1 != h2, "handles are distinct negatives"
    assert pool.bytes_used == 16 and len(pool) == 2
    h3 = pool.put(_tiny_rows(3.0))  # over budget: LRU (h1) drops
    assert drops == [h1] and h1 not in pool and len(pool) == 2
    assert float(np.asarray(pool.get(h2)["k"])[0]) == 2.0  # get keeps the entry
    h4 = pool.put(_tiny_rows(4.0))  # h2 was LRU-bumped by get -> h3 drops
    assert drops == [h1, h3]
    assert float(np.asarray(pool.pop(h4)["k"])[0]) == 4.0  # pop removes
    assert h4 not in pool and pool.bytes_used == 8
    assert pool.put(_tiny_rows(9.0) | {"pad": np.zeros(30, np.float32)}) is None
    assert pool.refused == 1, "an entry alone exceeding capacity is refused"
    s = pool.stats()
    assert s["spills"] == 4 and s["drops"] == 2 and s["blocks"] == 1


def test_spill_pool_staging_defers_materialization():
    from repro.serving import SpillPool

    pool = SpillPool(1 << 20, mode="cache", staging_depth=2)
    h1, h2, h3 = (pool.put(_tiny_rows(float(i))) for i in (1, 2, 3))
    # depth 2: h1 was pushed out of the staging ring by h3's put
    assert pool.stats()["staged"] == 2
    assert isinstance(pool._payload[h1]["k"], np.ndarray), "h1 materialized to host"
    pool.flush()
    assert pool.stats()["staged"] == 0
    for h, tag in ((h1, 1.0), (h2, 2.0), (h3, 3.0)):
        assert float(np.asarray(pool.get(h)["k"])[0]) == tag


def test_allocator_uncache_is_stranding_repair_only():
    a = BlockAllocator(5)
    blocks = a.alloc(2)
    a.free_cached(blocks)
    a.uncache(blocks[0])
    assert a.stranded_reclaims == 1 and not a.is_cached(blocks[0])
    assert a.num_free == 4 and a.blocks_in_use == 0
    with pytest.raises(ValueError):
        a.uncache(blocks[0])  # not cached any more
    with pytest.raises(ValueError):
        a.uncache(a.alloc(1)[0])  # live blocks can't be uncached


def test_tiered_allocator_state_machine():
    """Random alloc/incref/free/free_cached/restore/uncache sequences against
    a BlockAllocator whose evictions spill into a byte-budgeted SpillPool.
    Invariants after every step: each block is in exactly ONE of
    {free, in-use, cached}; spill handles partition separately; refcounts
    never negative; capacity conserved; alloc never hands out the null
    block, a handle, or a block the model already tracks; spilled payloads
    roundtrip bit-exactly."""
    pytest.importorskip("hypothesis")  # optional dep: property tests skip cleanly without it
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import (
        RuleBasedStateMachine,
        invariant,
        precondition,
        rule,
        run_state_machine_as_test,
    )

    from repro.serving import OutOfBlocks, SpillPool

    class TieredMachine(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.pool = SpillPool(4 * 8, mode="cache", staging_depth=1)  # 4 entries
            self.pool.on_drop = self._on_drop
            self.alloc_ = BlockAllocator(9, on_evict=self._on_evict)  # 8 usable
            self.live: dict[int, int] = {}  # block -> model refcount
            self.cached: list[int] = []  # model LRU order (oldest first)
            self.spilled: dict[int, float] = {}  # handle -> expected payload tag

        # -- the prefix index's tier hooks, minimally modelled ----------
        def _on_evict(self, block):
            self.cached.remove(block)
            h = self.pool.put(_tiny_rows(float(block)))
            if h is None:
                return "dropped"
            self.spilled[h] = float(block)
            return "spilled"

        def _on_drop(self, handle):
            self.spilled.pop(handle, None)

        # -- rules ------------------------------------------------------
        @rule(n=st.integers(0, 3))
        def alloc(self, n):
            if n > self.alloc_.num_free:
                with pytest.raises(OutOfBlocks):
                    self.alloc_.alloc(n)
                return
            got = self.alloc_.alloc(n)
            assert len(got) == n and len(set(got)) == n
            for b in got:
                assert b >= 1, f"alloc handed out null/handle id {b}"
                assert b not in self.live and b not in self.cached
                self.live[b] = 1

        @precondition(lambda self: self.live)
        @rule(data=st.data())
        def incref(self, data):
            b = data.draw(st.sampled_from(sorted(self.live)))
            self.alloc_.incref(b)
            self.live[b] += 1

        @precondition(lambda self: self.live)
        @rule(data=st.data())
        def free(self, data):
            b = data.draw(st.sampled_from(sorted(self.live)))
            self.alloc_.free([b])
            self.live[b] -= 1
            if not self.live[b]:
                del self.live[b]

        @precondition(lambda self: self.live)
        @rule(data=st.data())
        def free_cached(self, data):
            b = data.draw(st.sampled_from(sorted(self.live)))
            self.alloc_.free_cached([b])
            self.live[b] -= 1
            if not self.live[b]:
                del self.live[b]
                self.cached.append(b)

        @precondition(lambda self: self.cached)
        @rule(data=st.data())
        def revive_cached(self, data):
            b = data.draw(st.sampled_from(self.cached))
            self.alloc_.reuse_cached(b)
            self.cached.remove(b)
            self.live[b] = 1

        @precondition(lambda self: self.cached)
        @rule(data=st.data())
        def uncache(self, data):
            b = data.draw(st.sampled_from(self.cached))
            self.alloc_.uncache(b)
            self.cached.remove(b)

        @precondition(lambda self: self.spilled and self.alloc_.num_free > 0)
        @rule(data=st.data())
        def restore(self, data):
            # the engine's swap-in admission: pop the payload FIRST, then
            # allocate the destination (alloc may spill more entries)
            h = data.draw(st.sampled_from(sorted(self.spilled)))
            tag = self.spilled.pop(h)
            payload = self.pool.pop(h)
            assert float(np.asarray(payload["k"])[0]) == tag, "spill roundtrip corrupted rows"
            got = self.alloc_.alloc(1)
            self.live[got[0]] = 1

        # -- invariants -------------------------------------------------
        @invariant()
        def tiers_partition(self):
            a = self.alloc_
            assert dict(a._ref) == self.live
            assert list(a._cached) == self.cached
            assert set(self.live).isdisjoint(self.cached)
            assert all(rc >= 1 for rc in self.live.values())
            assert a.blocks_in_use + len(a._free) + a.num_cached == a.capacity
            assert a.num_free == a.capacity - a.blocks_in_use

        @invariant()
        def pool_consistent(self):
            assert set(self.pool._payload) == set(self.spilled)
            assert all(h < 0 for h in self.spilled)
            assert self.pool.bytes_used <= self.pool.capacity_bytes
            assert self.pool.bytes_used == 8 * len(self.spilled)

    run_state_machine_as_test(
        TieredMachine,
        settings=settings(max_examples=25, stateful_step_count=50, deadline=None),
    )
