"""Unit tests for the dry-run analysis machinery: the jaxpr cost model and
the trip-count-aware HLO collective parser (the roofline's data sources)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import collective_stats, shape_bytes
from repro.launch.jaxpr_cost import estimate_cost
from repro.parallel.collectives import CollectiveModel


def test_jaxpr_cost_exact_matmul():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    est = estimate_cost(lambda x, y: x @ y, a, b)
    assert est["flops"] == 2 * 128 * 256 * 64
    # bytes: both operands + output
    assert est["hbm_bytes"] == (128 * 256 + 256 * 64 + 128 * 64) * 4


def test_jaxpr_cost_scales_scan_by_trip_count():
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def scanned(w, x):
        def body(c, _):
            return c @ w, None

        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    est = estimate_cost(scanned, w, x)
    assert est["flops"] >= 10 * 2 * 64**3  # ONE body x 10 (XLA reports x1)


def test_jaxpr_cost_counts_remat_recompute():
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def loss(w):
        f = jax.checkpoint(lambda w: jnp.sum(jnp.tanh(w @ w) @ w))
        return f(w)

    base = estimate_cost(lambda w: jnp.sum(jnp.tanh(w @ w) @ w), w)
    grad = estimate_cost(jax.grad(loss), w)
    # grad-with-remat must cost more than 2x forward (fwd + recompute + bwd)
    assert grad["flops"] > 2.5 * base["flops"]


def test_shape_bytes_parser():
    assert shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert shape_bytes("bf16[2,2]{1,0}") == 8
    assert shape_bytes("(f32[4], s32[2])") == 24
    assert shape_bytes("pred[]") == 1  # scalar


def test_collective_parser_scales_by_while_trip():
    hlo = """
HloModule test

%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(%gte), replica_groups={{0,1,2,3}}, to_apply=%add
}

%cond.1 (p: (s32[], f32[64])) -> pred[] {
  %c = s32[] constant(16)
  %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %w = (s32[], f32[64]) while(%init), condition=%cond.1, body=%body.1
  %ar2 = f32[128]{0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
}
"""
    st = collective_stats(hlo)
    # 16 iterations x 256B + 1 x 512B
    assert st.operand_bytes["all-reduce"] == 16 * 256 + 512
    assert st.count["all-reduce"] == 17


def test_collective_ring_model():
    m = CollectiveModel()
    assert m.all_reduce(100.0, 4) == pytest.approx(150.0)  # 2(n-1)/n
    assert m.all_gather(100.0, 4) == pytest.approx(75.0)
    assert m.all_to_all(100.0, 2) == pytest.approx(50.0)
