"""Multi-replica router: affinity, health, failover, drain, degraded mode.

The contract under test:

* **Routing** — prefix affinity sends a request to the replica already
  holding its prompt's blocks; a cold burst sharing a new prefix pins to
  one replica via the sticky key; distinct prompts balance by load.
* **Failover correctness** — killing a replica mid-decode must lose no
  request: in-flight work resubmits to a peer, resumes from the committed
  tokens, and the final greedy output is *token-identical* to a run with
  no failure (the preemption-resume contract, across engines).
* **Health lifecycle** — missed heartbeats walk HEALTHY → SUSPECT →
  UNHEALTHY exactly like the seed cluster's sweep; a straggler recovers,
  a hung replica fails over.
* **Drain / degraded mode** — a draining replica finishes (or migrates)
  its work and retires; with no admittable replica ``submit`` raises
  ``ServiceUnavailable`` and fully-orphaned work fails fast.
"""

import asyncio

import jax
import jax.numpy as jnp
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.serving import (
    AsyncEngine,
    FaultPlan,
    InferenceEngine,
    ManualClock,
    Replica,
    ReplicaState,
    Router,
    ServiceUnavailable,
)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params = init_params_cached(cfg)
    return cfg, params


_PARAMS_CACHE = {}


def init_params_cached(cfg):
    if "p" not in _PARAMS_CACHE:
        from repro.models import init_params

        _PARAMS_CACHE["p"] = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return _PARAMS_CACHE["p"]


def make_engine(cfg, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 64)
    kw.setdefault("cache_kind", "paged")
    kw.setdefault("block_size", 4)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("prefill_budget", 8)
    return InferenceEngine(cfg, params, **kw)


def make_router(cfg, params, n, *, clock=None, fault_plans=None, engine_kw=None, **router_kw):
    replicas = [
        Replica(
            i,
            make_engine(cfg, params, clock=clock, **(engine_kw or {})),
            clock=clock,
            fault_plan=(fault_plans or {}).get(i),
        )
        for i in range(n)
    ]
    router_kw.setdefault("backoff_base_s", 1e-4)
    return Router(replicas, clock=clock, **router_kw)


# prompts stay well under the smoke config's vocab (256): an out-of-vocab
# id reads garbage embeddings and poisons the greedy argmax
def family(t, n=8):
    return [(13 * t + 5 * j + 7) % 197 + 2 for j in range(n)]


# ---- FaultPlan unit behaviour ---------------------------------------------


def test_fault_plan_schedule():
    plan = FaultPlan(crash_at_step=3, hang_from_step=10, slow_from_step=5, slow_until_step=8)
    assert not plan.crashes_at(2) and plan.crashes_at(3) and plan.crashes_at(7)
    assert not plan.hangs_at(9) and plan.hangs_at(10)
    assert not plan.slow_at(4) and plan.slow_at(5) and plan.slow_at(7)
    assert not plan.slow_at(8), "slow window is half-open"
    assert not plan.benign
    assert FaultPlan().benign
    assert FaultPlan(slow_from_step=0, slow_until_step=None).slow_at(10 ** 6)
    with pytest.raises(ValueError):
        FaultPlan(slow_every=0)


def test_router_validation(setup):
    cfg, params = setup
    with pytest.raises(ValueError):
        Router([])
    eng = make_engine(cfg, params)
    with pytest.raises(ValueError):
        Router([Replica(0, eng), Replica(0, eng)])
    with pytest.raises(ValueError):
        Router([Replica(0, eng)], policy="sticky-bit")
    with pytest.raises(ValueError):
        Router([Replica(0, eng)], suspect_after=2.0, fail_after=1.0)
    with pytest.raises(ValueError):
        Router([Replica(0, eng)], max_retries=-1)
    with pytest.raises(ValueError):
        Replica(-1, eng)


# ---- routing --------------------------------------------------------------


def test_affinity_routes_to_warm_replica(setup):
    """After one request drains, a prefix-sharing follower must land on the
    replica whose PrefixIndex holds the blocks — even when that replica is
    more loaded than its cold peer."""
    cfg, params = setup
    router = make_router(cfg, params, 2)
    first = router.submit(family(0) + [31, 32], max_new_tokens=4)
    router.run_until_drained()
    warm = first.replica_id
    # tilt the load away from the warm replica: affinity must still win
    cold = router.replicas[1 - warm]
    cold_req = cold.engine.submit([9, 8, 7], max_new_tokens=2)
    follower = router.submit(family(0) + [41, 42], max_new_tokens=4)
    assert follower.replica_id == warm
    assert router.metrics.counter("router_affinity_routed_total").value >= 1
    router.run_until_drained()
    assert follower.generated and follower.state == "done"
    assert cold_req.state.name == "DONE"


def test_sticky_key_pins_cold_burst(setup):
    """A burst sharing a brand-new prefix arrives before anything is cached;
    the sticky routing key must pin the whole burst to one replica so the
    first prefill serves the rest."""
    cfg, params = setup
    router = make_router(cfg, params, 2)
    burst = [router.submit(family(3) + [60 + i], max_new_tokens=3) for i in range(3)]
    assert len({r.replica_id for r in burst}) == 1
    # distinct prompts balance away from the pinned replica by load
    other = router.submit(family(4), max_new_tokens=3)
    assert other.replica_id != burst[0].replica_id
    router.run_until_drained()
    assert all(r.state == "done" for r in burst)


def test_distinct_prompts_balance_by_load(setup):
    cfg, params = setup
    router = make_router(cfg, params, 2)
    reqs = [router.submit(family(t), max_new_tokens=3) for t in range(4)]
    assert {r.replica_id for r in reqs} == {0, 1}
    router.run_until_drained()
    s = router.stats()
    assert s["requests_done"] == 4 and s["requests_failed"] == 0
    assert s["failovers"] == 0


def test_round_robin_and_random_policies(setup):
    cfg, params = setup
    rr = make_router(cfg, params, 2, policy="round_robin")
    a = rr.submit(family(0), max_new_tokens=2)
    b = rr.submit(family(0), max_new_tokens=2)  # same prefix, still alternates
    assert {a.replica_id, b.replica_id} == {0, 1}
    rnd = make_router(cfg, params, 2, policy="random")
    reqs = [rnd.submit(family(t), max_new_tokens=2) for t in range(8)]
    assert all(r.replica_id in (0, 1) for r in reqs)


# ---- failover correctness -------------------------------------------------


def test_crash_failover_is_token_identical(setup):
    """Kill one of two replicas mid-decode: every request must finish via
    failover with greedy output identical to a no-failure run."""
    cfg, params = setup
    prompts = [family(t) + [50 + t] for t in range(4)]
    ref = make_engine(cfg, params, max_batch=8)
    ref_reqs = [ref.submit(p, max_new_tokens=8) for p in prompts]
    ref.run_until_drained()

    clock = ManualClock(tick=1e-4)
    router = make_router(
        cfg, params, 2, clock=clock, fault_plans={0: FaultPlan(crash_at_step=4)}
    )
    reqs = [router.submit(p, max_new_tokens=8) for p in prompts]
    on_zero = [r for r in reqs if r.replica_id == 0]
    assert on_zero, "load balancing must place work on the doomed replica"
    router.run_until_drained()

    assert router.replicas[0].state is ReplicaState.DEAD
    s = router.stats()
    assert s["requests_done"] == 4 and s["requests_failed"] == 0
    assert s["failovers"] >= len(on_zero) and s["retries"] >= len(on_zero)
    for got, want in zip(reqs, ref_reqs):
        assert got.generated == want.generated, "failover changed greedy output"
    moved = on_zero[0]
    assert moved.failovers >= 1 and moved.preemptions == moved.failovers
    assert moved.replica_id == 1
    names = [e.name for e in router.tracer.events]
    assert "replica_down" in names and "failover" in names
    assert "router_failovers_total" in router.metrics.render_text()


def test_hang_detected_by_heartbeat_sweep(setup):
    """A wedged replica (no work, no heartbeat) must walk SUSPECT →
    UNHEALTHY through the sweep and its in-flight work must fail over."""
    cfg, params = setup
    clock = ManualClock(tick=0.01)
    router = make_router(
        cfg,
        params,
        2,
        clock=clock,
        suspect_after=0.05,
        fail_after=0.4,
        fault_plans={0: FaultPlan(hang_from_step=1)},
    )
    req = router.submit(family(1), max_new_tokens=6)
    assert req.replica_id == 0  # first placement: lowest id on a load tie
    router.run_until_drained()
    assert router.replicas[0].state is ReplicaState.UNHEALTHY
    assert req.state == "done" and req.replica_id == 1
    assert req.failovers >= 1
    names = [e.name for e in router.tracer.events]
    assert "replica_suspect" in names and "replica_down" in names

    ref = make_engine(cfg, params)
    ref_req = ref.submit(family(1), max_new_tokens=6)
    ref.run_until_drained()
    assert req.generated == ref_req.generated


def test_slow_replica_suspects_then_recovers(setup):
    """A stale heartbeat marks a replica SUSPECT (routed around, still
    admittable as a last resort); a fresh heartbeat restores HEALTHY."""
    cfg, params = setup
    clock = ManualClock()
    router = make_router(cfg, params, 2, clock=clock, suspect_after=1.0, fail_after=50.0)
    straggler = router.replicas[0]
    straggler.last_heartbeat = -2.0  # age 2.0 > suspect_after at now=0
    router._sweep_health(clock.now)
    assert straggler.state is ReplicaState.SUSPECT
    assert straggler.admittable, "suspect beats a 503"
    req = router.submit(family(2), max_new_tokens=2)
    assert req.replica_id == 1, "healthy peer preferred over the suspect"
    straggler.last_heartbeat = clock.now  # straggler caught up
    router._sweep_health(clock.now)
    assert straggler.state is ReplicaState.HEALTHY
    names = [e.name for e in router.tracer.events]
    assert "replica_suspect" in names and "replica_recovered" in names


def test_retry_exhaustion_fails_the_request(setup):
    """With every replica eventually dead, orphaned work must fail fast
    (finish_reason="unavailable") instead of hanging in the retry queue."""
    cfg, params = setup
    clock = ManualClock(tick=1e-4)
    router = make_router(
        cfg,
        params,
        2,
        clock=clock,
        fault_plans={0: FaultPlan(crash_at_step=2), 1: FaultPlan(crash_at_step=2)},
    )
    reqs = [router.submit(family(t), max_new_tokens=8) for t in range(2)]
    done = router.run_until_drained()
    assert all(r.state is ReplicaState.DEAD for r in router.replicas)
    assert all(r.state == "failed" for r in reqs)
    assert {r.finish_reason for r in reqs} <= {"failed", "unavailable"}
    assert len(done) == 2 and not router.has_work
    assert router.stats()["requests_failed"] == 2


def test_degraded_mode_rejects_submissions(setup):
    cfg, params = setup
    router = make_router(cfg, params, 1)
    router.replicas[0].state = ReplicaState.UNHEALTHY
    with pytest.raises(ServiceUnavailable):
        router.submit(family(0), max_new_tokens=2)
    assert router.metrics.counter("router_unavailable_total").value == 1
    assert router.stats()["replicas_admittable"] == 0


def test_abort_reaches_parked_failover(setup):
    """A request orphaned by a crash and parked behind backoff must still
    be abortable — the client that cancels during an outage gets a finish
    event, not a zombie retry."""
    cfg, params = setup
    clock = ManualClock()  # no ticks: backoff gate never expires on its own
    router = make_router(
        cfg, params, 2, clock=clock, backoff_base_s=1e9,
        fault_plans={0: FaultPlan(crash_at_step=1)},
    )
    req = router.submit(family(5), max_new_tokens=8)
    assert req.replica_id == 0
    router.step()  # replica step 0: normal work
    router.step()  # replica step 1: crash fires; the orphan parks

    assert req.engine_req is None and req.state == "active"
    assert router.abort(req, "cancelled")
    assert req.state == "done" and req.finish_reason == "cancelled"
    assert not router.abort(req), "double abort is a no-op"
    router.run_until_drained()
    assert router.stats()["requests_inflight"] == 0


# ---- drain ----------------------------------------------------------------


def test_drain_finishes_work_then_retires(setup):
    cfg, params = setup
    router = make_router(cfg, params, 2)
    req = router.submit(family(0), max_new_tokens=6)
    assert req.replica_id == 0
    router.step()
    router.drain(0)
    late = router.submit(family(0) + [70], max_new_tokens=2)
    assert late.replica_id == 1, "draining replica must not admit, even on affinity"
    router.run_until_drained()
    router.step()  # one idle step retires the drained-clean replica
    assert req.state == "done" and req.replica_id == 0, "drain lets work finish in place"
    assert router.replicas[0].state is ReplicaState.RETIRED
    with pytest.raises(ValueError):
        router.drain(0)  # retired: nothing to drain
    names = [e.name for e in router.tracer.events]
    assert "drain" in names and "drain_complete" in names


def test_drain_migrate_moves_work_token_identically(setup):
    cfg, params = setup
    ref = make_engine(cfg, params)
    ref_req = ref.submit(family(6), max_new_tokens=8)
    ref.run_until_drained()

    clock = ManualClock(tick=1e-4)
    router = make_router(cfg, params, 2, clock=clock)
    req = router.submit(family(6), max_new_tokens=8)
    assert req.replica_id == 0
    for _ in range(3):
        router.step()
    assert req.generated, "migration must happen mid-decode to test resume"
    router.drain(0, migrate=True)
    router.run_until_drained()
    router.step()
    assert req.state == "done" and req.replica_id == 1
    assert req.generated == ref_req.generated, "migration changed greedy output"
    assert router.stats()["migrations"] == 1
    assert router.stats()["failovers"] == 0, "migration is not failure accounting"
    assert router.replicas[0].state is ReplicaState.RETIRED
    alloc = router.replicas[0].engine.allocator
    assert alloc.num_free == alloc.capacity, "migrated-off replica must hold no blocks"


# ---- the async loop serves a fleet unchanged ------------------------------


def test_async_engine_drives_router_fleet(setup):
    """AsyncEngine duck-types the router exactly as one engine: streams
    over a 2-replica fleet must match the single-engine reference."""
    cfg, params = setup
    prompts = [family(0) + [80], family(1) + [81]]
    ref = make_engine(cfg, params)
    ref_reqs = [ref.submit(p, max_new_tokens=5) for p in prompts]
    ref.run_until_drained()

    async def go():
        async with AsyncEngine(make_router(cfg, params, 2)) as aeng:
            outs = await asyncio.gather(
                *(aeng.generate(p, max_new_tokens=5) for p in prompts)
            )
            return outs

    outs = asyncio.run(go())
    for (final, toks), want in zip(outs, ref_reqs):
        assert toks == want.generated
        assert final.reason == "length"
