"""Tiered KV cache: host-RAM spill for evicted prefix blocks.

Token-identity of spill-hit decode against the drop-on-evict paged baseline,
the uncached re-prefill engine and the dense-cache oracle across
dense/window/moe x xla/pallas x at-rest compression; mid-restore preemption
and mid-restore abort leave zero residue on both pools; knob validation."""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InferenceEngine, RequestState


def _make(arch, window=0):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    if window:
        cfg = cfg.replace(sliding_window=window)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _group_prompts(groups=4, pre_len=24):
    """One prompt per group: a distinct ``pre_len``-token prefix (3 full
    blocks at block_size 8) plus a unique tail token.  Submitted over two
    rounds against a pool too small for all chains, round 2 finds round 1's
    chains evicted — dropped or spilled depending on the tier."""
    return [
        [10 + g * 40 + i for i in range(pre_len)] + [200 + g] for g in range(groups)
    ]


def _drive(eng, rounds=2, max_new=5):
    outs = []
    for _ in range(rounds):
        for p in _group_prompts():
            r = eng.submit(p, max_new_tokens=max_new)
            eng.run_until_drained()
            outs.append(list(r.generated))
    return outs


def _engine(cfg, params, impl="xla", **kw):
    base = dict(
        max_batch=2, max_seq=64, block_size=8, cache_dtype=jnp.float32, attn_impl=impl
    )
    base.update(kw)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return InferenceEngine(cfg, params, **base)


def _force_spill(eng):
    """Churn the pool so every cached chain is evicted (spilled when a pool
    is attached): allocate the whole free budget, then return it."""
    blks = eng.allocator.alloc(eng.allocator.num_free)
    eng.allocator.free(blks)


# ---------------------------------------------------------------------------
# token identity: spill == drop == uncached re-prefill == dense oracle
# ---------------------------------------------------------------------------

# arch, sliding window, attention impl, extra engine knobs for the paged arms
TIERED_CASES = [
    ("olmo-1b", 0, "xla", {}),
    ("olmo-1b", 0, "pallas", {}),
    ("olmo-1b", 8, "xla", {}),  # sliding-window arch
    ("qwen3-moe-235b-a22b", 0, "xla", {}),
    ("olmo-1b", 0, "xla", {"spill_dtype": "int8"}),  # lossy at-rest compression
    ("olmo-1b", 0, "xla", {"quantize_kv": True}),  # int8 pool: spill is pool-native
]


@pytest.mark.parametrize("arch,window,impl,extra", TIERED_CASES)
def test_spill_engine_token_identical_to_baselines(arch, window, impl, extra):
    """The spill tier is a pure capacity extension: greedy outputs must be
    token-identical whether an evicted chain restores from host RAM (spill),
    re-prefills from scratch (drop / uncached), or was never paged at all
    (dense oracle) — including int8-at-rest and int8-pool configurations."""
    cfg, params = _make(arch, window)
    paged = dict(num_blocks=10, prefill_budget=8, **extra)  # 9 usable blocks
    if "quantize_kv" in extra:
        # an int8 pool has no dense counterpart — the oracle is an ample
        # paged pool of the same dtype that never needs to evict
        oracle = dict(num_blocks=64, **extra)
    else:
        oracle = dict(cache_kind="dense")
    outs, stats = {}, {}
    variants = {
        "oracle": oracle,
        "uncached": dict(prefix_cache=False, **paged),
        "drop": dict(**paged),
        "spill": dict(spill_bytes=8 << 20, **paged),
    }
    for label, kw in variants.items():
        eng = _engine(cfg, params, impl=impl, **kw)
        outs[label] = _drive(eng)
        stats[label] = eng.stats()
        assert eng.allocator is None or eng.allocator.blocks_in_use == 0
    assert outs["spill"] == outs["oracle"], f"{arch}/{impl}: spill diverged from oracle"
    assert outs["spill"] == outs["drop"], f"{arch}/{impl}: spill diverged from drop"
    assert outs["spill"] == outs["uncached"]
    drop_s, spill_s = stats["drop"], stats["spill"]
    assert drop_s["alloc_evictions_dropped"] > 0, "scenario failed to overflow the pool"
    assert spill_s["alloc_evictions_spilled"] > 0
    assert spill_s["restores"] > 0 and spill_s["spill_hit_tokens"] > 0
    assert spill_s["restores_pending"] == 0 and spill_s["spill_staged"] >= 0
    # round 2 hits the host tier instead of re-prefilling: strictly better
    assert spill_s["prefix_hit_rate"] > drop_s["prefix_hit_rate"]
    assert spill_s["prefill_tokens"] < drop_s["prefill_tokens"]


# ---------------------------------------------------------------------------
# mid-restore preemption / abort (PR-7 / PR-8 interactions)
# ---------------------------------------------------------------------------


def _restore_setup():
    """An engine + a spilled 3-block chain + a request admitted against it
    with restore_budget=1, stepped once: exactly one block restored, two
    swap-ins still pending."""
    cfg, params = _make("olmo-1b")
    eng = _engine(
        cfg, params, max_batch=1, num_blocks=12, prefill_budget=8,
        restore_budget=1, spill_bytes=1 << 20,
    )
    pre = list(range(2, 26))  # 24 tokens = 3 full blocks @ bs 8
    p_low = pre + [30]
    r0 = eng.submit(p_low, max_new_tokens=4)
    eng.run_until_drained()
    _force_spill(eng)
    assert len(eng.spill) >= 3, "chain must be fully spilled"
    r1 = eng.submit(p_low, max_new_tokens=4)
    eng.step()  # admit: 3 swap-ins queued, budget executes 1
    assert r1.pending_restores and len(eng._restore_q) == 2
    return eng, p_low, r0, r1


def test_mid_restore_preemption_token_identical():
    """A higher-priority arrival preempts a victim whose spill swap-ins are
    still in flight: the un-copied payloads demote back to the pool, the
    victim resumes through a mixed device/spilled chain, and every output
    matches the unconstrained reference."""
    eng, p_low, r0, r1 = _restore_setup()
    rh = eng.submit([40, 41, 42], max_new_tokens=4, priority=5)
    eng.step()  # SLO preemption evicts the mid-restore victim
    assert r1.state == RequestState.WAITING and r1.preemptions == 1
    assert not eng._restore_q and not eng._restoring, "cancel left tasks queued"
    assert eng.stats()["restores_cancelled"] >= 1
    assert eng.stats()["prefix_demoted"] >= 1, "payloads must re-park in the pool"
    eng.run_until_drained()
    assert r1.state == RequestState.DONE and rh.state == RequestState.DONE
    assert r1.generated == r0.generated, "resumed spill-hit decode diverged"
    # the high-priority request must match a clean single-request engine
    cfg, params = _make("olmo-1b")
    ref = _engine(cfg, params, max_batch=1)
    rr = ref.submit([40, 41, 42], max_new_tokens=4)
    ref.run_until_drained()
    assert rh.generated == rr.generated
    # zero residue on both pools
    assert eng.allocator.blocks_in_use == 0
    assert not eng._restore_q and not eng._restoring
    assert all(not r.pending_restores for r in eng.done)
    assert eng.spill.bytes_used == sum(eng.spill._nbytes.values())


def test_mid_restore_abort_zero_residue():
    """abort() of a request with pending swap-ins cancels the queue,
    demotes the un-copied entries back to the pool, frees every block, and
    the chain stays matchable for the next identical prompt."""
    eng, p_low, r0, r1 = _restore_setup()
    assert len(eng.spill) == 0  # admission popped every spilled payload
    assert eng.abort(r1)
    assert r1.state == RequestState.DONE and r1.finish_reason == "aborted"
    assert not eng._restore_q and not eng._restoring
    assert not r1.pending_restores
    assert eng.allocator.blocks_in_use == 0, "abort leaked blocks mid-restore"
    assert eng.stats()["restores_cancelled"] == 2
    assert len(eng.spill) == 2  # the two un-copied payloads demoted back
    # the demoted entries (and the one restored device block) still serve
    # the next identical prompt, token-identically
    r2 = eng.submit(p_low, max_new_tokens=4)
    eng.run_until_drained()
    assert r2.generated == r0.generated
    assert eng.allocator.blocks_in_use == 0
    assert eng.stats()["spill_hit_tokens"] > 0


# ---------------------------------------------------------------------------
# knob validation
# ---------------------------------------------------------------------------


def test_spill_knob_validation():
    cfg, params = _make("olmo-1b")
    with pytest.raises(ValueError, match="spill_dtype"):
        _engine(cfg, params, spill_dtype="fp4")
    with pytest.raises(ValueError, match="restore_budget"):
        _engine(cfg, params, restore_budget=0)
    with pytest.warns(RuntimeWarning, match="spill_bytes only applies"):
        dense = InferenceEngine(
            cfg, params, max_batch=2, max_seq=64, cache_kind="dense", spill_bytes=1 << 20
        )
    assert dense.spill is None
    with pytest.warns(RuntimeWarning, match="spill_bytes needs the prefix cache"):
        off = InferenceEngine(
            cfg, params, max_batch=2, max_seq=64, block_size=8,
            prefix_cache=False, spill_bytes=1 << 20,
        )
    assert off.spill is None
    hybrid_cfg, hybrid_params = _make("hymba-1.5b")
    with pytest.warns(RuntimeWarning):
        hyb = InferenceEngine(
            hybrid_cfg, hybrid_params, max_batch=2, max_seq=64, block_size=8,
            spill_bytes=1 << 20,
        )
    assert hyb.spill is None  # hybrid: no prefix cache, tier disabled
    with pytest.warns(RuntimeWarning, match="re-quantize"):
        q8 = InferenceEngine(
            cfg, params, max_batch=2, max_seq=64, block_size=8,
            cache_dtype=jnp.float32, quantize_kv=True,
            spill_bytes=1 << 20, spill_dtype="fp8",
        )
    assert q8.spill is not None and q8.spill.mode == "cache"
