"""End-to-end facility scenario: the paper's platform in one test.

A phase-1-like cluster hosts two tenants; a training job runs under the QoS
scheduler with periodic checkpoints; a node fails mid-run and the job resumes
bit-exactly; an inference tenant serves requests through the continuous-
batching engine; the DCIM ledger accounts energy under the PUE target.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import RunConfig, TrainConfig
from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.core import (
    Cluster,
    ClusterSpec,
    FaultTolerantRunner,
    IAM,
    Job,
    JobState,
    QoS,
    Role,
    Scheduler,
    TenantManager,
)
from repro.data import make_batch_fn
from repro.serving import InferenceEngine
from repro.train.step import init_train_state, make_train_step


def test_full_facility_scenario(tmp_path):
    # --- facility + tenancy -------------------------------------------------
    cluster = Cluster(ClusterSpec("phase1-mini", nodes_per_pod=6, num_pods=1))
    iam = IAM(clock=lambda: 0.0)
    admin = iam.federated_login("ops@bristol.ac.uk", "uob")
    iam.grant("ops@bristol.ac.uk", Role.INFRA_ADMIN)
    tenants = TenantManager(cluster, iam)
    tenants.create_tenant("research", quota_nodes=4, admin="alice@inst", token=admin)
    tenants.create_tenant("serving", quota_nodes=2, admin="bob@inst", token=admin)
    tenants.grow_tenant("research", 3, token=admin)
    tenants.grow_tenant("serving", 1, token=admin)
    assert tenants.check_isolation() == []

    # --- QoS scheduling -----------------------------------------------------
    sched = Scheduler(cluster)
    train_job = sched.submit(
        Job("llm-train", "research", QoS.TRAINING, chips=8, duration=30, checkpoint_interval=5)
    )
    sched.tick(1)
    assert train_job.state == JobState.RUNNING

    # --- real training under fault tolerance --------------------------------
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    run = RunConfig(arch="olmo-1b", train=TrainConfig(global_batch=4, seq_len=16))
    state = init_train_state(cfg, run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, run))
    runner = FaultTolerantRunner(
        step_fn=step,
        init_state=state,
        batch_fn=make_batch_fn(cfg, global_batch=4, seq_len=16),
        cluster=cluster,
        ckpt=CheckpointManager(tmp_path, keep=2, async_save=False),
        job_id="llm-train",
        checkpoint_every=4,
    )
    report = runner.run(10, failure_schedule={6: train_job.nodes[0]})
    assert report.failures == 1 and report.restores == 1
    assert max(report.losses) == 10
    assert np.isfinite(list(report.losses.values())).all()

    # --- serving tenant -----------------------------------------------------
    params = runner.state.params
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64)
    r1 = eng.submit([3, 1, 4], max_new_tokens=4)
    r2 = eng.submit([1, 5, 9], max_new_tokens=4, online=False)
    eng.run_until_drained()
    assert len(r1.generated) == 4 and len(r2.generated) == 4

    # --- sustainability accounting -------------------------------------------
    rep = runner.ledger.report()
    assert rep["effective_pue"] < 1.1
    assert rep["facility_kwh"] > 0
