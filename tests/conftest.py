import os
import sys

# tests import the library from src/ (works with or without PYTHONPATH=src)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
