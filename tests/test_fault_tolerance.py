"""Fault tolerance: node failure -> detection -> restore -> BIT-EXACT resume.

This is the executable core of the paper's flex-start guarantee: the run with
failures must converge to exactly the same state as the run without them
(the data pipeline is step-keyed, so replay is deterministic).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.config import ParallelConfig, RunConfig, TrainConfig
from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.core import Cluster, ClusterSpec, FaultTolerantRunner
from repro.data import make_batch_fn
from repro.train.step import init_train_state, make_train_step


def build(tmp_path, tag, arch="olmo-1b"):
    cfg = reduce_for_smoke(get_config(arch))
    run = RunConfig(arch=arch, train=TrainConfig(global_batch=4, seq_len=16))
    state = init_train_state(cfg, run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, run))
    batch_fn = make_batch_fn(cfg, global_batch=4, seq_len=16, seed=0)
    cluster = Cluster(ClusterSpec("t", nodes_per_pod=2, num_pods=1))
    cluster.allocate([0, 1], "train-job")
    for n in cluster.nodes.values():
        cluster.heartbeat(n.node_id, 0.0)
    ckpt = CheckpointManager(tmp_path / tag, keep=3, async_save=False)
    return FaultTolerantRunner(
        step_fn=step,
        init_state=state,
        batch_fn=batch_fn,
        cluster=cluster,
        ckpt=ckpt,
        checkpoint_every=5,
    )


def test_failure_recovery_is_bit_exact(tmp_path):
    clean = build(tmp_path, "clean")
    r1 = clean.run(12)
    faulty = build(tmp_path, "faulty")
    r2 = faulty.run(12, failure_schedule={7: 1})

    assert r2.failures == 1
    assert r2.restores == 1
    assert r2.rollback_steps > 0
    # the loss at every step index must match the clean run exactly
    for s, loss in r1.losses.items():
        assert s in r2.losses
        assert loss == r2.losses[s], f"step {s}: {loss} != {r2.losses[s]} (not bit-exact)"
    # final states identical
    for a, b in zip(jax.tree.leaves(clean.state), jax.tree.leaves(faulty.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multiple_failures_still_complete(tmp_path):
    runner = build(tmp_path, "multi")
    rep = runner.run(15, failure_schedule={4: 0, 9: 1, 13: 0})
    assert rep.failures == 3
    assert rep.restores == 3
    assert max(rep.losses) == 15


def test_heartbeat_detection_marks_failed(tmp_path):
    runner = build(tmp_path, "hb")
    cluster = runner.cluster
    cluster.heartbeat(0, 104.0)  # node 0 fresh
    cluster.heartbeat(1, 100.0)  # node 1 goes silent afterwards
    failed = cluster.sweep_heartbeats(105.0, suspect_after=0.5, fail_after=4.0)
    assert [n.node_id for n in failed] == [1]
    assert cluster.nodes[0].state.value in ("healthy", "suspect")


def test_energy_ledger_accumulates(tmp_path):
    runner = build(tmp_path, "energy")
    runner.run(6)
    rep = runner.ledger.report()
    assert rep["it_kwh"] > 0
    assert rep["effective_pue"] < 1.1  # the paper's headline PUE target
