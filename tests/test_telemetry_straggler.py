"""DCIM telemetry (energy/PUE/5MW envelope) + straggler mitigation."""

import pytest

from repro.core import EnergyLedger, StragglerDetector, effective_pue, mw_check


def test_pue_below_paper_target():
    assert effective_pue() < 1.1  # paper headline: PUE < 1.1


def test_5mw_envelope_phase2():
    """5,280 chips flat out must stay near the paper's 5 MW facility budget."""
    mw = mw_check(5280, utilization=1.0)
    assert 1.0 < mw < 5.0, f"phase-2 power model: {mw:.2f} MW"


def test_energy_ledger_per_job():
    led = EnergyLedger()
    led.record("job-a", chips=256, seconds=3600, utilization=0.5)
    led.record("job-b", chips=4, seconds=3600, utilization=0.9)
    rep = led.report()
    assert rep["jobs"]["job-a"] > rep["jobs"]["job-b"]
    assert rep["facility_kwh"] > rep["it_kwh"]  # PUE overhead applied
    assert rep["scope2_kgco2"] > 0


def test_straggler_detection_ladder():
    det = StragglerDetector(min_samples=3)
    for step in range(6):
        for node in range(8):
            t = 1.0
            if node == 6:
                t = 1.8  # slow blade -> drain
            if node == 7:
                t = 4.0  # broken blade -> evict
            det.observe(node, t)
    actions = det.stragglers()
    assert actions.get(6) == "drain"
    assert actions.get(7) == "evict"
    assert 5 not in actions
    assert det.step_slowdown() > 3.0  # sync step gated by the worst node


def test_straggler_needs_samples():
    det = StragglerDetector(min_samples=3)
    det.observe(0, 1.0)
    det.observe(1, 99.0)
    assert det.stragglers() == {}  # too few samples to judge
