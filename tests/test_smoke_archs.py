"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch is instantiated at a REDUCED config of the same family and
runs one forward + one train step on CPU, asserting output shapes and no
NaNs.  The FULL configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RunConfig, TrainConfig, ParallelConfig
from repro.config.model import reduce_for_smoke
from repro.configs import ASSIGNED, get_config, list_archs
from repro.models import forward, init_params
from repro.train.step import init_train_state, make_train_step

ALL = ASSIGNED + ["bert-large"]


def _batch(cfg, key, B=2, S=32):
    batch = {}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model))
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch["tokens"] = tokens
        batch["labels"] = jnp.roll(tokens, -1, axis=1)
    if cfg.family == "vlm":
        batch["vision_tokens"] = jax.random.normal(key, (B, cfg.vision.num_image_tokens, cfg.d_model))
    return batch


def test_registry_covers_assignment():
    for arch in ASSIGNED:
        assert arch in list_archs()
    assert len(ASSIGNED) == 10


@pytest.mark.parametrize("arch", ALL)
def test_forward_smoke(arch):
    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, jnp.float32)
    batch = _batch(cfg, key)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b, remat="full"))(params, batch)
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not np.isnan(np.asarray(logits)).any()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL)
def test_train_step_smoke(arch):
    cfg = reduce_for_smoke(get_config(arch))
    run = RunConfig(
        arch=arch,
        train=TrainConfig(global_batch=4, seq_len=32),
        parallel=ParallelConfig(num_microbatches=2, remat="full"),
    )
    state = init_train_state(cfg, run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, run))
    batch = _batch(cfg, jax.random.PRNGKey(1), B=4, S=32)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """The FULL config transcribes the assignment table (no allocation)."""
    cfg = get_config(arch)
    table = {
        "rwkv6-7b": (32, 4096, 14336, 65536),
        "olmo-1b": (16, 2048, 8192, 50304),
        "mistral-nemo-12b": (40, 5120, 14336, 131072),
        "stablelm-12b": (40, 5120, 13824, 100352),
        "gemma-7b": (28, 3072, 24576, 256000),
        "hubert-xlarge": (48, 1280, 5120, 504),
        "arctic-480b": (35, 7168, 4864, 32000),
        "qwen3-moe-235b-a22b": (94, 4096, 1536, 151936),
        "hymba-1.5b": (32, 1600, 5504, 32001),
        "llama-3.2-vision-90b": (100, 8192, 28672, 128256),
    }
    L, d, ff, v = table[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    if arch == "arctic-480b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 2 and cfg.moe.dense_residual
        assert 450e9 < cfg.param_count() < 510e9
    if arch == "qwen3-moe-235b-a22b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 8
        assert 220e9 < cfg.param_count() < 250e9
        assert 15e9 < cfg.active_param_count() < 30e9
    if arch == "hymba-1.5b":
        assert cfg.ssm.state_size == 16
