"""Elastic scaling: resize plans + live resharding + training continuity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.config import RunConfig, TrainConfig
from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.core import make_elastic_mesh, plan_resize, reshard_state, resize_batch
from repro.data import make_batch_fn
from repro.train.step import init_train_state, make_train_step


def test_plan_shrink_keeps_per_chip_batch():
    plan = plan_resize(old_chips=256, new_chips=192, model_parallel=16, global_batch=256)
    assert plan.model == 16
    assert plan.data == 12
    # per-data-shard batch was 16 -> new global = 12 * 16
    assert plan.new_global_batch == 192


def test_plan_rejects_too_small():
    with pytest.raises(ValueError):
        plan_resize(old_chips=256, new_chips=8, model_parallel=16, global_batch=256)


@settings(max_examples=100, deadline=None)
@given(
    st.integers(1, 8),  # model parallel (power-ish)
    st.integers(16, 512),
    st.integers(16, 512),
)
def test_plan_properties(mp, old, new):
    if new < mp:
        return
    plan = plan_resize(old_chips=old, new_chips=new, model_parallel=mp, global_batch=64)
    assert plan.data * plan.model <= new
    assert plan.new_global_batch >= 1
    assert plan.model == mp


def test_elastic_resume_continues_training():
    """Shrink mid-run: resharded state keeps training (loss finite, decreasing
    over a few steps) with the smaller batch."""
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    run = RunConfig(arch="olmo-1b", train=TrainConfig(global_batch=8, seq_len=16))
    state = init_train_state(cfg, run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, run))
    batch_fn = make_batch_fn(cfg, global_batch=8, seq_len=16)
    for s in range(3):
        state, m = step(state, batch_fn(s))
    loss_before = float(m["loss"])

    # "lose" half the fleet: 8 -> 4 global batch
    plan = plan_resize(old_chips=8, new_chips=4, model_parallel=1, global_batch=8)
    mesh = make_elastic_mesh(plan)
    from jax.sharding import NamedSharding, PartitionSpec as P

    sharding = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    state = reshard_state(state, sharding)
    losses = []
    for s in range(3, 8):
        small = resize_batch(batch_fn(s), plan)
        assert small["tokens"].shape[0] == plan.new_global_batch
        state, m = step(state, small) if plan.new_global_batch == 8 else jax.jit(
            make_train_step(cfg, run)
        )(state, small)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < loss_before + 1.0  # training continues sanely
