"""Optimizer, schedules, gradient compression, data pipeline properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.data import make_batch_fn, pack_sequences
from repro.data.packing import packing_efficiency
from repro.optim import adamw_init, adamw_update, make_schedule
from repro.optim.adamw import clip_by_global_norm, global_norm
from repro.parallel import compress_gradients, init_compression_state


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}  # d/dw (w^2)
        params, state, _ = adamw_update(params, grads, state, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_layer_scan_equivalent():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (4, 8, 8))}
    grads = {"w": jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 8))}
    s0 = adamw_init(params)
    p1, s1, _ = adamw_update(params, grads, s0, lr=1e-2, layer_scan=False)
    p2, s2, _ = adamw_update(params, grads, s0, lr=1e-2, layer_scan=True)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s1.m["w"]), np.asarray(s2.m["w"]), rtol=1e-6)


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_schedule_warmup_cosine():
    sched = make_schedule("cosine", base_lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1e-3) < 1e-9
    assert float(sched(100)) < float(sched(50)) < float(sched(10))


@pytest.mark.parametrize("method", ["bf16", "int8"])
def test_compression_error_feedback_unbiased(method):
    """With error feedback, the SUM of compressed grads tracks the true sum."""
    key = jax.random.PRNGKey(0)
    true = {"w": jax.random.normal(key, (256,))}
    residual = init_compression_state(true, method)
    total_c = jnp.zeros((256,))
    for i in range(20):
        g, residual = compress_gradients(true, residual, method)
        total_c = total_c + g["w"]
    rel = float(jnp.linalg.norm(total_c - 20 * true["w"]) / jnp.linalg.norm(20 * true["w"]))
    assert rel < 0.05, f"{method}: error feedback drifted {rel:.3f}"


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_synthetic_batches_deterministic():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    fn = make_batch_fn(cfg, global_batch=4, seq_len=16, seed=7)
    a, b = fn(3), fn(3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = fn(4)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.integers(1, 60), min_size=1, max_size=20),
    st.sampled_from([64, 128]),
)
def test_packing_conserves_tokens(doc_lens, seq_len):
    docs = [np.arange(1, n + 1, dtype=np.int32) for n in doc_lens]
    tokens, positions, segments = pack_sequences(docs, seq_len)
    # property 1: every document token appears exactly once
    assert int((segments > 0).sum()) == sum(min(n, seq_len) for n in doc_lens)
    # property 2: positions reset at each document start
    for row in range(tokens.shape[0]):
        segs = segments[row]
        pos = positions[row]
        for j in range(seq_len):
            if segs[j] > 0 and (j == 0 or segs[j] != segs[j - 1]):
                assert pos[j] == 0  # new doc -> position resets
    # property 3: efficiency in (0, 1]
    assert 0 < packing_efficiency(segments) <= 1.0
