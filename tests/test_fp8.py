"""FP8 quantized training: round-trip bounds, delayed scaling, GEMM kernel
vs oracle, gradient fidelity, and end-to-end train-step parity vs bf16."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ParallelConfig, RunConfig, TrainConfig
from repro.config.model import reduce_for_smoke
from repro.config.run import PrecisionConfig
from repro.configs import get_config
from repro.fp8 import (
    E4M3,
    E5M2,
    FP8_MAX,
    compute_scale,
    dequantize,
    fp8_dot,
    fp8_gemm,
    fp8_gemm_ref,
    fp8_sites,
    fp8_supported,
    init_fp8_state,
    quantize,
    scale_keys,
    tensor_amax,
    update_fp8_state,
)
from repro.train.step import init_train_state, make_train_step


def _amax_scale(x, dtype):
    return compute_scale(tensor_amax(x), dtype)


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,mantissa_bits", [(E4M3, 3), (E5M2, 2)])
def test_round_trip_error_bound(dtype, mantissa_bits):
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,), jnp.float32)
    s = _amax_scale(x, dtype)
    xd = dequantize(quantize(x, s, dtype), s)
    # relative-to-amax error: one rounding step at the top binade is
    # amax * 2^-(mantissa+1); everything below rounds at least as finely
    amax = float(jnp.max(jnp.abs(x)))
    bound = amax * 2.0 ** -(mantissa_bits + 1) * 1.001
    assert float(jnp.max(jnp.abs(x - xd))) <= bound


def test_e4m3_beats_e5m2_precision():
    x = jax.random.normal(jax.random.PRNGKey(1), (4096,), jnp.float32)
    errs = {}
    for dt in (E4M3, E5M2):
        s = _amax_scale(x, dt)
        errs[dt] = float(jnp.mean(jnp.abs(x - dequantize(quantize(x, s, dt), s))))
    assert errs[E4M3] < errs[E5M2]


def test_exact_values_round_trip_exactly():
    x = jnp.array([1.0, 1.5, -2.0, 0.25, 448.0, 0.0], jnp.float32)
    one = jnp.float32(1.0)
    np.testing.assert_array_equal(np.asarray(dequantize(quantize(x, one, E4M3), one)), np.asarray(x))


def test_saturating_cast_no_nan():
    # jax's astype(f8) maps overflow to NaN; our quantize must clip instead
    x = jnp.array([1e6, -1e6, 700.0], jnp.float32)
    q = quantize(x, jnp.float32(1.0), E4M3)
    d = np.asarray(dequantize(q, jnp.float32(1.0)))
    assert np.all(np.isfinite(d))
    np.testing.assert_array_equal(d, [448.0, -448.0, 448.0])


# ---------------------------------------------------------------------------
# delayed scaling
# ---------------------------------------------------------------------------


def test_delayed_scaling_window_semantics():
    st = init_fp8_state(["s/x"], window=4)
    assert float(st.scale["s/x"][0]) == 1.0  # first step quantizes at unit scale
    for a in [1.0, 2.0, 3.0, 4.0, 5.0]:
        st = update_fp8_state(st, {"s/x": jnp.float32(a)}, dtype=E4M3)
    np.testing.assert_allclose(np.asarray(st.amax_history["s/x"])[0], [5.0, 4.0, 3.0, 2.0])
    np.testing.assert_allclose(float(st.scale["s/x"][0]), FP8_MAX[E4M3] / 5.0, rtol=1e-6)
    assert int(st.step) == 5
    # the old peak ages out of the window: scale recovers toward the recent amax
    for _ in range(4):
        st = update_fp8_state(st, {"s/x": jnp.float32(0.5)}, dtype=E4M3)
    np.testing.assert_allclose(float(st.scale["s/x"][0]), FP8_MAX[E4M3] / 0.5, rtol=1e-6)


def test_delayed_scaling_is_per_layer():
    # per-tensor scaling: each layer's row rolls/scales independently
    st = init_fp8_state(["s/x"], window=2, num_layers=3)
    st = update_fp8_state(st, {"s/x": jnp.array([1.0, 10.0, 100.0], jnp.float32)}, dtype=E4M3)
    np.testing.assert_allclose(
        np.asarray(st.scale["s/x"]), FP8_MAX[E4M3] / np.array([1.0, 10.0, 100.0]), rtol=1e-6
    )


def test_margin_halves_scale_per_unit():
    s0 = compute_scale(jnp.float32(2.0), E4M3, margin=0.0)
    s1 = compute_scale(jnp.float32(2.0), E4M3, margin=1.0)
    np.testing.assert_allclose(float(s0), 2.0 * float(s1), rtol=1e-6)


# ---------------------------------------------------------------------------
# GEMM: Pallas kernel vs jnp oracle, and FP8 path vs exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 512), (100, 70, 36)])
def test_pallas_gemm_matches_ref(shape):
    M, K, N = shape
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    sa, sb = _amax_scale(a, E4M3), _amax_scale(b, E4M3)
    qa, qb = quantize(a, sa, E4M3), quantize(b, sb, E4M3)
    ref = fp8_gemm_ref(qa, qb, sa, sb)
    pal = fp8_gemm(qa, qb, sa, sb)  # interpret mode on CPU
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_fp8_gemm_within_quantization_tolerance_of_exact():
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 128), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 192), jnp.float32)
    sa, sb = _amax_scale(a, E4M3), _amax_scale(b, E4M3)
    out = fp8_gemm(quantize(a, sa, E4M3), quantize(b, sb, E4M3), sa, sb)
    exact = a @ b
    rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
    assert rel < 0.06  # per-element e4m3 noise averages to a few % in the dot


def test_fp8_dot_gradients_close_to_exact():
    from repro.fp8.gemm_ref import fp8_gemm_ref as gemm

    x = jax.random.normal(jax.random.PRNGKey(0), (64, 48), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (48, 32), jnp.float32)
    sx, sw = _amax_scale(x, E4M3), _amax_scale(w, E4M3)

    gx, gw = jax.grad(lambda x, w: jnp.sum(fp8_dot(x, w, sx, sw, E4M3, gemm) ** 2), (0, 1))(x, w)
    egx, egw = jax.grad(lambda x, w: jnp.sum((x @ w) ** 2), (0, 1))(x, w)
    for g, eg in ((gx, egx), (gw, egw)):
        rel = float(jnp.linalg.norm(g - eg) / jnp.linalg.norm(eg))
        assert rel < 0.15  # e5m2 backward quantization noise


# ---------------------------------------------------------------------------
# policy / sites
# ---------------------------------------------------------------------------


def test_policy_sites():
    dense = reduce_for_smoke(get_config("olmo-1b"))
    assert fp8_supported(dense)
    sites = fp8_sites(dense)
    assert {"attn_q", "attn_k", "attn_v", "attn_o", "ffn_up", "ffn_gate", "ffn_down"} == set(sites)
    assert len(scale_keys(dense)) == 2 * len(sites)
    # routed-expert MoE without dense residual: attention only
    moe = reduce_for_smoke(get_config("qwen3-moe-235b-a22b"))
    assert set(fp8_sites(moe)) == {"attn_q", "attn_k", "attn_v", "attn_o"}
    # ssm/vlm: no fp8 path
    assert not fp8_supported(reduce_for_smoke(get_config("rwkv6-7b")))
    assert not fp8_supported(reduce_for_smoke(get_config("llama-3.2-vision-90b")))


# ---------------------------------------------------------------------------
# end-to-end train step
# ---------------------------------------------------------------------------


def _batch(cfg, key, B=4, S=32):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}


def _run_cfg(arch, fp8, nmb=1):
    return RunConfig(
        arch=arch,
        train=TrainConfig(global_batch=4, seq_len=32),
        parallel=ParallelConfig(remat="full", num_microbatches=nmb),
        precision=PrecisionConfig(fp8=fp8),
    )


def test_train_step_fp8_loss_parity_and_amax_update():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    losses = {}
    for fp8 in (False, True):
        run = _run_cfg("olmo-1b", fp8)
        state = init_train_state(cfg, run, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, run))
        ls = []
        for s in range(3):
            state, m = step(state, _batch(cfg, jax.random.PRNGKey(s)))
            ls.append(float(m["loss"]))
        losses[fp8] = ls
        if fp8:
            assert int(state.fp8.step) == 3
            # every site x layer observed a nonzero amax each step (newest
            # first); history leaves are (num_layers, window)
            for k, h in state.fp8.amax_history.items():
                assert np.asarray(h).shape == (cfg.num_layers, 16), k
                assert np.all(np.asarray(h)[:, :3] > 0), k
            # scales actually moved off the init value
            assert any(
                np.any(np.abs(np.asarray(s) - 1.0) > 1e-3) for s in state.fp8.scale.values()
            )
    for a, b in zip(losses[False], losses[True]):
        assert np.isfinite(b)
        assert abs(a - b) / abs(a) < 0.02  # quantization-level deviation only

    # bf16 runs carry no fp8 state
    run = _run_cfg("olmo-1b", False)
    assert init_train_state(cfg, run, jax.random.PRNGKey(0)).fp8 is None


def test_train_step_fp8_microbatched():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    run = _run_cfg("olmo-1b", True, nmb=2)
    state = init_train_state(cfg, run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, run))
    state, m = step(state, _batch(cfg, jax.random.PRNGKey(0)))
    assert np.isfinite(float(m["loss"]))
    assert int(state.fp8.step) == 1
    assert all(np.all(np.asarray(h)[:, 0] > 0) for h in state.fp8.amax_history.values())


def test_train_step_fp8_unsupported_family_falls_back():
    cfg = reduce_for_smoke(get_config("rwkv6-7b"))
    run = _run_cfg("rwkv6-7b", True)
    state = init_train_state(cfg, run, jax.random.PRNGKey(0))
    assert state.fp8 is None
    step = jax.jit(make_train_step(cfg, run))
    state, m = step(state, _batch(cfg, jax.random.PRNGKey(0)))
    assert np.isfinite(float(m["loss"]))
