"""Prefix-cached paged serving: refcounted allocator + LRU eviction
invariants, prefix-index matching (full blocks, partial-tail copy-on-write),
chunked-prefill kernel vs oracle, token equivalence of the cached + chunked
engine against the uncached/unchunked baselines, and the scheduling
satellites (batched sampling, auto-defrag, queue discipline)."""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.kernels import paged_prefill_attention
from repro.kernels.paged_attention_ref import paged_prefill_attention_ref
from repro.models import forward, init_params
from repro.serving import (
    BlockAllocator,
    InferenceEngine,
    PrefixIndex,
    RequestState,
    binary_chunks,
    sample_token,
    sample_tokens,
)


# ---------------------------------------------------------------------------
# allocator: refcounts, cached pool, eviction
# ---------------------------------------------------------------------------


def test_refcount_shared_free():
    a = BlockAllocator(9)
    blocks = a.alloc(3)
    for b in blocks:
        a.incref(b)  # second sharer
    a.free(blocks)  # first sharer drops out
    assert a.blocks_in_use == 3, "shared blocks must survive one sharer's free"
    assert a.num_free == 5
    a.free(blocks)  # last sharer
    assert a.blocks_in_use == 0 and a.num_free == 8
    with pytest.raises(ValueError):
        a.free(blocks)  # double free of dead blocks
    with pytest.raises(ValueError):
        a.incref(blocks[0])  # incref on a dead block


def test_cached_pool_counts_as_free_and_reuses():
    a = BlockAllocator(5)
    blocks = a.alloc(4)
    a.free_cached(blocks)
    assert a.blocks_in_use == 0
    assert a.num_cached == 4
    assert a.num_free == 4, "cached blocks are evictable, hence free for gating"
    a.reuse_cached(blocks[1])  # prefix hit revives without eviction
    assert a.refcount(blocks[1]) == 1 and a.num_cached == 3
    with pytest.raises(ValueError):
        a.reuse_cached(blocks[1])  # no longer cached


def test_eviction_is_lru_and_notifies():
    evicted = []
    a = BlockAllocator(5, on_evict=evicted.append)
    blocks = a.alloc(4)
    a.free_cached(blocks[:2])  # oldest
    a.free_cached(blocks[2:])  # newest
    got = a.alloc(3)  # free list is empty -> evicts 3 oldest cached blocks
    assert evicted == blocks[:3], "eviction must be oldest-first"
    assert set(got) == set(blocks[:3])
    assert a.evictions == 3 and a.num_cached == 1


def test_fragmentation_defrag_boundary_cases():
    a = BlockAllocator(5)
    assert a.fragmentation() == 0.0  # pristine free list
    blocks = a.alloc(4)
    assert a.fragmentation() == 0.0 and a.defrag() == 0.0  # empty free list
    a.free(blocks[:1])
    assert a.fragmentation() == 0.0  # single free block is trivially contiguous
    a.free(blocks[2:3])
    assert a.fragmentation() > 0.0  # {b0, b2}: a hole
    a.free(blocks[1:2])
    a.defrag()
    assert a.fragmentation() == 0.0
    # cached blocks never enter the free-list fragmentation accounting
    a2 = BlockAllocator(5)
    bs = a2.alloc(4)
    a2.free_cached(bs)
    assert a2.fragmentation() == 0.0


def test_eviction_under_pressure_keeps_live_blocks():
    a = BlockAllocator(6, on_evict=lambda b: None)
    live = a.alloc(3)
    cached = a.alloc(2)
    a.free_cached(cached)
    got = a.alloc(2)  # must evict the cached pair, never touch live
    assert set(got) == set(cached)
    assert all(a.refcount(b) == 1 for b in live)


# ---------------------------------------------------------------------------
# prefix index
# ---------------------------------------------------------------------------


def _index(num_blocks=17, bs=4):
    return PrefixIndex(BlockAllocator(num_blocks), bs)


def test_prefix_match_full_blocks_and_cap():
    idx = _index()
    prompt = list(range(10, 26))  # 16 tokens = 4 full blocks @ bs 4
    blocks = idx.allocator.alloc(4)
    idx.register(prompt, blocks, upto=16)
    assert len(idx) == 4
    # identical prompt: the cap must leave >= 1 token to prefill -> 3 blocks
    full, partial = idx.match(prompt)
    assert full == blocks[:3]
    assert partial is None or partial.block == blocks[3]
    # longer prompt with the same prefix: all 4 blocks match
    full, partial = idx.match(prompt + [99, 98])
    assert full == blocks
    assert partial is None
    # diverging second block: only the first matches
    other = prompt[:4] + [77, 77, 77, 77] + prompt[8:] + [1]
    full, _ = idx.match(other)
    assert full == blocks[:1]


def test_prefix_partial_tail_match():
    idx = _index()
    prompt = list(range(10, 22))  # 3 full blocks
    blocks = idx.allocator.alloc(3)
    idx.register(prompt, blocks, upto=12)
    probe = prompt[:8] + [prompt[8], prompt[9], 555, 556, 557]
    full, partial = idx.match(probe)
    assert full == blocks[:2]
    assert partial is not None and partial.block == blocks[2] and partial.tokens == 2


def test_prefix_eviction_unmaps():
    idx = _index(num_blocks=5, bs=4)
    prompt = list(range(8))
    blocks = idx.allocator.alloc(2)
    idx.register(prompt, blocks, upto=8)
    idx.release(blocks)  # refcount 0 -> LRU cached pool, still matchable
    assert idx.match(prompt + [9])[0] == blocks
    idx.allocator.alloc(4)  # forces eviction of both cached blocks
    assert idx.match(prompt + [9]) == ([], None), "evicted blocks must unmap"
    assert len(idx) == 0


def test_prefix_release_routes_indexed_blocks_to_cache():
    idx = _index()
    prompt = list(range(8))
    blocks = idx.allocator.alloc(3)  # 2 full prompt blocks + 1 generation block
    idx.register(prompt, blocks[:2], upto=8)
    idx.release(blocks)
    assert idx.allocator.num_cached == 2, "indexed blocks park in the LRU pool"
    assert idx.allocator.blocks_in_use == 0  # unindexed block freed eagerly


def test_binary_chunks():
    assert binary_chunks(52) == [32, 16, 4]
    assert binary_chunks(1) == [1]
    assert binary_chunks(8) == [8]
    for n in range(1, 200):
        parts = binary_chunks(n)
        assert sum(parts) == n
        assert parts == sorted(parts, reverse=True)
        assert all(p & (p - 1) == 0 for p in parts)


# ---------------------------------------------------------------------------
# chunked-prefill Pallas kernel vs jnp oracle
# ---------------------------------------------------------------------------

CHUNK_KERNEL_CASES = [
    # B, nb, bs, C, H, KV, hd, window, softcap, dtype
    (2, 4, 8, 5, 4, 2, 16, 0, 0.0, jnp.float32),
    (1, 3, 16, 8, 8, 2, 32, 0, 0.0, jnp.float32),
    (2, 4, 8, 6, 4, 4, 16, 10, 0.0, jnp.float32),  # sliding window
    (1, 2, 8, 3, 2, 1, 64, 0, 30.0, jnp.float32),  # MQA + softcap
    (2, 4, 8, 4, 4, 2, 16, 0, 0.0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", CHUNK_KERNEL_CASES)
def test_chunked_prefill_kernel_matches_oracle(case):
    B, nb, bs, C, H, KV, hd, win, cap, dt = case
    N = 1 + B * nb
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, C, H, hd), dt)
    kp = jax.random.normal(ks[1], (N, bs, KV, hd), dt)
    vp = jax.random.normal(ks[2], (N, bs, KV, hd), dt)
    perm = jax.random.permutation(jax.random.PRNGKey(7), N - 1) + 1
    tbl = perm[: B * nb].reshape(B, nb).astype(jnp.int32)
    start = jnp.array([(5 * b + 2) % (nb * bs - C) for b in range(B)], jnp.int32)
    out = paged_prefill_attention(q, kp, vp, tbl, start, softcap=cap, window=win)
    ref = paged_prefill_attention_ref(q, kp, vp, tbl, start, softcap=cap, window=win)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < tol, f"{case}: err={err}"


# ---------------------------------------------------------------------------
# engine: cached + chunked == uncached/unchunked (greedy token equivalence)
# ---------------------------------------------------------------------------


def _make(arch, window=0):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    if window:
        cfg = cfg.replace(sliding_window=window)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


EQUIV_CASES = [
    ("olmo-1b", 0, "xla"),
    ("olmo-1b", 0, "pallas"),
    ("olmo-1b", 8, "xla"),  # sliding-window arch
    ("qwen3-moe-235b-a22b", 0, "xla"),
    ("hymba-1.5b", 0, "xla"),  # hybrid: feature safely disabled internally
]


@pytest.mark.parametrize("arch,window,impl", EQUIV_CASES)
def test_cached_chunked_engine_matches_baselines(arch, window, impl):
    """Prefix caching + chunked prefill must reproduce the dense-cache
    engine (fully independent prefill/decode path) and the uncached paged
    engine token-for-token under greedy sampling, with real sharing (the
    requests run back-to-back, so later prompts hit the registered prefix).
    """
    cfg, params = _make(arch, window)
    sys_prompt = [7, 3, 9, 4, 11, 2, 6, 8, 13, 5, 10, 12, 14, 15, 16, 17]
    prompts = [sys_prompt + [30 + i] for i in range(3)] + [[5, 9, 12]]
    outs, stats = {}, {}
    variants = {
        "dense": dict(cache_kind="dense"),
        "uncached": dict(prefix_cache=False),
        "cached": dict(prefix_cache=True),
        "cached_budget": dict(prefix_cache=True, prefill_budget=4),
    }
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for label, kw in variants.items():
            eng = InferenceEngine(
                cfg, params, max_batch=2, max_seq=64, block_size=8,
                cache_dtype=jnp.float32, attn_impl=impl, **kw,
            )
            gen = []
            for p in prompts:  # sequential: sharing kicks in from request 2
                r = eng.submit(p, max_new_tokens=5)
                eng.run_until_drained()
                gen.append(r.generated)
            outs[label] = gen
            stats[label] = eng.stats()
    assert outs["cached"] == outs["dense"], f"{arch}: cached diverged from dense"
    assert outs["cached_budget"] == outs["dense"]
    assert outs["uncached"] == outs["dense"]
    if arch != "hymba-1.5b":  # hybrid can't share (blocking prefill path)
        assert stats["cached"]["prefix_hit_tokens"] >= 2 * 16, stats["cached"]
        saved = stats["uncached"]["prefill_tokens"] - stats["cached"]["prefill_tokens"]
        assert saved == stats["cached"]["prefix_hit_tokens"]


def test_partial_tail_copy_on_write_engine():
    cfg, params = _make("olmo-1b")
    sys24 = list(range(2, 26))  # 3 full blocks @ bs 8
    p1 = sys24 + [30]
    p2 = sys24[:20] + [99, 98, 97, 96]  # full blocks 0-1 + 4 tokens of block 2
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64, block_size=8,
                          cache_dtype=jnp.float32, prefix_cache=True)
    eng.submit(p1, max_new_tokens=4)
    eng.run_until_drained()
    r2 = eng.submit(p2, max_new_tokens=4)
    eng.run_until_drained()
    s = eng.stats()
    assert s["prefix_partial_hits"] == 1
    assert r2.prefix_hit_tokens == 20  # 16 full + 4 copied-on-write
    ref = InferenceEngine(cfg, params, max_batch=1, max_seq=64, block_size=8,
                          cache_dtype=jnp.float32, prefix_cache=False)
    q2 = ref.submit(p2, max_new_tokens=4)
    ref.run_until_drained()
    assert r2.generated == q2.generated, "COW hit changed greedy tokens"


def test_engine_eviction_under_pressure():
    """A pool too small to cache every finished prompt must evict LRU
    entries on demand — and keep serving correctly."""
    cfg, params = _make("olmo-1b")
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64, block_size=8,
                          num_blocks=7, cache_dtype=jnp.float32, prefix_cache=True)
    for i in range(4):
        eng.submit([50 + i] + list(range(2, 18)) + [60 + i] * 7, max_new_tokens=4)
        eng.run_until_drained()
    s = eng.stats()
    assert s["requests_done"] == 4
    assert s["evictions"] > 0
    assert s["alloc_blocks_in_use"] == 0
    assert s["alloc_num_cached"] + len(eng.allocator._free) == eng.allocator.capacity


def test_prefill_budget_bounds_chunk_sizes():
    """With prefill_budget=B, no single step may process more than B prompt
    tokens, and the jitted chunk trace count stays O(log)."""
    cfg, params = _make("olmo-1b")
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=128, block_size=8,
                          cache_dtype=jnp.float32, prefill_budget=8)
    r = eng.submit(list(range(2, 55)), max_new_tokens=2)  # 53-token prompt
    seen = []
    while r.state != RequestState.DONE:
        before = eng.prefill_tokens
        eng.step()
        seen.append(eng.prefill_tokens - before)
    assert max(seen) <= 8, seen
    assert eng._chunk_step._cache_size() <= 4  # chunks of 8, 4, 2, 1 at most
    assert len(r.generated) == 2


def test_hybrid_prefix_cache_warns_and_disables():
    cfg, params = _make("hymba-1.5b")
    with pytest.warns(RuntimeWarning, match="prefix_cache"):
        eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64,
                              prefix_cache=True)
    assert eng.prefix is None
    with pytest.warns(RuntimeWarning, match="prefill_budget"):
        InferenceEngine(cfg, params, max_batch=1, max_seq=64, prefill_budget=8)
    r = eng.submit([5, 9, 12], max_new_tokens=3)
    eng.run_until_drained()
    assert len(r.generated) == 3


# ---------------------------------------------------------------------------
# satellites: batched sampling, queue discipline, auto-defrag
# ---------------------------------------------------------------------------


def test_batched_sampler_greedy_matches_scalar():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (5, 64))
    out = sample_tokens(logits, jnp.zeros(5), jnp.zeros(5, jnp.int32), key)
    assert out.shape == (5,)
    for b in range(5):
        assert int(out[b]) == int(sample_token(logits[b], 0.0, key))


def test_batched_sampler_top_k_one_is_greedy():
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (4, 32))
    out = sample_tokens(logits, jnp.full(4, 0.9), jnp.ones(4, jnp.int32), key)
    assert np.array_equal(np.asarray(out), np.asarray(jnp.argmax(logits, -1)))


def test_batched_sampler_respects_top_k_support():
    key = jax.random.PRNGKey(4)
    logits = jax.random.normal(key, (3, 32))
    ks = jnp.array([2, 4, 0], jnp.int32)
    for seed in range(8):
        out = np.asarray(sample_tokens(logits, jnp.ones(3), ks, jax.random.PRNGKey(seed)))
        for b, k in enumerate([2, 4, 32]):
            topk = set(np.argsort(np.asarray(logits[b]))[-k:].tolist())
            assert out[b] in topk


def test_queue_admission_order_unchanged():
    """Priority-aware insert must reproduce the old sort-by-(offline,
    submit_t) admission order exactly."""
    cfg, params = _make("olmo-1b")
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64, cache_dtype=jnp.float32)
    pattern = [False, True, False, True, True, False]
    reqs = [eng.submit([10 + i, 2], max_new_tokens=1, online=on)
            for i, on in enumerate(pattern)]
    expected = [r.req_id for r in sorted(reqs, key=lambda r: (not r.online, r.submit_t))]
    assert [r.req_id for r in eng.queue] == expected
    eng.run_until_drained()
    admitted = [r.req_id for r in sorted(eng.done, key=lambda r: r.first_token_t)]
    assert admitted == expected, "admission order drifted from the sort baseline"


def test_auto_defrag_triggers_and_counts():
    cfg, params = _make("olmo-1b")
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64, block_size=8,
                          num_blocks=17, cache_dtype=jnp.float32,
                          prefix_cache=False, defrag_threshold=0.5)
    blocks = eng.allocator.alloc(16)
    eng.allocator.free([b for b in blocks if b % 2 == 0])  # scattered frees
    assert eng.allocator.fragmentation() > 0.5
    eng.step()  # no work, but the post-step check must fire
    assert eng.stats()["defrag_triggers"] == 1
    # defrag sorts the free list: the next allocations come out id-contiguous
    freed = sorted(b for b in blocks if b % 2 == 0)
    assert eng.allocator.alloc(3) == freed[:3]
    eng.step()  # no new frees -> no re-trigger
    assert eng.stats()["defrag_triggers"] == 1


def test_shared_prefix_halves_prefill_tokens():
    """Acceptance: a shared-system-prompt mix must compute >= 2x fewer
    prefill tokens with the cache on (sequential arrivals)."""
    cfg, params = _make("olmo-1b")
    system = list(range(2, 34))  # 32 tokens = 4 full blocks @ bs 8
    prompts = [system + [40 + i, 50 + i] for i in range(6)]
    toks = {}
    for label, on in (("uncached", False), ("cached", True)):
        eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64, block_size=8,
                              cache_dtype=jnp.float32, prefix_cache=on)
        for p in prompts:
            eng.submit(p, max_new_tokens=3)
            eng.run_until_drained()
        toks[label] = eng.stats()["prefill_tokens"]
        if on:
            assert eng.stats()["prefix_hit_rate"] > 0.5
    assert toks["cached"] * 2 <= toks["uncached"], toks


# ---------------------------------------------------------------------------
# stranding hazard: an evicted parent leaves its cached children unreachable
# ---------------------------------------------------------------------------


def _spill_fetch(block):
    """Stand-in for the engine's device gather: 8 recognizable bytes."""
    return {"k": np.full((2,), float(block), np.float32)}


def test_evicted_parent_reclaims_stranded_children_drop_tier():
    """Matching always walks from the root, so dropping a chain's first
    block makes every descendant unmatchable.  The eviction cascade must
    unmap the whole subtree AND return still-cached descendants to the
    free list (``uncache``) — without it they sit in the LRU pool as
    unreachable-but-resident capacity until eviction churn gets to them."""
    a = BlockAllocator(17)  # 16 usable
    idx = PrefixIndex(a, 4)
    toks = list(range(40, 52))  # 12 tokens = 3 chained blocks
    blocks = a.alloc(3)
    idx.register(toks, blocks, 12)
    idx.release(blocks)  # whole chain parks in the LRU, oldest = blocks[0]
    assert all(a.is_cached(b) for b in blocks) and len(idx) == 3
    a.alloc(13)  # drain the free list; only the cached chain remains
    got = a.alloc(1)  # forces eviction of the LRU entry: the chain's ROOT
    assert got == [blocks[0]]
    assert a.evictions_dropped == 1 and a.evictions_spilled == 0
    # the cascade unmapped the children and repaired the stranding
    assert len(idx) == 0 and idx.stranded_dropped == 2
    assert a.stranded_reclaims == 2
    assert not a.is_cached(blocks[1]) and not a.is_cached(blocks[2])
    assert idx.match(toks + [99]) == ([], None)
    # the reclaimed blocks are allocatable immediately
    assert set(a.alloc(2)) == {blocks[1], blocks[2]}
    assert a.num_free == 0


def test_spilled_parent_keeps_children_matchable():
    """Under the spill tier the same eviction DEMOTES instead: the parent
    re-keys to a host-pool handle, descendants stay reachable through the
    mixed-tier chain walk, and nothing is stranded."""
    from repro.serving import SpillPool, is_spilled

    a = BlockAllocator(17)
    idx = PrefixIndex(a, 4)
    idx.attach_spill(SpillPool(1 << 10, mode="cache"), _spill_fetch)
    toks = list(range(60, 72))
    blocks = a.alloc(3)
    idx.register(toks, blocks, 12)
    idx.release(blocks)
    a.alloc(13)
    a.alloc(1)  # evicts the root -> spilled, not dropped
    assert a.evictions_spilled == 1 and a.evictions_dropped == 0
    assert a.stranded_reclaims == 0 and len(idx) == 3
    full, partial = idx.match(toks + [99])
    assert len(full) == 3 and partial is None
    assert is_spilled(full[0]) and full[1:] == blocks[1:]
    assert idx.stats()["spilled_entries"] == 1
    # the spilled payload is the evicted block's rows, bit-exact
    got = idx.spill.pop(full[0])
    assert float(np.asarray(got["k"])[0]) == float(blocks[0])


def test_spill_pool_budget_drop_cascades_through_index():
    """When the host pool's own byte budget forces a spilled parent out,
    the drop must cascade exactly like a device-tier drop: spilled
    descendants leave the pool, cached device descendants return to the
    free list, and a spill racing its ancestor's drop discards cleanly
    (the mid-``put`` reentrancy path)."""
    from repro.serving import SpillPool

    a = BlockAllocator(17)
    idx = PrefixIndex(a, 4)
    pool = SpillPool(16, mode="cache", staging_depth=0)  # room for TWO entries
    idx.attach_spill(pool, _spill_fetch)
    toks = list(range(80, 92))
    blocks = a.alloc(3)
    idx.register(toks, blocks, 12)
    idx.release(blocks)
    a.alloc(13)
    a.alloc(3)  # evict the whole chain, oldest first
    # b0 and b1 spilled; b2's put overflowed the pool, dropping b0 — whose
    # cascade discarded b1 from the pool and unmapped b2 mid-spill, so the
    # b2 spill was discarded rather than stranded in the pool
    assert a.evictions_spilled == 2 and a.evictions_dropped == 1
    assert len(idx) == 0 and len(pool) == 0 and pool.bytes_used == 0
    assert idx.stranded_dropped == 2
    assert idx.match(toks + [99]) == ([], None)
