"""Always-on serving: SLO scheduling, preemption correctness, async streaming.

The contract under test has three layers:

* **SchedulerCore policy** — ``slo`` orders the queue by
  ``(-priority, offline, deadline, arrival)`` and preempts strictly
  lower-priority running work under slot/block pressure; ``fcfs`` is the
  historical online-first arrival order and never preempts.
* **Preemption correctness** — a preempted-and-resumed request must produce
  *exactly* the tokens it would have produced with ample resources
  (greedy determinism), with and without the prefix cache recovering the
  committed context.
* **Async front-end** — ``AsyncEngine.submit_stream`` must deliver the same
  tokens as a closed-loop ``run_until_drained``, incrementally, and the
  stdlib HTTP/SSE front-end must round-trip them over a socket.
"""

import asyncio
import json

import jax
import jax.numpy as jnp
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    AsyncEngine,
    HttpFrontend,
    InferenceEngine,
    ManualClock,
    RequestState,
)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def ample_engine(cfg, params, **kw):
    """Reference engine: enough slots and blocks that nothing ever waits."""
    return InferenceEngine(
        cfg, params, max_batch=8, max_seq=64, cache_kind="paged", block_size=4, **kw
    )


# ---- submit() validation --------------------------------------------------


@pytest.mark.parametrize(
    "kw",
    [
        {"max_new_tokens": 0},
        {"max_new_tokens": -3},
        {"priority": -1},
        {"deadline_s": 0.0},
        {"deadline_s": -2.5},
    ],
)
def test_submit_rejects_bad_knobs(setup, kw):
    cfg, params = setup
    eng = ample_engine(cfg, params)
    with pytest.raises(ValueError):
        eng.submit([1, 2, 3], **kw)
    with pytest.raises(ValueError):
        eng.submit([], max_new_tokens=4)
    assert not eng.has_work, "rejected submissions must not enqueue"


# ---- queue ordering -------------------------------------------------------


def test_slo_queue_orders_priority_then_deadline(setup):
    cfg, params = setup
    clock = ManualClock()  # tick=0: every submit_t is 0, deadline_t = deadline_s
    eng = ample_engine(cfg, params, policy="slo", clock=clock)
    lo = eng.submit([1, 2], max_new_tokens=2)
    late = eng.submit([3, 4], max_new_tokens=2, priority=2, deadline_s=5.0)
    soon = eng.submit([5, 6], max_new_tokens=2, priority=2, deadline_s=1.0)
    hi = eng.submit([7, 8], max_new_tokens=2, priority=9)
    offline = eng.submit([9, 10], max_new_tokens=2, priority=9, online=False)
    order = [r.req_id for r in eng.queue]
    # priority desc, then online before offline, then earliest deadline
    assert order == [hi.req_id, offline.req_id, soon.req_id, late.req_id, lo.req_id]


def test_fcfs_queue_ignores_slo_knobs(setup):
    cfg, params = setup
    eng = ample_engine(cfg, params, policy="fcfs")
    first = eng.submit([1, 2], max_new_tokens=2)
    urgent = eng.submit([3, 4], max_new_tokens=2, priority=9, deadline_s=0.001)
    offline = eng.submit([5, 6], max_new_tokens=2, online=False, priority=9)
    order = [r.req_id for r in eng.queue]
    assert order == [first.req_id, urgent.req_id, offline.req_id]


def test_unknown_policy_rejected(setup):
    cfg, params = setup
    with pytest.raises(ValueError):
        ample_engine(cfg, params, policy="edf")


# ---- preemption correctness ----------------------------------------------


@pytest.mark.parametrize("prefix_cache", [True, False])
def test_preempted_request_is_token_identical(setup, prefix_cache):
    """Force a mid-decode preemption via slot pressure; the victim's final
    output must match an ample-resource greedy run exactly — the committed
    context is either recovered from the prefix cache or re-prefilled."""
    cfg, params = setup
    lo_prompt, hi_prompt = [5, 9, 12, 7, 3, 20], [21, 22, 23]

    ref = ample_engine(cfg, params)
    ref_lo = ref.submit(lo_prompt, max_new_tokens=10)
    ref_hi = ref.submit(hi_prompt, max_new_tokens=4)
    ref.run_until_drained()

    eng = InferenceEngine(
        cfg,
        params,
        max_batch=1,  # hi can only run by evicting lo
        max_seq=64,
        cache_kind="paged",
        block_size=4,
        prefix_cache=prefix_cache,
        prefill_budget=8,  # chunked path: preemption requires it
        policy="slo",
    )
    lo = eng.submit(lo_prompt, max_new_tokens=10)
    for _ in range(4):  # lo is mid-decode with committed generated tokens
        eng.step()
    assert lo.state == RequestState.ACTIVE and len(lo.generated) >= 2
    hi = eng.submit(hi_prompt, max_new_tokens=4, priority=2)
    eng.run_until_drained()

    assert lo.preemptions >= 1
    assert hi.preemptions == 0
    assert lo.generated == ref_lo.generated
    assert hi.generated == ref_hi.generated
    assert hi.done_t <= lo.done_t, "high priority must finish first"
    s = eng.stats()
    assert s["preemptions"] >= 1
    assert s["requests_preempted"] == 1
    if prefix_cache:
        assert lo.prefix_hit_tokens > 0, "resume must recover committed blocks"
    names = [e.name for e in eng.tracer.events_for(lo.req_id)]
    assert "preempt" in names and "resume" in names
    assert names.index("preempt") < names.index("resume")
    assert "engine_preemptions_total 1" in eng.metrics.render_text()


def test_preemption_under_block_pressure(setup):
    """Free slots but an exhausted block pool: admission of the
    high-priority request must evict a lower-priority one for its blocks."""
    cfg, params = setup
    ref = ample_engine(cfg, params)
    lo_prompt, hi_prompt = [4, 4, 8, 6, 2, 11, 13, 9], [30, 31]
    ref_lo = ref.submit(lo_prompt, max_new_tokens=8)
    ref_hi = ref.submit(hi_prompt, max_new_tokens=3)
    ref.run_until_drained()

    eng = InferenceEngine(
        cfg,
        params,
        max_batch=2,  # a slot is free; only blocks are scarce
        max_seq=64,
        cache_kind="paged",
        block_size=4,
        num_blocks=5,  # 1 null + 4 usable: lo holds all of them
        prefix_cache=False,
        prefill_budget=8,
        policy="slo",
    )
    lo = eng.submit(lo_prompt, max_new_tokens=8)
    for _ in range(3):
        eng.step()
    assert lo.state == RequestState.ACTIVE
    hi = eng.submit(hi_prompt, max_new_tokens=3, priority=5)
    eng.run_until_drained()
    assert lo.preemptions >= 1
    assert lo.generated == ref_lo.generated
    assert hi.generated == ref_hi.generated


def test_fcfs_never_preempts(setup):
    cfg, params = setup
    eng = InferenceEngine(
        cfg,
        params,
        max_batch=1,
        max_seq=64,
        cache_kind="paged",
        block_size=4,
        prefill_budget=8,
        policy="fcfs",
    )
    lo = eng.submit([5, 9, 12, 7], max_new_tokens=8)
    for _ in range(3):
        eng.step()
    hi = eng.submit([21, 22], max_new_tokens=3, priority=9)
    eng.run_until_drained()
    assert eng.stats()["preemptions"] == 0
    assert lo.preemptions == hi.preemptions == 0
    assert lo.done_t <= hi.done_t, "fcfs runs strictly in arrival order"


def test_deadline_violation_aborts_pre_first_token(setup):
    """An unservable TTFT deadline now *aborts* the request (finish_reason
    "deadline_exceeded") instead of letting it finish late — finishing a
    missed interactive request only delays everyone else."""
    cfg, params = setup
    clock = ManualClock(tick=0.05)  # every clock read advances 50ms
    eng = ample_engine(cfg, params, clock=clock)
    req = eng.submit([1, 2, 3], max_new_tokens=2, deadline_s=0.001)
    eng.run_until_drained()
    assert req.finish_reason == "deadline_exceeded" and req.generated == []
    assert eng.deadline_violations == 1
    s = eng.stats()
    assert s["deadline_violations"] == 1 and s["requests_aborted"] == 1


# ---- async engine ---------------------------------------------------------


def test_async_stream_matches_drained_tokens(setup):
    cfg, params = setup
    prompt = [5, 9, 12, 7]
    ref = ample_engine(cfg, params)
    ref_req = ref.submit(prompt, max_new_tokens=8)
    ref.run_until_drained()

    async def go():
        async with AsyncEngine(ample_engine(cfg, params)) as aeng:
            events = []
            async for ev in aeng.submit_stream(prompt, max_new_tokens=8):
                events.append(ev)
            return events

    events = asyncio.run(go())
    token_events = [e for e in events if e.kind == "token"]
    assert len(token_events) >= 2, "tokens must stream incrementally, not in one batch"
    streamed = [t for e in token_events for t in e.tokens]
    assert streamed == ref_req.generated
    finish = events[-1]
    assert finish.kind == "finish"
    assert finish.reason == "length" and finish.n_tokens == 8
    assert finish.ttft_s is not None


def test_async_concurrent_streams(setup):
    cfg, params = setup
    prompts = [[5, 9, 12], [7, 3], [20, 21, 22, 23]]
    ref = ample_engine(cfg, params)
    ref_reqs = [ref.submit(p, max_new_tokens=5) for p in prompts]
    ref.run_until_drained()

    async def go():
        async with AsyncEngine(ample_engine(cfg, params)) as aeng:
            outs = await asyncio.gather(
                *(aeng.generate(p, max_new_tokens=5) for p in prompts)
            )
            return [toks for _, toks in outs]

    outs = asyncio.run(go())
    for got, ref_req in zip(outs, ref_reqs):
        assert got == ref_req.generated


def test_async_submit_validation_raises_in_caller(setup):
    cfg, params = setup

    async def go():
        async with AsyncEngine(ample_engine(cfg, params)) as aeng:
            with pytest.raises(ValueError):
                async for _ in aeng.submit_stream([1, 2], max_new_tokens=-1):
                    pass  # pragma: no cover

    asyncio.run(go())


# ---- HTTP/SSE front-end ---------------------------------------------------


async def _http_roundtrip(port: int, payload: dict):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(payload).encode()
    writer.write(
        b"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
        + f"Content-Length: {len(body)}\r\n\r\n".encode()
        + body
    )
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    return raw.decode()


def _parse_sse(raw: str) -> list[tuple[str, dict]]:
    head, _, stream = raw.partition("\r\n\r\n")
    assert "200" in head.split("\r\n")[0], head
    frames = []
    for block in stream.strip().split("\n\n"):
        lines = dict(ln.split(": ", 1) for ln in block.split("\n") if ": " in ln)
        frames.append((lines["event"], json.loads(lines["data"])))
    return frames


def test_http_sse_roundtrip(setup):
    cfg, params = setup
    prompt = [5, 9, 12, 7]
    ref = ample_engine(cfg, params)
    ref_req = ref.submit(prompt, max_new_tokens=6)
    ref.run_until_drained()

    async def go():
        front = HttpFrontend(AsyncEngine(ample_engine(cfg, params)), port=0)
        await front.start()
        try:
            raw = await _http_roundtrip(
                front.port, {"prompt": prompt, "max_new_tokens": 6}
            )
            # metrics + stats endpoints over the same acceptor
            r, w = await asyncio.open_connection("127.0.0.1", front.port)
            w.write(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n")
            await w.drain()
            metrics = (await r.read()).decode()
            w.close()
            await w.wait_closed()
            bad = await _http_roundtrip(
                front.port, {"prompt": prompt, "max_new_tokens": -1}
            )
            return raw, metrics, bad
        finally:
            await front.stop()

    raw, metrics, bad = asyncio.run(go())
    frames = _parse_sse(raw)
    kinds = [k for k, _ in frames]
    assert kinds[-1] == "done" and all(k == "token" for k in kinds[:-1])
    streamed = [t for k, d in frames if k == "token" for t in d["tokens"]]
    assert streamed == ref_req.generated
    assert frames[-1][1]["reason"] == "length"
    assert "engine_tokens_out_total" in metrics
    assert "400" in bad.split("\r\n")[0]


# ---- HTTP hardening / lifecycle -------------------------------------------


async def _http_get(port: int, path: str) -> str:
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    await writer.drain()
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    return raw.decode()


def test_http_rejects_malformed_framing(setup):
    """A hostile client must get a structured 400, never crash the
    acceptor: bad Content-Length, oversized declared body, non-JSON body,
    non-object JSON body."""
    cfg, params = setup

    async def go():
        front = HttpFrontend(AsyncEngine(ample_engine(cfg, params)), port=0)
        await front.start()
        results = {}
        try:
            for name, req in {
                "bad_length": b"POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: nope\r\n\r\n",
                "huge_body": b"POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: 999999999\r\n\r\n",
                "not_json": b"POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: 4\r\n\r\nabcd",
                "not_object": b"POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: 7\r\n\r\n[1,2,3]",
                "bad_prompt": b'POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: 21\r\n\r\n{"prompt": "strings"}',
            }.items():
                reader, writer = await asyncio.open_connection("127.0.0.1", front.port)
                writer.write(req)
                await writer.drain()
                results[name] = (await reader.read()).decode()
                writer.close()
                await writer.wait_closed()
            # the acceptor survived all of it and still serves health
            results["healthz"] = await _http_get(front.port, "/healthz")
        finally:
            await front.stop()
        return results

    results = asyncio.run(go())
    for name in ("bad_length", "huge_body", "not_json", "not_object", "bad_prompt"):
        assert "400" in results[name].split("\r\n")[0], (name, results[name])
    assert "200" in results["healthz"].split("\r\n")[0]


def test_http_client_disconnect_aborts_request(setup):
    """A client that opens a stream and drops the socket mid-generation
    must not keep decoding into the void: the SSE write path tears the
    stream generator down, which cancels the engine request."""
    cfg, params = setup
    eng = ample_engine(cfg, params)

    async def go():
        aeng = AsyncEngine(eng)
        front = HttpFrontend(aeng, port=0)
        await front.start()
        try:
            reader, writer = await asyncio.open_connection("127.0.0.1", front.port)
            body = json.dumps({"prompt": [5, 9, 12, 7], "max_new_tokens": 48}).encode()
            writer.write(
                b"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                + f"Content-Length: {len(body)}\r\n\r\n".encode()
                + body
            )
            await writer.drain()
            await reader.readuntil(b"event: token")  # first token is out
            writer.close()  # client vanishes mid-stream
            await writer.wait_closed()
            await aeng.drain()
        finally:
            await front.stop()

    asyncio.run(go())
    s = eng.stats()
    assert s["requests_aborted"] == 1, "disconnected client's request must abort"
    assert s["requests_active"] == 0 and s["requests_prefilling"] == 0
    assert eng.allocator.num_free == eng.allocator.capacity


def test_healthz_reports_draining_and_replicas(setup):
    """/healthz is the readiness probe: 200 while accepting, 503 + reason
    while draining; under a router it carries per-replica states."""
    cfg, params = setup

    async def go():
        aeng = AsyncEngine(ample_engine(cfg, params))
        front = HttpFrontend(aeng, port=0)
        await front.start()
        try:
            ready = await _http_get(front.port, "/healthz")
            aeng._draining = True  # what shutdown() flips first
            draining = await _http_get(front.port, "/healthz")
        finally:
            await front.stop()
        return ready, draining

    ready, draining = asyncio.run(go())
    assert "200" in ready.split("\r\n")[0]
    assert json.loads(ready.partition("\r\n\r\n")[2])["ok"] is True
    assert "503" in draining.split("\r\n")[0]
    body = json.loads(draining.partition("\r\n\r\n")[2])
    assert body == {"ok": False, "draining": True}


def test_submission_during_drain_gets_503(setup):
    cfg, params = setup

    async def go():
        aeng = AsyncEngine(ample_engine(cfg, params))
        front = HttpFrontend(aeng, port=0)
        await front.start()
        port = front.port
        try:
            aeng._draining = True
            return await _http_roundtrip(port, {"prompt": [5, 9], "max_new_tokens": 2})
        finally:
            await front.stop()

    raw = asyncio.run(go())
    assert "503" in raw.split("\r\n")[0]
    assert "draining" in json.loads(raw.partition("\r\n\r\n")[2])["error"]


def test_serve_http_sigterm_drains_and_flushes(setup, tmp_path):
    """The full production shutdown path: serve_http installs a SIGTERM
    handler; the signal triggers a graceful drain (in-flight requests
    finish) and the metrics/trace artifacts flush before exit."""
    import os
    import signal as _signal

    from repro.serving.http import serve_http

    cfg, params = setup
    metrics_path = tmp_path / "metrics.json"
    trace_path = tmp_path / "trace.json"
    eng = ample_engine(cfg, params)

    async def go():
        ready = asyncio.Queue()

        async def client():
            front = await ready.get()
            raw = await _http_roundtrip(
                front.port, {"prompt": [5, 9, 12, 7], "max_new_tokens": 6}
            )
            os.kill(os.getpid(), _signal.SIGTERM)
            return raw

        server = serve_http(
            eng,
            port=0,
            metrics_json=str(metrics_path),
            trace_out=str(trace_path),
            drain_timeout_s=30.0,
            on_ready=ready.put_nowait,
        )
        _, raw = await asyncio.wait_for(asyncio.gather(server, client()), timeout=60)
        return raw

    raw = asyncio.run(go())
    frames = _parse_sse(raw)
    assert frames[-1][0] == "done" and frames[-1][1]["reason"] == "length"
    snap = json.loads(metrics_path.read_text())
    assert snap["counters"]["engine_tokens_out_total"]["value"] >= 6
    trace = json.loads(trace_path.read_text())
    assert any(e.get("name") == "finish" for e in trace["traceEvents"])
