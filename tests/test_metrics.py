"""Observability layer: metrics registry, request tracing, profiling, energy.

Covers the contracts docs/observability.md promises:

* histogram bucket/percentile math against a numpy oracle (error bounded by
  one factor-2 bucket width);
* exact, deterministic engine latencies under an injected ``ManualClock``
  (no sleeps);
* per-request event ordering (submit < admit < chunks < first_token <
  finish) and Chrome-trace JSON schema validity;
* ``profile=False`` adds **zero** device syncs to the hot path (counted by
  monkeypatching the engine's ``_block_until_ready`` seam);
* ``stats()`` is a defensive snapshot with division-by-zero-guarded rates;
* energy attribution: step joules split over the requests that did work;
* TP=1 vs TP=2 metrics parity for device-invariant counters (runs in the
  CI tp-serving lane; skips on a single-device jax).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serving.engine as engine_mod
from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    SCHEDULER_TRACK,
    EnergyBridge,
    Histogram,
    InferenceEngine,
    ManualClock,
    MetricsRegistry,
    Tracer,
    exponential_buckets,
    slot_track,
)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


# ---------------------------------------------------------------- registry
def test_exponential_buckets():
    assert exponential_buckets(1.0, 2.0, 3) == [1.0, 2.0, 4.0]
    with pytest.raises(ValueError):
        exponential_buckets(0.0, 2.0, 3)
    with pytest.raises(ValueError):
        exponential_buckets(1.0, 1.0, 3)


def test_counter_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(7)
    g.set(3)
    assert g.value == 3.0
    # get-or-create is idempotent, kind mismatch raises
    assert reg.counter("c_total") is c
    with pytest.raises(ValueError):
        reg.gauge("c_total")


def test_histogram_percentiles_vs_numpy_oracle():
    rng = np.random.default_rng(42)
    values = rng.lognormal(mean=-6.0, sigma=2.0, size=500)  # spans many buckets
    h = Histogram("lat_seconds")
    for v in values:
        h.observe(float(v))
    assert h.count == 500
    assert h.sum == pytest.approx(values.sum())
    assert h.min == values.min() and h.max == values.max()
    for pct in (50, 90, 99):
        est = h.percentile(pct)
        true = float(np.percentile(values, pct))
        # estimate lies in the bucket of the rank-th order stat; with
        # factor-2 buckets that bounds the ratio to ~one bucket width
        assert true / 2.5 <= est <= true * 2.5, (pct, est, true)


def test_histogram_edge_cases():
    h = Histogram("h", buckets=[1.0, 2.0, 4.0])
    assert h.percentile(50) is None and h.mean is None
    h.observe(1.5)
    assert h.percentile(50) == 1.5  # single value: clamped to min==max
    h.observe(100.0)  # overflow bucket has no upper edge -> observed max
    assert h.percentile(99) == 100.0
    with pytest.raises(ValueError):
        h.percentile(0)
    with pytest.raises(ValueError):
        Histogram("bad", buckets=[2.0, 1.0])


def test_render_text_prometheus_format():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(3)
    h = reg.histogram("lat", buckets=[1.0, 2.0])
    h.observe(0.5)
    h.observe(1.5)
    h.observe(9.0)
    text = reg.render_text()
    assert "# TYPE req_total counter" in text
    assert "req_total 3" in text
    assert '# TYPE lat histogram' in text
    assert 'lat_bucket{le="1"} 1' in text
    assert 'lat_bucket{le="2"} 2' in text  # cumulative
    assert 'lat_bucket{le="+Inf"} 3' in text
    assert "lat_count 3" in text


def test_snapshot_is_json_serializable():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.gauge("g").set(2)
    reg.histogram("h").observe(0.01)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["counters"]["c"]["value"] == 1
    assert snap["histograms"]["h"]["count"] == 1
    assert snap["histograms"]["h"]["p50"] == pytest.approx(0.01)
    # empty histograms serialize their stats as null, not NaN/inf
    reg.histogram("empty")
    json.dumps(reg.snapshot())
    assert reg.percentiles("empty")[50] is None
    assert reg.percentiles("missing")[99] is None


def test_manual_clock():
    clk = ManualClock(start=10.0)
    assert clk() == 10.0 and clk() == 10.0  # frozen without tick
    clk.advance(0.5)
    assert clk() == 10.5
    with pytest.raises(ValueError):
        clk.advance(-1)
    ticking = ManualClock(tick=0.25)
    assert [ticking() for _ in range(3)] == [0.0, 0.25, 0.5]


# ------------------------------------------------------------------ tracer
def test_tracer_ring_buffer_drops_oldest():
    clk = ManualClock(tick=1.0)
    tr = Tracer(clock=clk, capacity=3)
    for i in range(5):
        tr.instant(f"e{i}")
    assert [e.name for e in tr.events] == ["e2", "e3", "e4"]
    assert tr.recorded == 5 and tr.dropped == 2
    assert tr.to_chrome()["metadata"]["dropped_events"] == 2


def test_tracer_chrome_schema():
    clk = ManualClock(start=100.0, tick=0.001)
    tr = Tracer(clock=clk, capacity=64)
    tr.instant("submit", track=SCHEDULER_TRACK, req_id=0, online=True)
    t0 = tr.now()
    tr.span("prefill", t0, track=slot_track(2), req_id=0, tokens=8)
    doc = tr.to_chrome()
    evs = doc["traceEvents"]
    assert all({"name", "ph", "ts", "pid", "tid", "args"} <= set(e) for e in evs)
    names = {e["args"].get("name") for e in evs if e["ph"] == "M"}
    assert {"paged-engine", "scheduler", "slot 2"} <= names
    inst = next(e for e in evs if e["name"] == "submit")
    assert inst["ph"] == "i" and inst["ts"] == 0.0  # rebased to first event
    assert inst["args"]["req_id"] == 0
    span = next(e for e in evs if e["name"] == "prefill")
    assert span["ph"] == "X" and span["dur"] > 0 and span["tid"] == slot_track(2)
    json.dumps(doc)  # must be a valid JSON document


# ------------------------------------------------------------------ engine
def test_engine_exact_latencies_with_manual_clock(setup):
    """Frozen clock + explicit advances make latencies exact equalities."""
    cfg, params = setup
    clk = ManualClock()
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64, clock=clk)
    r = eng.submit([3, 1, 4], max_new_tokens=4)
    assert r.submit_t == 0.0
    clk.advance(0.5)  # request sits in the queue for exactly 0.5s
    eng.step()  # admit + prefill + first token, clock frozen at 0.5
    assert r.admit_t == 0.5 and r.queue_wait == 0.5
    assert r.first_token_t == 0.5 and r.ttft == 0.5
    h = eng.metrics.get("engine_ttft_seconds")
    assert h.count == 1 and h.percentile(50) == 0.5  # clamped to min==max
    assert eng.metrics.get("engine_queue_wait_seconds").percentile(99) == 0.5
    clk.advance(0.25)
    eng.run_until_drained()
    assert r.done_t == 0.75
    # 3 decode tokens after the first, all in frozen-clock steps -> tpot 0
    assert r.tpot == pytest.approx(0.25 / 3)
    assert eng.stats()["ttft_p50_s"] == 0.5


def test_engine_event_ordering_per_request(setup):
    cfg, params = setup
    clk = ManualClock(tick=1e-4)  # strictly increasing timestamps
    eng = InferenceEngine(
        cfg, params, max_batch=2, max_seq=64, block_size=8,
        prefill_budget=8, clock=clk,
    )
    reqs = [eng.submit(list(range(2, 20)), max_new_tokens=3) for _ in range(2)]
    eng.run_until_drained()
    for r in reqs:
        evs = eng.tracer.events_for(r.req_id)
        by_name = {}
        for e in evs:
            by_name.setdefault(e.name, []).append(e)
        for name in ("submit", "admit", "prefill_chunk", "first_token", "finish"):
            assert name in by_name, f"req {r.req_id} missing {name}"
        t = lambda n: by_name[n][0].ts
        assert t("submit") < t("admit") < t("prefill_chunk")
        assert t("prefill_chunk") < t("first_token") < t("finish")
        # chunks are spans on the request's slot track, in time order
        chunks = by_name["prefill_chunk"]
        assert all(e.dur is not None for e in chunks)
        assert [e.ts for e in chunks] == sorted(e.ts for e in chunks)
        assert {e.track for e in evs if e.name != "submit"} == {slot_track(r.slot)}
        # the admit -> finish envelope span brackets the whole lifetime
        # (admit_t is read one clock tick before the admit instant)
        env = by_name[f"req {r.req_id}"][0]
        assert env.ts <= t("admit") and env.ts < t("first_token") < env.ts + env.dur
    # the scheduler track carries the step spans
    steps = [e for e in eng.tracer.events if e.name == "step"]
    assert steps and all(e.track == SCHEDULER_TRACK for e in steps)


def test_profiling_off_means_zero_syncs(setup, monkeypatch, tmp_path):
    """The default path must not gain host syncs; profile=True brackets
    every dispatch and decomposes the step span by phase."""
    cfg, params = setup
    calls = {"n": 0}
    real = engine_mod._block_until_ready

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(engine_mod, "_block_until_ready", counting)
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64)
    eng.submit([5, 6, 7], max_new_tokens=4)
    eng.run_until_drained()
    assert calls["n"] == 0, "profile=False must never call block_until_ready"
    assert not any(n.startswith("engine_profile_") for n in eng.metrics.names())

    prof = InferenceEngine(cfg, params, max_batch=2, max_seq=64, profile=True)
    prof.submit([5, 6, 7], max_new_tokens=4)
    prof.run_until_drained()
    assert calls["n"] > 0
    decode = prof.metrics.get("engine_profile_decode_seconds")
    assert decode is not None and decode.count > 0
    phases = [e.args.get("phases") for e in prof.tracer.events if e.name == "step"]
    assert any(p and "decode" in p for p in phases)


def test_stats_defensive_snapshot_and_guards(setup):
    cfg, params = setup
    eng = InferenceEngine(
        cfg, params, max_batch=2, max_seq=64, spec_decode="ngram", spec_k=2
    )
    s = eng.stats()  # empty drain: every derived rate must guard, not raise
    assert s["mean_ttft_s"] is None and s["ttft_p50_s"] is None
    assert s["acceptance_rate"] == 0.0 and s["accepted_per_step"] == 0.0
    assert s["prefix_hit_rate"] == 0.0 and s["joules_per_token"] == 0.0
    # mutating the snapshot must not corrupt engine state
    s["tokens_out"] = 999999
    s.clear()
    s2 = eng.stats()
    assert s2["tokens_out"] == 0 and "cache_kind" in s2


def test_energy_attribution(setup):
    cfg, params = setup
    clk = ManualClock(tick=0.01)  # nonzero step durations without sleeping
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64, clock=clk)
    reqs = [eng.submit([9 + i, 2, 3], max_new_tokens=4) for i in range(3)]
    eng.run_until_drained()
    s = eng.stats()
    assert s["energy_joules"] > 0
    assert s["joules_per_token"] == pytest.approx(s["energy_joules"] / s["tokens_out"])
    # the step joules split exactly over the requests that did the work
    assert sum(r.energy_j for r in reqs) == pytest.approx(eng.energy.joules)
    assert all(r.energy_j > 0 and r.joules_per_token > 0 for r in reqs)
    assert eng.metrics.get("engine_energy_joules_total").value == pytest.approx(
        eng.energy.joules
    )
    # a fixed roofline utilization override scales the charge deterministically
    bridge = EnergyBridge(chips=4, utilization=0.5)
    j = bridge.record_step(2.0, occupancy=1.0)
    assert j > 0 and bridge.record_step(0.0, occupancy=1.0) == 0.0
    assert bridge.joules == j


def test_pool_and_prefix_metrics_published(setup, tmp_path):
    cfg, params = setup
    shared = [11, 12, 13, 14, 15, 16, 17, 18]
    eng = InferenceEngine(
        cfg, params, max_batch=2, max_seq=64, block_size=8,
        prefix_cache=True, prefill_budget=8,
    )
    for i in range(4):
        eng.submit(shared + [40 + i], max_new_tokens=3)
    eng.run_until_drained()
    m = eng.metrics
    assert m.get("pool_allocs_total").value > 0
    assert m.get("pool_blocks_in_use").value == eng.allocator.blocks_in_use
    assert m.get("pool_blocks_cached").value == eng.allocator.num_cached
    assert m.get("prefix_entries").value == len(eng.prefix)
    assert m.get("prefix_registrations_total").value == eng.prefix.registered
    assert m.get("engine_prefix_hit_tokens_total").value == eng.prefix_hit_tokens > 0
    # snapshot + chrome trace write end-to-end
    m.write_json(tmp_path / "metrics.json")
    snap = json.loads((tmp_path / "metrics.json").read_text())
    assert snap["histograms"]["engine_ttft_seconds"]["count"] == 4
    eng.tracer.write(tmp_path / "trace.json")
    doc = json.loads((tmp_path / "trace.json").read_text())
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"submit", "admit", "first_token", "finish", "step"} <= names


@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)
def test_tp_metrics_parity(setup):
    """Device-invariant counters must match exactly between TP=1 and TP=2
    (latency histograms legitimately differ; token/block/prefix accounting
    must not)."""
    from repro.launch.mesh import make_serving_mesh

    cfg, params = setup
    prompts = [[11, 12, 13, 14, 15, 16, 17, 18] + [40 + i] for i in range(4)]

    def drive(mesh):
        eng = InferenceEngine(
            cfg, params, max_batch=2, max_seq=64, block_size=8,
            cache_dtype=jnp.float32, prefix_cache=True, prefill_budget=8,
            mesh=mesh,
        )
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        eng.run_until_drained()
        return eng

    base, tp = drive(None), drive(make_serving_mesh(2))
    for name in (
        "engine_requests_submitted_total",
        "engine_requests_finished_total",
        "engine_tokens_out_total",
        "engine_prefill_tokens_total",
        "engine_prefix_hit_tokens_total",
        "pool_allocs_total",
        "pool_frees_total",
        "pool_evictions_total",
        "prefix_registrations_total",
    ):
        assert base.metrics.get(name).value == tp.metrics.get(name).value, name
    assert base.metrics.get("engine_ttft_seconds").count == 4
    assert tp.metrics.get("engine_ttft_seconds").count == 4
    # TP charges mesh-size chips into the energy bridge
    assert base.energy.chips == 1 and tp.energy.chips == 2
