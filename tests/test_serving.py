"""Serving engine: continuous batching must equal per-prompt greedy decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import forward, init_params
from repro.serving import InferenceEngine, RequestState


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = forward(cfg, params, {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt) :]


@pytest.mark.parametrize("cache_kind", ["paged", "dense"])
def test_continuous_batching_matches_reference(setup, cache_kind):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=3, max_seq=64, cache_kind=cache_kind)
    prompts = [[5, 9, 12], [7, 3], [20, 21, 22, 23], [4, 4, 8]]  # 4 reqs, 3 slots
    reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.run_until_drained()
    for p, r in zip(prompts, reqs):
        assert r.state == RequestState.DONE
        ref = greedy_reference(cfg, params, p, 6)
        assert r.generated[: len(ref)] == ref, f"slot-reuse corrupted request {p}"


def test_online_requests_admitted_before_offline(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64)
    off = eng.submit([1, 2, 3], max_new_tokens=4, online=False)
    on = eng.submit([4, 5, 6], max_new_tokens=4, online=True)
    eng.step()  # admission happens here
    assert on.state == RequestState.ACTIVE
    assert off.state == RequestState.WAITING


def test_engine_stats(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64)
    eng.submit([1, 2], max_new_tokens=3)
    eng.submit([3, 4], max_new_tokens=3)
    eng.run_until_drained()
    s = eng.stats()
    assert s["requests_done"] == 2
    assert s["tokens_out"] == 6
    assert s["mean_ttft_s"] is not None


def test_eos_stops_generation(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64, eos_token=999999)
    r = eng.submit([1, 2, 3], max_new_tokens=5)
    eng.run_until_drained()
    assert len(r.generated) == 5  # eos never sampled -> runs to max_new_tokens


def test_never_admitted_request_has_none_ttft(setup):
    """A queued-but-never-admitted request must report ttft=None (the serve
    CLI guards its ms formatting on this), and a truncated drain must be
    distinguishable from a finished one in ``stats()``: ``mean_ttft_s`` /
    ``slot_utilization`` only describe the finished/current population, so
    the queued/active counts carry the truncation evidence into benchmark
    JSON."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64)
    first = eng.submit([1, 2, 3], max_new_tokens=30)
    starved = eng.submit([4, 5, 6], max_new_tokens=4)
    with pytest.warns(RuntimeWarning):
        eng.run_until_drained(max_steps=2)
    assert first.ttft is not None
    assert starved.ttft is None and starved.state == RequestState.WAITING
    s = eng.stats()
    # truncated run: one request still decoding in its slot, one never left
    # the queue — requests_done alone would under-report the workload
    assert s["requests_done"] == 0
    assert s["requests_active"] == 1
    assert s["requests_queued"] == 1
    assert s["mean_ttft_s"] is None  # no finished requests to average over
    assert s["requests_done"] + s["requests_active"] + s["requests_queued"] == 2
    eng.run_until_drained()
    s = eng.stats()
    assert s["requests_done"] == 2
    assert s["requests_active"] == 0 and s["requests_queued"] == 0
    assert s["mean_ttft_s"] is not None


def test_stats_populations_partition_mid_prefill(setup):
    """A slot still chunk-prefilling counts under ``requests_prefilling``,
    NOT ``requests_active`` — the four populations must partition the
    submitted requests or benchmark consumers double-count."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64, prefill_budget=4)
    eng.submit(list(range(2, 26)), max_new_tokens=4)  # 24-token prompt, 4/step
    eng.submit([30, 31], max_new_tokens=4)
    with pytest.warns(RuntimeWarning):
        eng.run_until_drained(max_steps=2)  # truncates mid-prefill
    s = eng.stats()
    assert s["requests_prefilling"] == 1
    assert s["requests_active"] == 0  # mid-prefill slot is not decoding
    assert s["requests_queued"] == 1
    assert (
        s["requests_done"] + s["requests_queued"] + s["requests_active"] + s["requests_prefilling"]
        == 2
    )


def test_sample_tokens_greedy_extreme_logits():
    """Greedy rows (temperature <= 0) must never route extreme logits
    through the 1e-6 temperature clamp: ``logits / 1e-6`` overflows fp32 to
    inf inside the sampled branch (sort / categorical) before ``jnp.where``
    discards it.  The safe-select keeps every intermediate finite and the
    greedy result exactly argmax."""
    from repro.serving.sampler import sample_tokens

    V = 16
    big = np.full((V,), -3.0e38, np.float32)
    big[7] = 3.0e38  # near-fp32-max spread: naive 1e6 scaling overflows
    logits = jnp.asarray(np.stack([big, np.roll(big, 3), np.linspace(-1, 1, V, dtype=np.float32)]))
    temps = jnp.asarray([0.0, -1.0, 0.7])  # two greedy rows, one sampled
    top_ks = jnp.asarray([0, 5, 3], jnp.int32)
    out = np.asarray(sample_tokens(logits, temps, top_ks, jax.random.PRNGKey(0)))
    assert out[0] == 7 and out[1] == 10, out
    assert 0 <= out[2] < V
    # all-greedy batch with the same extreme logits: still exact argmax
    out2 = np.asarray(
        sample_tokens(logits, jnp.zeros((3,)), jnp.zeros((3,), jnp.int32), jax.random.PRNGKey(1))
    )
    assert list(out2) == [int(np.argmax(np.asarray(l))) for l in logits]


def test_spec_accept_greedy_extreme_logits():
    """The verify-path twin of the sampler fix: greedy rows in
    ``spec_accept`` scale by a benign temperature so near-fp32-max logits
    can't produce inf/NaN in the (discarded) softmax lanes, and the greedy
    accept rule stays exact argmax-prefix comparison."""
    from repro.serving.sampler import _target_probs, spec_accept

    B, K, V = 1, 2, 8
    logits = np.full((B, K + 1, V), -3.0e38, np.float32)
    argmaxes = [2, 5, 1]
    for i, a in enumerate(argmaxes):
        logits[0, i, a] = 3.0e38
    logits = jnp.asarray(logits)
    temps = jnp.zeros((B,))
    top_ks = jnp.zeros((B,), jnp.int32)
    p = np.asarray(_target_probs(logits, temps, top_ks))
    assert np.isfinite(p).all(), "greedy _target_probs produced non-finite probs"
    drafts = jnp.asarray([[2, 5]], jnp.int32)  # matches argmax prefix
    q = jax.nn.one_hot(drafts, V, dtype=jnp.float32)
    n_acc, final = spec_accept(
        logits, drafts, q, jnp.ones((B, K), bool), temps, top_ks, jax.random.PRNGKey(0)
    )
    assert int(n_acc[0]) == K and int(final[0]) == 1
