"""Serving engine: continuous batching must equal per-prompt greedy decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import forward, init_params
from repro.serving import InferenceEngine, RequestState


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = forward(cfg, params, {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt) :]


@pytest.mark.parametrize("cache_kind", ["paged", "dense"])
def test_continuous_batching_matches_reference(setup, cache_kind):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=3, max_seq=64, cache_kind=cache_kind)
    prompts = [[5, 9, 12], [7, 3], [20, 21, 22, 23], [4, 4, 8]]  # 4 reqs, 3 slots
    reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.run_until_drained()
    for p, r in zip(prompts, reqs):
        assert r.state == RequestState.DONE
        ref = greedy_reference(cfg, params, p, 6)
        assert r.generated[: len(ref)] == ref, f"slot-reuse corrupted request {p}"


def test_online_requests_admitted_before_offline(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64)
    off = eng.submit([1, 2, 3], max_new_tokens=4, online=False)
    on = eng.submit([4, 5, 6], max_new_tokens=4, online=True)
    eng.step()  # admission happens here
    assert on.state == RequestState.ACTIVE
    assert off.state == RequestState.WAITING


def test_engine_stats(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=2, max_seq=64)
    eng.submit([1, 2], max_new_tokens=3)
    eng.submit([3, 4], max_new_tokens=3)
    eng.run_until_drained()
    s = eng.stats()
    assert s["requests_done"] == 2
    assert s["tokens_out"] == 6
    assert s["mean_ttft_s"] is not None


def test_eos_stops_generation(setup):
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64, eos_token=999999)
    r = eng.submit([1, 2, 3], max_new_tokens=5)
    eng.run_until_drained()
    assert len(r.generated) == 5  # eos never sampled -> runs to max_new_tokens


def test_never_admitted_request_has_none_ttft(setup):
    """A queued-but-never-admitted request must report ttft=None (the serve
    CLI guards its ms formatting on this)."""
    cfg, params = setup
    eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64)
    first = eng.submit([1, 2, 3], max_new_tokens=30)
    starved = eng.submit([4, 5, 6], max_new_tokens=4)
    with pytest.warns(RuntimeWarning):
        eng.run_until_drained(max_steps=2)
    assert first.ttft is not None
    assert starved.ttft is None and starved.state == RequestState.WAITING
