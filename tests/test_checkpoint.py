"""Checkpoint layer: atomic roundtrip, retention, tier models, Young cadence."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    TIERS,
    available_steps,
    checkpoint_bytes,
    restore_pytree,
    save_pytree,
)
from repro.checkpoint.storage import DataMover


def tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jax.random.normal(k, (3,), jnp.bfloat16)},
    }


def test_roundtrip(tmp_path):
    t = tree()
    save_pytree(t, tmp_path, step=7)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, extra = restore_pytree(like, tmp_path)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_restore_validates_shapes(tmp_path):
    save_pytree(tree(), tmp_path, step=1)
    bad = {"a": jax.ShapeDtypeStruct((2, 2), jnp.float32), "nested": {"b": jax.ShapeDtypeStruct((10,), jnp.int32), "c": jax.ShapeDtypeStruct((3,), jnp.bfloat16)}}
    with pytest.raises(ValueError):
        restore_pytree(bad, tmp_path)


def test_atomic_commit_never_exposes_partial(tmp_path):
    """A directory only becomes a restore point at the atomic rename."""
    save_pytree(tree(), tmp_path, step=1)
    # simulate a crashed writer: leftover tmp dir must be ignored
    crashed = tmp_path / ".tmp_ckpt_crashed"
    crashed.mkdir()
    (crashed / "manifest.json").write_text("{corrupt")
    assert available_steps(tmp_path) == [1]


def test_manager_keep_k_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=True)
    t = tree()
    for s in (1, 2, 3, 4):
        mgr.save(t, step=s)
    mgr.wait()
    assert available_steps(tmp_path) == [3, 4]
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, extra = mgr.restore(like)
    assert "modeled_restore_seconds" in extra
    mgr.close()


def test_tier_selection_by_qos(tmp_path):
    assert CheckpointManager(tmp_path, qos="training").tier_name == "lustre"
    assert CheckpointManager(tmp_path / "b", qos="inference").tier_name == "vast"
    assert CheckpointManager(tmp_path / "c", qos="experimentation").tier_name == "local"


def test_arctic_checkpoint_fits_paper_lustre_envelope(tmp_path):
    """480B params in bf16 (+bf16 moments) ~ 2.9 TB -> < 2 s at the paper's
    1,980 GB/s ClusterStor write bandwidth. Validates the facility sizing."""
    nbytes = 480e9 * 2 * 3  # params + m + v in bf16
    t = TIERS["lustre"].write_seconds(nbytes)
    assert t < 2.0, f"480B checkpoint would take {t:.1f}s on Lustre"
    # and would take >9 hours to tape — the DMF tiering story
    assert TIERS["tape"].write_seconds(nbytes) > 9 * 3600 * 0.06


def test_young_daly_cadence(tmp_path):
    mgr = CheckpointManager(tmp_path, qos="training", nodes=1320)
    advice = mgr.cadence_advice(step_seconds=10.0, nbytes=2.9e12)
    # 1,320 nodes at 50k h node-MTBF -> job MTBF ~ 37.9 h
    assert 30 < advice["job_mtbf_hours"] < 45
    assert advice["optimal_interval_seconds"] > 60
    assert advice["overhead_fraction"] < 0.05
    mgr.close()


def test_data_mover_policy():
    mover = DataMover()
    t = mover.move_seconds(1e12, "lustre", "vast")
    assert t > 0 and mover.log
    assert mover.archive_policy(age_days=400, accessed_days=200) == "tape"
    assert mover.archive_policy(age_days=40, accessed_days=35) == "vast"
    assert mover.archive_policy(age_days=1, accessed_days=1) is None
