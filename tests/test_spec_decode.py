"""Speculative decoding on the paged serving engine.

Covers: the n-gram prompt-lookup and draft-model drafters, exactness of the
vectorised rejection-sampling accept/reject (greedy degeneration AND the
distributional identity for temperature > 0), token-level block-table
truncation, and the engine-level guarantee the feature is sold on — greedy
speculative decode (both modes) is token-identical to the non-speculative
paged engine, with allocator accounting clean under mid-sequence rollback.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import (
    DraftModel,
    InferenceEngine,
    make_draft_config,
    ngram_draft,
    spec_accept,
    truncate_blocks,
)

# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------


def test_ngram_draft_finds_repeats():
    ctx = [1, 2, 3, 4, 1, 2, 3, 4, 1, 2]
    # suffix [4, 1, 2] occurred before, followed by 3, 4, 1...
    assert ngram_draft(ctx, 3) == [3, 4, 1]
    # a run of identical tokens proposes the whole window, not one token
    run = [9, 9, 9, 9, 9, 9]
    assert ngram_draft(run, 4) == [9, 9, 9, 9]


def test_ngram_draft_prefers_longest_suffix():
    # [7, 8] recurs with continuation 5; the unigram [8] also recurs later
    # with a different continuation — the longer suffix must win
    ctx = [7, 8, 5, 0, 8, 3, 7, 8]
    assert ngram_draft(ctx, 1, max_ngram=3) == [5]


def test_ngram_draft_no_match_and_budget():
    assert ngram_draft([1, 2, 3, 4, 5], 4) == []  # no repeats
    assert ngram_draft([1, 2, 1, 2], 0) == []  # no budget
    assert ngram_draft([5], 4) == []  # too short
    # a match near the end extrapolates its period past the boundary
    assert ngram_draft([3, 4, 9, 3, 4], 4, max_ngram=2) == [9, 3, 4, 9]


def test_truncate_blocks_token_level():
    blocks = [4, 7, 2, 9]
    assert truncate_blocks(blocks, 32, 8) == ([4, 7, 2, 9], [])
    assert truncate_blocks(blocks, 17, 8) == ([4, 7, 2], [9])
    assert truncate_blocks(blocks, 16, 8) == ([4, 7], [2, 9])
    assert truncate_blocks(blocks, 1, 8) == ([4], [7, 2, 9])
    assert truncate_blocks(blocks, 0, 8) == ([], [4, 7, 2, 9])
    assert truncate_blocks([], 5, 8) == ([], [])


def test_make_draft_config_shares_vocab():
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    dcfg = make_draft_config(cfg)
    assert dcfg.num_layers == max(cfg.num_layers // 2, 1)
    assert dcfg.padded_vocab == cfg.padded_vocab
    assert dcfg.family == cfg.family


def test_draft_model_catchup_and_rollback():
    """After a rollback, re-drafting from the same committed context must
    reproduce the same greedy proposals (stale ring entries are re-fed)."""
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    dm = DraftModel(cfg, params, max_batch=2, max_seq=64)
    ctx = [5, 9, 12, 7, 3]
    d1, q1 = dm.draft(0, ctx, 3)
    assert len(d1) == 3 and q1.shape == (3, cfg.padded_vocab)
    assert all(q1[i, d1[i]] == 1.0 for i in range(3))  # greedy -> one-hot
    dm.rollback(0, len(ctx))  # target rejected everything
    d2, _ = dm.draft(0, ctx + [42], 3)  # correction token extends the context
    dm.reset(0)
    d3, _ = dm.draft(0, ctx + [42], 3)  # cold replay of the same context
    assert d2 == d3, "rollback + catch-up diverged from a cold start"


# ---------------------------------------------------------------------------
# spec_accept: rejection sampling
# ---------------------------------------------------------------------------


def _greedy_args(B, K, V):
    return (
        jnp.zeros((B,), jnp.float32),  # temperature
        jnp.zeros((B,), jnp.int32),  # top_k
        jax.random.PRNGKey(0),
    )


def test_spec_accept_greedy_prefix():
    V, K = 16, 3
    logits = jnp.stack(
        [jax.nn.one_hot(jnp.array([3, 5, 7, 9]), V) * 10.0]
    )  # (1, K+1, V): argmax = 3,5,7,9
    drafts = jnp.array([[3, 5, 0]])  # first two match, third diverges
    qprobs = jax.nn.one_hot(drafts, V)
    valid = jnp.ones((1, K), bool)
    n_acc, final = spec_accept(logits, drafts, qprobs, valid, *_greedy_args(1, K, V))
    assert int(n_acc[0]) == 2
    assert int(final[0]) == 7  # the correction token IS the target argmax


def test_spec_accept_greedy_bonus_on_full_accept():
    V, K = 16, 2
    logits = jnp.stack([jax.nn.one_hot(jnp.array([3, 5, 7]), V) * 10.0])
    drafts = jnp.array([[3, 5]])
    n_acc, final = spec_accept(
        logits, drafts, jax.nn.one_hot(drafts, V), jnp.ones((1, K), bool), *_greedy_args(1, K, V)
    )
    assert int(n_acc[0]) == K and int(final[0]) == 7  # bonus from the K+1-th dist


def test_spec_accept_invalid_forces_reject():
    V, K = 16, 3
    logits = jnp.stack([jax.nn.one_hot(jnp.array([3, 5, 7, 9]), V) * 10.0])
    drafts = jnp.array([[3, 5, 7]])  # all would match...
    valid = jnp.array([[True, False, True]])  # ...but lane 1 proposed nothing
    n_acc, final = spec_accept(
        logits, drafts, jax.nn.one_hot(drafts, V), valid, *_greedy_args(1, K, V)
    )
    assert int(n_acc[0]) == 1
    assert int(final[0]) == 5  # plain greedy sample at the forced reject


def test_spec_accept_identical_draft_distribution_always_accepts():
    """q == p => the accept ratio is 1 for the drafted token: sampled mode
    must accept the full window regardless of the key."""
    V, K, B = 8, 3, 4
    key = jax.random.PRNGKey(1)
    logits = jax.random.normal(key, (B, K + 1, V))
    p = jax.nn.softmax(logits[:, :K], axis=-1)
    drafts = jnp.argmax(p, axis=-1)  # any supported token works; argmax is stable
    for seed in range(5):
        n_acc, _ = spec_accept(
            logits,
            drafts,
            p,
            jnp.ones((B, K), bool),
            jnp.ones((B,), jnp.float32),
            jnp.zeros((B,), jnp.int32),
            jax.random.PRNGKey(seed),
        )
        assert np.all(np.asarray(n_acc) == K)


def test_spec_accept_matches_target_distribution():
    """The combined accept/resample law must equal the target distribution
    (the exactness theorem): empirical histogram over many keys ~ p."""
    V, K, N = 8, 1, 4000
    key = jax.random.PRNGKey(2)
    logits1 = jax.random.normal(key, (1, K + 1, V))
    logits = jnp.broadcast_to(logits1, (N, K + 1, V))
    # a deliberately bad one-hot draft (the ngram case): token 0 every time
    drafts = jnp.zeros((N, K), jnp.int32)
    qprobs = jax.nn.one_hot(drafts, V)
    n_acc, final = spec_accept(
        logits,
        drafts,
        qprobs,
        jnp.ones((N, K), bool),
        jnp.ones((N,), jnp.float32),
        jnp.zeros((N,), jnp.int32),
        jax.random.PRNGKey(7),
    )
    n_acc, final = np.asarray(n_acc), np.asarray(final)
    emitted = np.where(n_acc >= 1, 0, final)  # first emitted token per row
    p = np.asarray(jax.nn.softmax(logits1[0, 0]))
    freq = np.bincount(emitted, minlength=V) / N
    assert np.max(np.abs(freq - p)) < 0.04, f"emitted law diverged: {freq} vs {p}"


# ---------------------------------------------------------------------------
# engine: greedy speculative decode == non-speculative paged engine
# ---------------------------------------------------------------------------


def _make(arch, window=0):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    if window:
        cfg = cfg.replace(sliding_window=window)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


PROMPTS = [[7, 3, 9, 4] * 4 + [5], [5, 9, 12, 5, 9, 12, 5, 9, 12, 2], [30, 31]]


def _run_engine(cfg, params, prompts, *, max_new=6, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        eng = InferenceEngine(
            cfg, params, max_batch=2, max_seq=64, block_size=8,
            cache_dtype=jnp.float32, **kw,
        )
        outs = []
        for p in prompts:
            r = eng.submit(p, max_new_tokens=max_new)
            eng.run_until_drained()
            outs.append(r.generated)
        return outs, eng.stats()


SPEC_CASES = [
    ("olmo-1b", 0, "xla", "ngram"),
    ("olmo-1b", 0, "xla", "draft"),
    ("olmo-1b", 0, "pallas", "ngram"),
    ("olmo-1b", 8, "xla", "ngram"),  # sliding window: reclaim under rollback
    ("qwen3-moe-235b-a22b", 0, "xla", "ngram"),
    ("qwen3-moe-235b-a22b", 0, "xla", "draft"),
]


@pytest.mark.parametrize("arch,window,impl,mode", SPEC_CASES)
def test_spec_engine_matches_baseline(arch, window, impl, mode):
    cfg, params = _make(arch, window)
    kw = {}
    if mode == "draft":
        # self-drafting (draft == target): maximal acceptance, and the
        # equivalence check is still meaningful — commit/rollback runs hot
        kw = dict(draft_cfg=cfg, draft_params=params)
    base, _ = _run_engine(cfg, params, PROMPTS, attn_impl=impl)
    out, stats = _run_engine(
        cfg, params, PROMPTS, attn_impl=impl, spec_decode=mode, spec_k=4, **kw
    )
    assert out == base, f"{arch}/{mode}: speculative decode changed greedy tokens"
    assert stats["spec_steps"] > 0
    # drained engine leak check: every alloc matched by a free
    assert stats["alloc_blocks_in_use"] == 0
    assert stats["alloc_total_allocs"] == stats["alloc_total_frees"]


def test_spec_self_draft_acceptance_upper_bound():
    """Draft == target params under greedy accepts every drafted token."""
    cfg, params = _make("olmo-1b")
    out, s = _run_engine(
        cfg, params, [PROMPTS[0]], max_new=9,
        spec_decode="draft", spec_k=4, draft_cfg=cfg, draft_params=params,
    )
    assert s["acceptance_rate"] == 1.0
    assert s["accepted_per_step"] > 2.0
    assert len(out[0]) == 9


def test_spec_with_prefix_cache_and_chunked_prefill():
    """All three features composed (prefix sharing + budgeted prefill +
    speculation) must still match the dense-cache engine token-for-token."""
    cfg, params = _make("olmo-1b")
    sysp = [7, 3, 9, 4, 11, 2, 6, 8, 13, 5, 10, 12, 14, 15, 16, 17]
    prompts = [sysp + [30 + i] for i in range(3)]
    base, _ = _run_engine(cfg, params, prompts, cache_kind="dense")
    out, s = _run_engine(
        cfg, params, prompts,
        prefix_cache=True, prefill_budget=4, spec_decode="ngram", spec_k=4,
    )
    assert out == base
    assert s["prefix_hit_tokens"] >= 2 * 16  # sharing still happened


def test_verify_tokens_do_not_deflate_prefix_hit_rate():
    """The speculative verify pass rides the chunked-prefill machinery, so
    a mis-wired counter would fold its fed windows into ``prefill_tokens``
    and deflate ``prefix_hit_rate`` whenever spec decode is on.  Audit
    result, pinned here: verify work accrues to the separate
    ``verify_tokens`` stat, ``prefill_tokens`` counts exactly the prompt
    tokens the model computed, and the hit rate matches the spec-off run."""
    cfg, params = _make("olmo-1b")
    sysp = [7, 3, 9, 4, 11, 2, 6, 8, 13, 5, 10, 12, 14, 15, 16, 17]
    # repetitive tails so the ngram drafter actually proposes (verify windows
    # run hot while the shared 16-token prefix is served from cache)
    prompts = [sysp + [30 + i, 40, 41, 40, 41, 40, 41] for i in range(3)]
    _, s_off = _run_engine(cfg, params, prompts, prefix_cache=True, prefill_budget=4)
    _, s_on = _run_engine(
        cfg, params, prompts,
        prefix_cache=True, prefill_budget=4, spec_decode="ngram", spec_k=4,
    )
    assert s_on["spec_steps"] > 0 and s_on["verify_tokens"] > 0
    # every prompt token is either computed (prefill) or served from cache —
    # verify windows must appear in neither bucket
    total_prompt = sum(len(p) for p in prompts)
    for s in (s_off, s_on):
        assert s["prefill_tokens"] + s["prefix_hit_tokens"] == total_prompt, s
    assert s_on["prefill_tokens"] == s_off["prefill_tokens"]
    assert s_on["prefix_hit_rate"] == s_off["prefix_hit_rate"] > 0
    assert "verify_tokens" not in s_off  # spec-off stats carry no spec keys


def test_spec_quantized_kv_matches_quantized_baseline():
    cfg, params = _make("olmo-1b")
    base, _ = _run_engine(cfg, params, PROMPTS[:2], quantize_kv=True)
    out, _ = _run_engine(
        cfg, params, PROMPTS[:2], quantize_kv=True, spec_decode="ngram", spec_k=3
    )
    assert out == base, "speculative rollback corrupted the int8 pool path"


def test_spec_hybrid_warns_and_disables():
    cfg, params = _make("hymba-1.5b")
    with pytest.warns(RuntimeWarning, match="spec_decode"):
        eng = InferenceEngine(cfg, params, max_batch=1, max_seq=64, spec_decode="ngram")
    assert eng.spec_mode == "off"
    r = eng.submit([5, 9, 12], max_new_tokens=3)
    eng.run_until_drained()
    assert len(r.generated) == 3


def test_spec_invalid_knobs_raise():
    cfg, params = _make("olmo-1b")
    with pytest.raises(ValueError, match="spec_decode"):
        InferenceEngine(cfg, params, max_batch=1, max_seq=64, spec_decode="bogus")
    with pytest.raises(ValueError, match="spec_k"):
        InferenceEngine(cfg, params, max_batch=1, max_seq=64, spec_decode="ngram", spec_k=0)


def test_spec_headroom_enforced_at_submit():
    """Admission must reserve spec_k positions of rollback headroom."""
    cfg, params = _make("olmo-1b")
    eng = InferenceEngine(
        cfg, params, max_batch=1, max_seq=32, block_size=8, spec_decode="ngram", spec_k=4
    )
    with pytest.raises(ValueError, match="headroom"):
        eng.submit(list(range(2, 22)), max_new_tokens=10)  # fits only without spec
    r = eng.submit(list(range(2, 18)), max_new_tokens=10)  # 26 + 4 <= 32
    eng.run_until_drained()
    assert len(r.generated) == 10


def test_spec_respects_max_new_budget():
    """A near-done request must not overshoot max_new even with a larger
    draft window (drafts are clamped to remaining - 1)."""
    cfg, params = _make("olmo-1b")
    base, _ = _run_engine(cfg, params, [PROMPTS[0]], max_new=2)
    out, _ = _run_engine(cfg, params, [PROMPTS[0]], max_new=2, spec_decode="ngram", spec_k=4)
    assert out == base and len(out[0]) == 2


def test_spec_eos_mid_window_truncates():
    """An accepted EOS inside the draft window must stop the request at the
    same length as the baseline engine (mid-sequence truncation path)."""
    cfg, params = _make("olmo-1b")
    probe, _ = _run_engine(cfg, params, [PROMPTS[0]], max_new=8)
    eos = probe[0][3]  # force EOS at the 4th generated token
    base, _ = _run_engine(cfg, params, [PROMPTS[0]], max_new=8, eos_token=eos)
    out, s = _run_engine(
        cfg, params, [PROMPTS[0]], max_new=8, eos_token=eos,
        spec_decode="draft", spec_k=4, draft_cfg=cfg, draft_params=params,
    )
    assert out == base
    assert out[0][-1] == eos and len(out[0]) <= 8
    assert s["alloc_blocks_in_use"] == 0
    assert s["alloc_total_allocs"] == s["alloc_total_frees"]


def test_spec_temperature_sampling_runs():
    """temperature > 0 speculation: not bit-identical to the baseline (the
    key stream differs) but counts, ranges and stats must hold."""
    cfg, params = _make("olmo-1b")
    out, s = _run_engine(
        cfg, params, PROMPTS[:2], max_new=8, spec_decode="ngram", spec_k=3
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        eng = InferenceEngine(
            cfg, params, max_batch=2, max_seq=64, block_size=8,
            cache_dtype=jnp.float32, spec_decode="ngram", spec_k=3,
        )
        rs = [eng.submit(p, max_new_tokens=8, temperature=0.9, top_k=4) for p in PROMPTS[:2]]
        eng.run_until_drained()
    for r in rs:
        assert len(r.generated) == 8
        assert all(0 <= t < cfg.padded_vocab for t in r.generated)
