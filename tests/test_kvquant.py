"""Int8 KV-cache quantization: error bounds + attention-output fidelity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models.attention import NEG_INF
from repro.serving.kvquant import (
    attend_quantized,
    dequantize,
    memory_saving,
    quantize,
    quantize_cache,
)


def test_quantize_roundtrip_error_bound():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 64, 4, 32)) * 3.0
    q, s = quantize(x)
    err = jnp.abs(dequantize(q, s, jnp.float32) - x)
    # symmetric int8: error <= scale/2 per element
    assert float(jnp.max(err - s / 2)) < 1e-6
    rel = float(jnp.max(err) / jnp.max(jnp.abs(x)))
    assert rel < 0.01


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(8, 64), st.floats(0.1, 100.0))
def test_quantize_scale_invariance(heads, seq, scale):
    """Property: quantization error scales linearly with tensor magnitude."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1, seq, heads, 16)) * scale
    q, s = quantize(x)
    err = float(jnp.max(jnp.abs(dequantize(q, s, jnp.float32) - x)))
    assert err <= float(jnp.max(s)) / 2 + 1e-6


def test_attention_output_fidelity():
    """Decode attention over int8 KV stays within bf16-level error."""
    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    key = jax.random.PRNGKey(2)
    B, W, H, KV, hd = 2, 64, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, W, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, W, KV, hd), jnp.float32)
    mask = jnp.zeros((B, 1, 1, 1, W), jnp.float32)

    from repro.models.attention import _attend_block

    ref = _attend_block(cfg, q, k, v, mask, cfg.q_per_kv)
    out = attend_quantized(cfg, q, quantize_cache(k, v), mask)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 0.02, f"int8 KV attention deviates by {err}"


def test_memory_saving_arithmetic():
    """mistral-nemo decode_32k: int8 KV nearly halves the bf16 cache traffic."""
    s = memory_saving(seq=32768, kv_heads=8, head_dim=128, layers=40, batch=128)
    assert 1.8 < s["ratio"] < 2.0
    assert s["bf16_bytes"] == 2 * 40 * 128 * 32768 * 8 * 128 * 2
