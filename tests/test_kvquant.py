"""Quantized KV-cache (int8 + fp8): error bounds + attention/serving fidelity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dep: only the property tests skip without it
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAS_HYPOTHESIS = False

    def given(*a, **k):  # no-op decorators keep module import clean
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn

    class st:  # noqa: N801 - stand-in namespace
        @staticmethod
        def integers(*a, **k):
            return None

        @staticmethod
        def floats(*a, **k):
            return None

        @staticmethod
        def lists(*a, **k):
            return None

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models.attention import NEG_INF
from repro.serving.kvquant import (
    attend_quantized,
    dequantize,
    memory_saving,
    quantize,
    quantize_cache,
)


def test_quantize_roundtrip_error_bound():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 64, 4, 32)) * 3.0
    q, s = quantize(x)
    err = jnp.abs(dequantize(q, s, jnp.float32) - x)
    # symmetric int8: error <= scale/2 per element
    assert float(jnp.max(err - s / 2)) < 1e-6
    rel = float(jnp.max(err) / jnp.max(jnp.abs(x)))
    assert rel < 0.01


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(8, 64), st.floats(0.1, 100.0))
def test_quantize_scale_invariance(heads, seq, scale):
    """Property: quantization error scales linearly with tensor magnitude."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1, seq, heads, 16)) * scale
    q, s = quantize(x)
    err = float(jnp.max(jnp.abs(dequantize(q, s, jnp.float32) - x)))
    assert err <= float(jnp.max(s)) / 2 + 1e-6


def test_attention_output_fidelity():
    """Decode attention over int8 KV stays within bf16-level error."""
    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    key = jax.random.PRNGKey(2)
    B, W, H, KV, hd = 2, 64, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, W, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, W, KV, hd), jnp.float32)
    mask = jnp.zeros((B, 1, 1, 1, W), jnp.float32)

    from repro.models.attention import _attend_block

    ref = _attend_block(cfg, q, k, v, mask, cfg.q_per_kv)
    out = attend_quantized(cfg, q, quantize_cache(k, v), mask)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 0.02, f"int8 KV attention deviates by {err}"


def test_memory_saving_arithmetic():
    """mistral-nemo decode_32k: int8 KV nearly halves the bf16 cache traffic."""
    s = memory_saving(seq=32768, kv_heads=8, head_dim=128, layers=40, batch=128)
    assert 1.8 < s["ratio"] < 2.0
    assert s["bf16_bytes"] == 2 * 40 * 128 * 32768 * 8 * 128 * 2


# ---------------------------------------------------------------------------
# fp8 (e4m3) pool mode
# ---------------------------------------------------------------------------


def test_fp8_quantize_outlier_robustness():
    """e4m3's error is *relative* (~2^-4 of each element) while int8's is a
    uniform grid of amax/254 across the whole (token, head) group: a single
    in-group outlier inflates every int8 neighbour's error but leaves fp8's
    mid-range precision unchanged — the reason serving stacks reach for fp8
    KV on outlier-heavy activations.  The saturating cast stays finite."""
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 2, 64))
    spike = x.at[..., 0].set(60.0)  # one outlier per quantization group
    q8, s8 = quantize(spike, "fp8")
    assert q8.dtype == jnp.float8_e4m3fn
    assert bool(jnp.all(jnp.isfinite(dequantize(q8, s8, jnp.float32))))

    def mean_err(data, mode):
        back = dequantize(*quantize(data, mode), jnp.float32)
        return float(jnp.mean(jnp.abs(back - data)[..., 1:]))  # non-outliers

    assert mean_err(spike, "fp8") < 1.5 * mean_err(x, "fp8"), "fp8 error not relative"
    assert mean_err(spike, "int8") > 5 * mean_err(x, "int8"), "int8 grid did not inflate"
    assert mean_err(spike, "fp8") < mean_err(spike, "int8"), "fp8 lost its own game"


def test_fp8_engine_tokens_close_to_bf16():
    """Serving closeness: a quantized-pool engine (int8 OR fp8) must agree
    with the full-precision pool on nearly every greedy token, and fp8 must
    be at least as close as int8 on this workload."""
    from repro.config.model import reduce_for_smoke as _smoke
    from repro.serving import InferenceEngine

    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params_key = jax.random.PRNGKey(0)
    from repro.models import init_params

    params = init_params(cfg, params_key, jnp.float32)
    prompts = [[7, 3, 9, 4] * 4 + [5], [5, 9, 12, 5, 9, 12, 2], [30, 31, 32, 33]]

    def run(quant):
        eng = InferenceEngine(
            cfg, params, max_batch=2, max_seq=64, block_size=8,
            cache_dtype=jnp.bfloat16, quantize_kv=quant,
        )
        reqs = [eng.submit(p, max_new_tokens=8) for p in prompts]
        eng.run_until_drained()
        return [list(r.generated) for r in reqs]

    base, int8, fp8 = run(False), run("int8"), run("fp8")

    def closeness(a, b):
        toks = [(x, y) for ra, rb in zip(a, b) for x, y in zip(ra, rb)]
        return sum(x == y for x, y in toks) / len(toks)

    c_int8, c_fp8 = closeness(base, int8), closeness(base, fp8)
    assert c_fp8 >= 0.75, f"fp8 pool drifted too far from bf16 ({c_fp8:.2f})"
    assert c_fp8 >= c_int8 - 0.15, f"fp8 ({c_fp8:.2f}) much worse than int8 ({c_int8:.2f})"


def test_fp8_pool_memory_equals_int8():
    """Both quantized modes store 1 byte/element + per-block scales: the
    engine reports the same cache footprint for int8 and fp8 pools."""
    from repro.config.model import reduce_for_smoke as _smoke
    from repro.models import init_params
    from repro.serving import InferenceEngine

    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    sizes = {}
    for mode in ("int8", "fp8", False):
        eng = InferenceEngine(
            cfg, params, max_batch=2, max_seq=64, block_size=8,
            cache_dtype=jnp.bfloat16, quantize_kv=mode,
        )
        sizes[mode] = eng.cache_bytes()
    assert sizes["fp8"] == sizes["int8"] < sizes[False]
