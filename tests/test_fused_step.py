"""Fused one-dispatch step: ``fused=True`` must be invisible to the tokens.

The fused engine lowers each scheduler tick into ONE jitted dispatch (unified
decode / prefill-chunk / spec-verify row batch with in-graph sampling, accept
and rollback) instead of the legacy per-phase walk.  Invariants:

* **Token equivalence** — greedy decode is token-identical to the legacy
  engine across dense / moe / sliding-window archs, both attention backends,
  prefix caching, and both speculative modes (ngram + draft model), with
  identical ``prefix_hit_rate`` / ``acceptance_rate``.
* **Mixed batches** — staggered submits make prefill chunks and decodes share
  one dispatch; outputs still match the legacy interleave.
* **Preemption + spill restore** — SLO preemption mid-flight and the
  host-RAM restore queue compose with the fused path without token drift.
* **Fewer dispatches** — the point of the refactor: the fused engine reports
  strictly fewer ``dispatches_per_step`` and ``host_syncs_per_step``.
* **TP=2** — under a 2-device mesh (CI forces host devices) the fused engine
  still matches the single-device legacy engine.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InferenceEngine, RequestState

# shared leading prefix (prefix-cache hits) + repetitive tails (real ngram
# drafts) + one short prompt (admission churn)
SHARED = [11, 12, 13, 14, 15, 16, 17, 18]
PROMPTS = [
    SHARED + [7, 3, 9, 4] * 3 + [5],
    SHARED + [5, 9, 12, 5, 9, 12, 2],
    SHARED + [21, 22, 23, 24],
    [30, 31],
]


def _make(arch, window=0):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    if window:
        cfg = cfg.replace(sliding_window=window)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _engine(cfg, params, *, fused, **kw):
    base = dict(
        max_batch=2, max_seq=64, block_size=8, cache_dtype=jnp.float32,
        prefill_budget=8, fused=fused,
    )
    base.update(kw)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return InferenceEngine(cfg, params, **base)


def _drain(eng, prompts=PROMPTS, max_new=6):
    reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run_until_drained()
    return [list(r.generated) for r in reqs]


# ---------------------------------------------------------------------------
# equivalence matrix: family x backend x prefix x spec mode
# ---------------------------------------------------------------------------

# arch, sliding window, attention impl, extra engine knobs
FUSED_CASES = [
    ("olmo-1b", 0, "xla", {}),
    ("olmo-1b", 0, "pallas", {}),
    ("olmo-1b", 0, "xla", dict(prefix_cache=True)),
    ("olmo-1b", 0, "xla", dict(prefix_cache=True, spec_decode="ngram", spec_k=3)),
    ("olmo-1b", 0, "pallas", dict(spec_decode="ngram", spec_k=3)),
    ("olmo-1b", 0, "xla", dict(spec_decode="draft", spec_k=3)),
    ("olmo-1b", 8, "xla", dict(spec_decode="ngram", spec_k=3)),  # window+rollback
    ("qwen3-moe-235b-a22b", 0, "xla", {}),
    ("qwen3-moe-235b-a22b", 0, "xla", dict(spec_decode="ngram", spec_k=3)),
]


@pytest.mark.parametrize("arch,window,impl,kw", FUSED_CASES)
def test_fused_token_identical_to_legacy(arch, window, impl, kw):
    cfg, params = _make(arch, window)
    if kw.get("spec_decode") == "draft":
        # self-drafting: maximal acceptance, commit/rollback runs hot
        kw = dict(kw, draft_cfg=cfg, draft_params=params)
    runs = {}
    for fused in (False, True):
        eng = _engine(cfg, params, fused=fused, attn_impl=impl, **kw)
        runs[fused] = (_drain(eng), eng.stats())
        assert eng.allocator is None or eng.allocator.blocks_in_use == 0
    (base, bs), (out, fs) = runs[False], runs[True]
    assert out == base, f"{arch}/w{window}/{impl}/{kw}: fused changed greedy tokens"
    for rate in ("prefix_hit_rate", "acceptance_rate"):
        if rate in bs:
            assert fs[rate] == bs[rate], f"{rate} drifted under fusion"
    assert fs["fused"] and not bs["fused"]


def test_fused_fewer_dispatches_and_syncs():
    """The refactor's contract: one dispatch and one host sync per tick."""
    cfg, params = _make("olmo-1b")
    stats = {}
    for fused in (False, True):
        eng = _engine(cfg, params, fused=fused)
        _drain(eng)
        stats[fused] = eng.stats()
    assert stats[True]["dispatches_per_step"] < stats[False]["dispatches_per_step"]
    assert stats[True]["host_syncs_per_step"] <= stats[False]["host_syncs_per_step"]
    # fused mixed/decode ticks each dispatch exactly once; the budget walk's
    # per-chunk dispatches are gone, so the mean sits at ~1 per decode step
    assert stats[True]["dispatches_per_step"] <= 1.5


def test_fused_requires_chunked_prefill():
    """The unified row batch is built from chunked-prefill machinery: a
    dense (non-paged) cache can't chunk, so ``fused=True`` must refuse."""
    cfg, params = _make("olmo-1b")
    with pytest.raises(ValueError, match="fused"):
        InferenceEngine(cfg, params, max_batch=2, max_seq=64, fused=True,
                        cache_kind="dense")


# ---------------------------------------------------------------------------
# mixed batches: chunks + decodes (+ verify windows) share one dispatch
# ---------------------------------------------------------------------------


def _staggered(eng):
    """Admit one request, decode it a few ticks, then pile on the rest: with
    prefill_budget=4 the later prompts chunk across several ticks while the
    first request keeps decoding — every mixed row-kind combination shows up."""
    rs = [eng.submit(PROMPTS[0], max_new_tokens=8)]
    for _ in range(3):
        eng.step()
    rs += [eng.submit(p, max_new_tokens=8) for p in PROMPTS[1:]]
    eng.run_until_drained()
    assert all(r.state == RequestState.DONE for r in rs)
    return [list(r.generated) for r in rs]


@pytest.mark.parametrize("kw", [{}, dict(spec_decode="ngram", spec_k=3)])
def test_fused_mixed_batches_match_legacy(kw):
    cfg, params = _make("olmo-1b")
    outs = {}
    for fused in (False, True):
        eng = _engine(cfg, params, fused=fused, max_batch=3, prefill_budget=4, **kw)
        outs[fused] = _staggered(eng)
    assert outs[True] == outs[False], f"mixed-batch fusion drifted ({kw})"


# ---------------------------------------------------------------------------
# preemption + restore-queue interleave
# ---------------------------------------------------------------------------


def test_fused_mid_step_preemption_token_identical():
    """A high-priority arrival preempts a decoding victim between fused
    ticks; the victim resumes (re-prefills via chunk rows) and both engines
    agree on every request's tokens."""
    cfg, params = _make("olmo-1b")
    outs = {}
    for fused in (False, True):
        eng = _engine(cfg, params, fused=fused, max_batch=1, prefill_budget=4)
        low = eng.submit(PROMPTS[0], max_new_tokens=8)
        for _ in range(4):
            eng.step()
        assert low.state == RequestState.ACTIVE
        high = eng.submit([40, 41, 42], max_new_tokens=4, priority=5)
        eng.step()  # SLO preemption evicts the decoding victim
        assert low.state == RequestState.WAITING and low.preemptions == 1
        eng.run_until_drained()
        assert low.state == high.state == RequestState.DONE
        outs[fused] = (list(low.generated), list(high.generated))
        assert eng.allocator.blocks_in_use == 0
    assert outs[True] == outs[False], "preempt/resume drifted under fusion"


def test_fused_restore_queue_interleave():
    """Spill-tier swap-ins (restore queue) interleave with fused ticks: the
    restoring request is planned around until its blocks land, then decodes
    token-identically to the legacy engine, with real restores happening."""
    cfg, params = _make("olmo-1b")
    pre = list(range(2, 26))  # 3 full blocks @ bs 8
    outs = {}
    for fused in (False, True):
        eng = _engine(
            cfg, params, fused=fused, max_batch=1, num_blocks=12,
            prefill_budget=8, restore_budget=1, spill_bytes=1 << 20,
        )
        r0 = eng.submit(pre + [30], max_new_tokens=4)
        eng.run_until_drained()
        blks = eng.allocator.alloc(eng.allocator.num_free)  # churn: spill chain
        eng.allocator.free(blks)
        assert len(eng.spill) >= 3, "chain must be fully spilled"
        r1 = eng.submit(pre + [30], max_new_tokens=4)
        eng.run_until_drained()
        s = eng.stats()
        assert s["restores"] > 0 and s["restores_pending"] == 0
        assert r1.generated == r0.generated, "spill-hit decode diverged"
        outs[fused] = list(r1.generated)
        assert eng.allocator.blocks_in_use == 0
    assert outs[True] == outs[False]


# ---------------------------------------------------------------------------
# TP=2 (runs under the CI fused-step lane's forced 2-device CPU)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)
@pytest.mark.parametrize("kw", [{}, dict(spec_decode="ngram", spec_k=3)])
def test_fused_tp2_token_identical(kw):
    from repro.launch.mesh import make_serving_mesh

    cfg, params = _make("olmo-1b")
    base_eng = _engine(cfg, params, fused=False)
    base = _drain(base_eng)
    eng = _engine(cfg, params, fused=True, mesh=make_serving_mesh(2), **kw)
    out = _drain(eng)
    assert out == base, f"fused TP=2 changed greedy tokens ({kw})"
