"""Pallas kernel validation: interpret-mode allclose vs pure-jnp oracles,
sweeping shapes, dtypes, and feature flags (assignment deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    flash_attention,
    stream_add,
    stream_bytes,
    stream_copy,
    stream_dot,
    stream_mul,
    stream_triad,
    wkv6,
)
from repro.kernels.babelstream_ref import add_ref, copy_ref, dot_ref, mul_ref, triad_ref
from repro.kernels.flash_attention_ref import attention_ref
from repro.kernels.rwkv6_scan_ref import wkv6_ref


# ---------------------------------------------------------------------------
# flash attention: shape/dtype/flag sweep
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Skv, H, KV, hd, causal, window, softcap, dtype
    (2, 256, 256, 4, 2, 64, True, 0, 0.0, jnp.float32),
    (1, 128, 128, 4, 4, 32, True, 0, 0.0, jnp.bfloat16),
    (2, 256, 256, 8, 2, 64, True, 64, 0.0, jnp.float32),  # sliding window
    (1, 256, 256, 2, 2, 128, True, 0, 30.0, jnp.float32),  # gemma softcap
    (1, 128, 128, 4, 2, 256, False, 0, 0.0, jnp.float32),  # encoder, hd=256
    (1, 384, 384, 2, 1, 64, True, 0, 0.0, jnp.float32),  # MQA, 3 blocks
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_oracle(case):
    B, Sq, Skv, H, KV, hd, causal, win, cap, dt = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dt)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd), dt)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd), dt)
    out = flash_attention(q, k, v, causal=causal, window=win, softcap=cap)
    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    ref = attention_ref(qt, kt, vt, causal=causal, window=win, softcap=cap).transpose(0, 2, 1, 3)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < tol, f"{case}: err={err}"


# ---------------------------------------------------------------------------
# babelstream
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [65_536, 262_144])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_babelstream_kernels(n, dtype):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n,), dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype)
    c = jax.random.normal(jax.random.fold_in(key, 2), (n,), dtype)
    np.testing.assert_allclose(stream_copy(a), copy_ref(a), rtol=0)
    np.testing.assert_allclose(
        np.asarray(stream_mul(c), np.float32), np.asarray(mul_ref(c), np.float32), rtol=1e-2
    )
    np.testing.assert_allclose(
        np.asarray(stream_add(a, b), np.float32), np.asarray(add_ref(a, b), np.float32), rtol=1e-2
    )
    np.testing.assert_allclose(
        np.asarray(stream_triad(b, c), np.float32), np.asarray(triad_ref(b, c), np.float32), rtol=1e-2
    )
    # dot accumulates in f32 for both paths
    assert abs(float(stream_dot(a, b)) - float(dot_ref(a, b))) < 1e-2 * n**0.5


def test_stream_bytes_convention():
    assert stream_bytes("copy", 1000, 4) == 8000
    assert stream_bytes("triad", 1000, 4) == 12000


# ---------------------------------------------------------------------------
# rwkv6 wkv scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(2, 128, 3, 16), (1, 64, 2, 32), (1, 256, 1, 64)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_matches_sequential_oracle(shape, chunk):
    B, S, H, n = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, S, H, n)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, n)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, n)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, n)) - 1.0)
    u = jax.random.normal(ks[4], (H, n)) * 0.3
    out = wkv6(r, k, v, logw, u, chunk=chunk)

    rb = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, n)
    ub = jnp.broadcast_to(u[None], (B, H, n)).reshape(B * H, n)
    ref = wkv6_ref(rb(r), rb(k), rb(v), rb(logw), ub).reshape(B, H, S, n).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-4, f"{shape} chunk={chunk}: err={err}"


def test_wkv6_fast_decay_stability():
    """Fast decay (logw very negative) must not produce inf/nan."""
    B, S, H, n = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    r = jax.random.normal(ks[0], (B, S, H, n))
    k = jax.random.normal(ks[1], (B, S, H, n))
    v = jax.random.normal(ks[2], (B, S, H, n))
    logw = jnp.full((B, S, H, n), -8.0)  # extremely fast decay
    u = jax.random.normal(ks[3], (H, n))
    out = wkv6(r, k, v, logw, u, chunk=16)
    assert np.isfinite(np.asarray(out)).all()
