"""Sharding rule engine: divisibility fallbacks, axis-conflict resolution.

Uses a stub mesh (only ``.shape`` is consulted by ``spec_for``), so the
production 16x16 geometry is tested without 256 devices.
"""

from dataclasses import dataclass

import jax
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests skip cleanly without it
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.config import MeshConfig, ParallelConfig
from repro.configs import get_config
from repro.models import abstract_params, param_logical_axes
from repro.parallel import make_rules


@dataclass
class StubMesh:
    shape: dict


MESH = StubMesh({"data": 16, "model": 16})
MESH_MULTI = StubMesh({"pod": 2, "data": 16, "model": 16})


def rules(multi=False, **kw):
    return make_rules(MeshConfig(multi_pod=multi), ParallelConfig(**kw))


class Leaf:
    def __init__(self, shape):
        self.shape = shape


def test_ffn_weight_tp_and_fsdp():
    r = rules()
    spec = r.spec_for(("embed", "mlp"), (4096, 14336), MESH, r.param_rules())
    assert spec == P(("data",), "model")


def test_multi_pod_fsdp_uses_both_axes():
    r = rules(multi=True)
    spec = r.spec_for(("embed", "mlp"), (4096, 14336), MESH_MULTI, r.param_rules())
    assert spec == P(("pod", "data"), "model")


def test_odd_head_count_falls_back_to_head_dim():
    """hymba: 25 heads don't divide 16 -> head_dim takes the model axis
    (contraction over head_dim psums cheaply), embed keeps FSDP."""
    r = rules()
    spec = r.spec_for(("embed", "heads", "head_dim"), (1600, 25, 64), MESH, r.param_rules())
    assert spec == P(("data",), None, "model")


def test_arctic_56_heads_fall_back():
    r = rules()
    spec = r.spec_for(("embed", "heads", "head_dim"), (7168, 56, 128), MESH, r.param_rules())
    assert spec == P(("data",), None, "model")


def test_expert_dim_gets_model_axis():
    r = rules()
    spec = r.spec_for(("expert", "embed", "expert_mlp"), (128, 7168, 4864), MESH, r.param_rules())
    # expert wins "model" (first come), embed takes FSDP, expert_mlp replicated
    assert spec == P("model", ("data",), None)


def test_no_mesh_axis_used_twice_per_tensor():
    r = rules()
    for arch in ["arctic-480b", "hymba-1.5b", "qwen3-moe-235b-a22b"]:
        cfg = get_config(arch)
        axes_tree = param_logical_axes(cfg)
        params = abstract_params(cfg)
        flat_axes = jax.tree.leaves(
            axes_tree, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
        )
        flat_leaves = jax.tree.leaves(params)
        for axes, leaf in zip(flat_axes, flat_leaves):
            spec = r.spec_for(axes, leaf.shape, MESH, r.param_rules())
            used = []
            for entry in spec:
                if entry is None:
                    continue
                axs = entry if isinstance(entry, tuple) else (entry,)
                used.extend(axs)
            assert len(used) == len(set(used)), f"{arch}: {axes} -> {spec}"


def test_vocab_tables_never_fsdp_on_embed_dim():
    cfg = get_config("mistral-nemo-12b")
    r = rules()
    spec = r.spec_for(("vocab", "embed_v"), (cfg.padded_vocab, cfg.d_model), MESH, r.param_rules())
    assert spec == P("model", None)


def test_cache_kv_head_fallback_to_sequence():
    """GQA kv=8 cannot shard over model=16 -> the kv_seq dim takes it."""
    r = rules()
    spec = r.spec_for(
        ("layers", "kv_batch", "kv_seq", "kv_heads", None), (40, 128, 32768, 8, 128), MESH, r.cache_rules()
    )
    assert spec == P(None, ("data",), "model", None, None)


def test_kv_heads_preferred_when_divisible():
    r = rules()
    spec = r.spec_for(
        ("layers", "kv_batch", "kv_seq", "kv_heads", None), (28, 128, 32768, 16, 256), MESH, r.cache_rules()
    )
    assert spec == P(None, ("data",), None, "model", None)


@settings(max_examples=200, deadline=None)
@given(
    dims=st.lists(st.integers(min_value=1, max_value=8192), min_size=1, max_size=4),
    axes=st.lists(
        st.sampled_from(["embed", "mlp", "heads", "kv_heads", "vocab", "expert", "batch", None]),
        min_size=1,
        max_size=4,
    ),
)
def test_spec_engine_invariants(dims, axes):
    """Property: every produced spec (a) only shards divisible dims,
    (b) never reuses a mesh axis within one tensor."""
    n = min(len(dims), len(axes))
    dims, axes = tuple(dims[:n]), tuple(axes[:n])
    r = rules()
    spec = r.spec_for(axes, dims, MESH, r.param_rules())
    used = []
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        axs = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axs:
            size *= MESH.shape[a]
        assert dim % size == 0, f"dim {dim} sharded by {size}"
        used.extend(axs)
    assert len(used) == len(set(used))
