"""Sequence packing: concatenate documents into fixed-length rows.

Packing removes pad waste (the difference between 40% and 95%+ token
efficiency on real corpora).  Cross-document attention is prevented by the
``positions`` array resetting at each document boundary — the model's RoPE
and causal mask consume positions directly, so a packed row behaves like
independent documents (segment-mask variant of T5/LLaMA packing).
"""

from __future__ import annotations

import numpy as np


def pack_sequences(docs: list[np.ndarray], seq_len: int, *, pad_id: int = 0):
    """Greedy first-fit packing.

    Returns (tokens (N, seq_len) int32, positions (N, seq_len) int32,
    segment_ids (N, seq_len) int32 — 0 = padding).
    """
    rows: list[list[np.ndarray]] = []
    space: list[int] = []
    for d in docs:
        d = np.asarray(d, np.int32)[:seq_len]
        placed = False
        for i, s in enumerate(space):
            if len(d) <= s:
                rows[i].append(d)
                space[i] -= len(d)
                placed = True
                break
        if not placed:
            rows.append([d])
            space.append(seq_len - len(d))

    n = len(rows)
    tokens = np.full((n, seq_len), pad_id, np.int32)
    positions = np.zeros((n, seq_len), np.int32)
    segments = np.zeros((n, seq_len), np.int32)
    for i, row in enumerate(rows):
        off = 0
        for j, d in enumerate(row, start=1):
            tokens[i, off : off + len(d)] = d
            positions[i, off : off + len(d)] = np.arange(len(d))
            segments[i, off : off + len(d)] = j
            off += len(d)
    return tokens, positions, segments


def packing_efficiency(segments: np.ndarray) -> float:
    return float((segments > 0).mean())
