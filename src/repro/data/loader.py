"""Host-side sharded data loader with prefetch.

Each host feeds its slice of the global batch (standard multi-host JAX input
pipeline): the loader yields per-host shards keyed by (step, host_id) so all
hosts stay deterministic and replay-identical after a flex-start restore.
A small background-thread prefetch queue hides host-side generation latency
behind device compute (the training/storage overlap the paper's Lustre tier
is sized for).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional


class ShardedLoader:
    def __init__(
        self,
        batch_fn: Callable[[int], dict],  # global step -> GLOBAL batch
        *,
        host_id: int = 0,
        num_hosts: int = 1,
        prefetch: int = 2,
    ):
        self.batch_fn = batch_fn
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------
    def host_shard(self, batch: dict) -> dict:
        """This host's contiguous slice of the global batch."""

        def shard(x):
            b = x.shape[0]
            per = b // self.num_hosts
            lo = self.host_id * per
            return x[lo : lo + per]

        import jax

        return jax.tree.map(shard, batch)

    def get(self, step: int) -> dict:
        return self.host_shard(self.batch_fn(step))

    # ------------------------------------------------------------------
    def iterate(self, start_step: int, num_steps: int) -> Iterator[tuple[int, dict]]:
        """Prefetching iterator over [start_step, start_step + num_steps)."""
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        self._stop.clear()

        def producer():
            for s in range(start_step, start_step + num_steps):
                if self._stop.is_set():
                    return
                q.put((s, self.get(s)))
            q.put(None)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        self._thread = t
        try:
            while True:
                item = q.get()
                if item is None:
                    return
                yield item
        finally:
            self._stop.set()

    def close(self) -> None:
        self._stop.set()
