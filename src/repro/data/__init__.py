from repro.data.synthetic import make_batch_fn, synthetic_batch
from repro.data.loader import ShardedLoader
from repro.data.packing import pack_sequences

__all__ = ["make_batch_fn", "synthetic_batch", "ShardedLoader", "pack_sequences"]
