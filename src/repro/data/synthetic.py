"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step) — the property the flex-start
fault-tolerance story depends on: after a rollback to step k, replaying steps
k..n yields bit-identical batches, so recovery is exactly reproducible (the
paper's "guaranteed completion" without loss-curve drift).

The token stream is Zipf-like over the vocabulary with a shifting Markov
flavor so losses actually decrease during smoke training (pure uniform noise
would pin CE at log V).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def synthetic_batch(cfg, *, step: int, global_batch: int, seq_len: int, seed: int = 0) -> dict:
    """One training batch for any architecture family."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k_tok, k_img, k_frame = jax.random.split(key, 3)
    batch: dict = {}
    if cfg.family == "audio":
        frames = jax.random.normal(k_frame, (global_batch, seq_len, cfg.d_model), jnp.float32)
        batch["frames"] = frames
        # pseudo cluster targets correlated with the frames (learnable)
        labels = jnp.argmax(frames[..., : cfg.vocab_size], axis=-1) % cfg.vocab_size
        batch["labels"] = labels.astype(jnp.int32)
        return batch

    # Zipf-ish marginals + local structure: next token depends on previous
    V = cfg.vocab_size
    ranks = jnp.arange(V, dtype=jnp.float32) + 1.0
    logits = -1.2 * jnp.log(ranks)
    base = jax.random.categorical(k_tok, logits, shape=(global_batch, seq_len))
    shift = jnp.roll(base, 1, axis=1) * 31 % V
    mix = jax.random.bernoulli(k_tok, 0.3, (global_batch, seq_len))
    tokens = jnp.where(mix, shift, base).astype(jnp.int32)
    batch["tokens"] = tokens
    batch["labels"] = jnp.roll(tokens, -1, axis=1)
    if cfg.family == "vlm":
        batch["vision_tokens"] = jax.random.normal(
            k_img, (global_batch, cfg.vision.num_image_tokens, cfg.d_model), jnp.float32
        )
    return batch


def make_batch_fn(cfg, *, global_batch: int, seq_len: int, seed: int = 0):
    """step -> batch closure (jit-compiled, deterministic)."""

    @partial(jax.jit, static_argnums=())
    def _gen(step):
        return synthetic_batch(cfg, step=0, global_batch=global_batch, seq_len=seq_len, seed=seed)

    # fold the step in python (jit caches the generator body per shape)
    def batch_fn(step: int) -> dict:
        return synthetic_batch(cfg, step=step, global_batch=global_batch, seq_len=seq_len, seed=seed)

    return batch_fn
