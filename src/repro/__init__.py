"""repro — an Isambard-AI-class AI-platform stack in JAX.

Reproduction of "Isambard-AI: a leadership class supercomputer optimised
specifically for Artificial Intelligence" (McIntosh-Smith, Alam, Woods; 2024),
adapted to TPU v5e pods.  See DESIGN.md for the paper-to-system mapping.
"""

__version__ = "1.0.0"
