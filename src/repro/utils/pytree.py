"""Path-aware pytree utilities.

The whole framework represents parameters, optimizer state and caches as plain
nested dicts.  These helpers give every leaf a stable ``"a/b/c"`` path string,
which the sharding rule engine (``repro.parallel.sharding``) and the checkpoint
layer key off.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def _key_str(k) -> str:
    """Render one jax tree key entry as a plain string."""
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    if isinstance(k, jax.tree_util.FlattenedIndexKey):
        return str(k.key)
    return str(k)


def path_str(path) -> str:
    return "/".join(_key_str(k) for k in path)


def tree_paths(tree: Any) -> list[str]:
    """All leaf paths of ``tree`` in flatten order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [path_str(p) for p, _ in leaves]


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any, *rest: Any) -> Any:
    """``jax.tree.map`` where ``fn`` receives the ``"a/b/c"`` leaf path first."""

    def wrapper(path, leaf, *others):
        return fn(path_str(path), leaf, *others)

    return jax.tree_util.tree_map_with_path(wrapper, tree, *rest)


def flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(path_str(p), v) for p, v in leaves]


def _leaf_nbytes(x: Any) -> int:
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all array-like leaves (works on ShapeDtypeStructs too)."""
    return sum(_leaf_nbytes(x) for x in jax.tree_util.tree_leaves(tree))


def tree_param_count(tree: Any) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        shape = getattr(x, "shape", None)
        if shape is not None:
            total += int(np.prod(shape, dtype=np.int64))
    return total
