from repro.utils.pytree import (
    tree_paths,
    tree_map_with_path,
    flatten_with_paths,
    tree_size_bytes,
    tree_param_count,
)
from repro.utils.registry import Registry

__all__ = [
    "tree_paths",
    "tree_map_with_path",
    "flatten_with_paths",
    "tree_size_bytes",
    "tree_param_count",
    "Registry",
]
