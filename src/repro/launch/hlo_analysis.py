"""Compiled-HLO analysis: collective byte counting + roofline term extraction.

``cost_analysis()`` on the CPU backend counts a ``while`` body ONCE (verified
empirically), and collectives inside scan-over-layers loops would be equally
undercounted by a flat text scan.  So the collective parser here builds the
HLO *computation call graph*, parses each while loop's trip count from its
condition computation, and multiplies collective bytes accordingly.

Per-device FLOPs / HBM bytes for the roofline come from the jaxpr cost model
(``repro.launch.jaxpr_cost``); raw ``cost_analysis()`` numbers are recorded
alongside as single-iteration lower bounds.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?P<result>\(?[a-z0-9_]+\[[^=]*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# NOTE: while-loop bodies take TUPLE params — the arg list contains nested
# parens, so the match must be greedy up to the final "->".
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)")
_WHILE_RE2 = re.compile(r"while\(.*?\)[^\n]*?body=%?([\w\.\-]+)[^\n]*?condition=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def shape_bytes(text: str) -> int:
    """Sum of array bytes in an HLO result/operand type string."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form: [num_groups,group_size]<=[...]
        return int(m.group(2))
    return 1


@dataclass
class CollectiveStats:
    count: dict = field(default_factory=dict)  # kind -> #executions (trip-scaled)
    operand_bytes: dict = field(default_factory=dict)  # kind -> per-device operand bytes
    wire_bytes: dict = field(default_factory=dict)  # kind -> modeled ring wire bytes
    trips: dict = field(default_factory=dict)  # while body comp -> trip count

    @property
    def total_operand_bytes(self) -> float:
        return float(sum(self.operand_bytes.values()))

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    def as_dict(self) -> dict:
        return {
            "count": self.count,
            "operand_bytes": self.operand_bytes,
            "wire_bytes": self.wire_bytes,
            "total_operand_bytes": self.total_operand_bytes,
            "total_wire_bytes": self.total_wire_bytes,
        }


def _parse_computations(hlo: str):
    """name -> list of op lines; also returns the ENTRY computation name."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur: list[str] | None = None
    for line in hlo.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m:
            name = m.group(2)
            comps[name] = cur = []
            if m.group(1):
                entry = name
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                cur.append(line)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    """Heuristic: the loop bound is the max integer constant in the condition."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def collective_stats(hlo_text: str) -> CollectiveStats:
    comps, entry = _parse_computations(hlo_text)
    st = CollectiveStats()
    if entry is None:  # fallback: flat scan
        entry_lines = hlo_text.splitlines()
        comps = {"__all__": entry_lines}
        entry = "__all__"

    memo: dict[str, dict] = {}

    def visit(name: str) -> dict:
        """Returns {kind: (count, operand_bytes, wire_bytes)} aggregated."""
        if name in memo:
            return memo[name]
        agg: dict[str, list[float]] = {}
        memo[name] = agg  # pre-insert (cycles shouldn't occur)
        for line in comps.get(name, ()):  # direct collectives
            m = _COLL_RE.search(line)
            if m:
                kind = m.group("kind")
                b = shape_bytes(m.group("result"))
                n = _group_size(line)
                if kind == "all-reduce":
                    w = 2.0 * (n - 1) / max(n, 1) * b
                elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
                    w = (n - 1) / max(n, 1) * b
                else:
                    w = float(b)
                e = agg.setdefault(kind, [0.0, 0.0, 0.0])
                e[0] += 1
                e[1] += b
                e[2] += w
            # call edges
            wm = _WHILE_RE.search(line) or _WHILE_RE2.search(line)
            if wm:
                if _WHILE_RE.search(line):
                    cond, body = wm.group(1), wm.group(2)
                else:
                    body, cond = wm.group(1), wm.group(2)
                trip = _trip_count(comps.get(cond, []))
                st.trips[body] = trip
                sub = visit(body)
                for k, (c, ob, wb) in sub.items():
                    e = agg.setdefault(k, [0.0, 0.0, 0.0])
                    e[0] += trip * c
                    e[1] += trip * ob
                    e[2] += trip * wb
                continue
            bm = _BRANCHES_RE.search(line)
            if bm:
                for br in bm.group(1).split(","):
                    sub = visit(br.strip().lstrip("%"))
                    for k, (c, ob, wb) in sub.items():
                        e = agg.setdefault(k, [0.0, 0.0, 0.0])
                        e[0] += c
                        e[1] += ob
                        e[2] += wb
                continue
            cm = _CALL_RE.search(line)
            if cm and not _COLL_RE.search(line):  # skip reducer regions of collectives
                sub = visit(cm.group(1))
                for k, (c, ob, wb) in sub.items():
                    e = agg.setdefault(k, [0.0, 0.0, 0.0])
                    e[0] += c
                    e[1] += ob
                    e[2] += wb
        return agg

    agg = visit(entry)
    for k, (c, ob, wb) in agg.items():
        st.count[k] = c
        st.operand_bytes[k] = ob
        st.wire_bytes[k] = wb
    return st


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e constants from the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
# FP8 matmul peak: 2x bf16, the GH200-class ratio behind Isambard-AI's
# "21 ExaFLOP/s of 8-bit floating point" headline (arXiv:2410.11199 §1).
PEAK_FLOPS_FP8 = 394e12
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def peak_flops(fp8: bool = False) -> float:
    """Per-chip matmul peak for the run's GEMM precision (fp8 doubles it)."""
    return PEAK_FLOPS_FP8 if fp8 else PEAK_FLOPS


def roofline_terms(
    per_device_flops: float,
    per_device_bytes: float,
    collective_operand_bytes: float,
    *,
    fp8: bool = False,
) -> dict:
    """The assignment's three terms, in seconds (all quantities per device,
    equivalent to global quantities divided by chip count).  ``fp8`` runs are
    costed against the doubled 8-bit matmul peak."""
    return {
        "compute_s": per_device_flops / peak_flops(fp8),
        "memory_s": per_device_bytes / HBM_BW,
        "collective_s": collective_operand_bytes / ICI_BW,
    }


def dominant_term(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])


def extract_cost(compiled) -> tuple[float, float]:
    """(per-device flops, per-device HBM bytes) from compiled.cost_analysis().

    NOTE: while-loop bodies are counted ONCE by XLA — these are recorded as
    reference lower bounds; the roofline uses the jaxpr cost model.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax: one properties dict per device
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return flops, byts


def memory_stats(compiled) -> dict:
    ms = compiled.memory_analysis()
    return {
        "argument_bytes": int(ms.argument_size_in_bytes),
        "output_bytes": int(ms.output_size_in_bytes),
        "temp_bytes": int(ms.temp_size_in_bytes),
        "alias_bytes": int(ms.alias_size_in_bytes),
        "peak_estimate_bytes": int(
            ms.argument_size_in_bytes
            + ms.output_size_in_bytes
            + ms.temp_size_in_bytes
            - ms.alias_size_in_bytes
        ),
    }
