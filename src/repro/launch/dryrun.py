import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh) cell.

For each runnable cell this lowers the real ``train_step`` / ``prefill`` /
``decode_step`` with full-size ShapeDtypeStruct inputs and the production
sharding trees, compiles it for 256 (single-pod 16x16) or 512 (multi-pod
2x16x16) host devices, and records:

* ``memory_analysis()``  — proves the per-device footprint,
* ``cost_analysis()``    — per-device FLOPs / HBM bytes for §Roofline,
* parsed collective operand/wire bytes from the partitioned HLO.

Results are written incrementally to ``benchmarks/results/dryrun_<mesh>.json``
so interrupted sweeps resume.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch all
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --arch rwkv6-7b --shape train_4k
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, SHAPES
from repro.configs import ASSIGNED, get_config
from repro.launch.hlo_analysis import (
    collective_stats,
    dominant_term,
    extract_cost,
    memory_stats,
    roofline_terms,
)
from repro.launch.jaxpr_cost import estimate_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    PREFILL_Q_CHUNK,
    TRAIN_KNOBS,
    CellKnobs,
    cell_status,
    decode_input_specs,
    prefill_input_specs,
    run_config_for,
    train_input_specs,
)
from repro.models import abstract_cache, decode_step, prefill
from repro.models.cache import raw_cache_axes
from repro.parallel import make_rules
from repro.train.step import abstract_train_state, make_train_step, state_shardings

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"


# ---------------------------------------------------------------------------
# per-kind lowering builders
# ---------------------------------------------------------------------------


def _batch_shardings(specs: dict, mesh, mesh_cfg: MeshConfig):
    data = tuple(mesh_cfg.data_axes)

    def one(s):
        if s.shape and s.shape[0] % _size(mesh, data) == 0:
            return NamedSharding(mesh, P(data, *([None] * (len(s.shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, specs)


def _size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def lower_train(arch, shape_name, mesh_cfg, mesh, knobs=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    knobs = knobs or TRAIN_KNOBS.get(arch, CellKnobs())
    run = run_config_for(arch, shape, mesh_cfg, knobs)
    rules = make_rules(mesh_cfg, run.parallel)
    astate = abstract_train_state(cfg, run)
    st_sh = state_shardings(cfg, run, rules, mesh, astate)
    batch = train_input_specs(cfg, shape)
    b_sh = _batch_shardings(batch, mesh, mesh_cfg)
    step = make_train_step(
        cfg, run, rules, mesh, q_chunk=knobs.q_chunk, param_shardings=st_sh.params
    )
    rep = NamedSharding(mesh, P())
    metrics_sh = {"loss": rep, "aux_loss": rep, "lr": rep, "grad_norm": rep}
    with mesh:
        lowered = jax.jit(
            step,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, metrics_sh),
            donate_argnums=(0,),
        ).lower(astate, batch)
    return lowered, cfg, run, step, (astate, batch)


def lower_prefill(arch, shape_name, mesh_cfg, mesh, knobs=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    knobs = knobs or CellKnobs()
    run = run_config_for(arch, shape, mesh_cfg, knobs)
    rules = make_rules(mesh_cfg, run.parallel)
    sh = rules.make_sharder(mesh)
    from repro.models import abstract_params
    from repro.train.step import DTYPES

    params = abstract_params(cfg, DTYPES[run.precision.param_dtype])
    p_sh = rules.param_shardings(cfg, mesh, params)
    batch = prefill_input_specs(cfg, shape)
    b_sh = _batch_shardings(batch, mesh, mesh_cfg)

    if cfg.is_encoder_only:
        # encoder "prefill" = batched forward inference (no cache exists)
        from repro.models import forward

        def fn(p, b):
            return forward(cfg, p, b, sh=sh, q_chunk=PREFILL_Q_CHUNK)[0]

        out_struct = jax.eval_shape(fn, params, batch)
        out_sh = NamedSharding(
            mesh, rules.spec_for(("batch", "seq", "vocab"), out_struct.shape, mesh, rules.act_rules())
        )
    else:

        def fn(p, b):
            return prefill(cfg, p, b, sh=sh, q_chunk=PREFILL_Q_CHUNK)

        logits_struct, cache_struct = jax.eval_shape(fn, params, batch)
        lg_sh = NamedSharding(
            mesh, rules.spec_for(("batch", "vocab"), logits_struct.shape, mesh, rules.act_rules())
        )
        cache_sh = rules.tree_specs(raw_cache_axes(cfg), cache_struct, mesh, rules.cache_rules())
        cache_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s), cache_sh, is_leaf=lambda x: isinstance(x, P)
        )
        out_sh = (lg_sh, cache_sh)

    with mesh:
        lowered = jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=out_sh).lower(params, batch)
    return lowered, cfg, run, fn, (params, batch)


def lower_decode(arch, shape_name, mesh_cfg, mesh, knobs=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    knobs = knobs or CellKnobs()
    run = run_config_for(arch, shape, mesh_cfg, knobs)
    rules = make_rules(mesh_cfg, run.parallel)
    sh = rules.make_sharder(mesh)
    from repro.models import abstract_params
    from repro.train.step import DTYPES

    dtype = DTYPES[run.precision.param_dtype]
    params = abstract_params(cfg, dtype)
    p_sh = rules.param_shardings(cfg, mesh, params)
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len, dtype)
    c_sh = rules.cache_shardings(cfg, mesh, cache)
    inp = decode_input_specs(cfg, shape)
    i_sh = _batch_shardings(inp, mesh, mesh_cfg)

    def fn(p, c, token, pos):
        return decode_step(cfg, p, c, token, pos, sh=sh)

    logits_struct, _ = jax.eval_shape(fn, params, cache, inp["token"], inp["pos"])
    lg_sh = NamedSharding(
        mesh, rules.spec_for(("batch", "vocab"), logits_struct.shape, mesh, rules.act_rules())
    )
    with mesh:
        lowered = jax.jit(
            fn,
            in_shardings=(p_sh, c_sh, i_sh["token"], i_sh["pos"]),
            out_shardings=(lg_sh, c_sh),
            donate_argnums=(1,),
        ).lower(params, cache, inp["token"], inp["pos"])
    return lowered, cfg, run, fn, (params, cache, inp["token"], inp["pos"])


LOWERERS = {"train": lower_train, "prefill": lower_prefill, "decode": lower_decode}


# ---------------------------------------------------------------------------
# model-FLOPs reference (6ND convention) for the useful-compute ratio
# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_cfg: MeshConfig, mesh, knobs=None, verbose=True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    status = cell_status(cfg, shape_name)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if mesh_cfg.multi_pod else "single",
        "n_devices": mesh_cfg.num_devices,
        "status": status,
    }
    if status != "run":
        return rec
    kind = shape.kind
    t0 = time.time()
    lowered, cfg, run, cost_fn, cost_args = LOWERERS[kind](arch, shape_name, mesh_cfg, mesh, knobs)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    # global FLOPs / modeled HBM bytes from the jaxpr cost model (XLA's
    # cost_analysis counts while bodies once — recorded as reference only)
    est = estimate_cost(cost_fn, *cost_args)
    n_dev = mesh_cfg.num_devices
    flops = est["flops"] / n_dev
    byts = est["hbm_bytes"] / n_dev
    xla_flops, xla_bytes = extract_cost(compiled)
    hlo = compiled.as_text()
    colls = collective_stats(hlo)
    mem = memory_stats(compiled)
    # HLO is the SPMD-partitioned per-device module, so operand bytes are
    # already per-device — matching the per-device flops/bytes convention.
    # Only cost against the FP8 peak when the step actually ran FP8 AND the
    # quantized sites carry the dominant GEMM FLOPs (ssm/vlm fall back to
    # bf16; moe keeps routed expert FFNs bf16 — see repro.fp8.policy).
    from repro.fp8 import fp8_peak_applies

    is_fp8 = bool(run.precision.fp8) and fp8_peak_applies(cfg) and kind == "train"
    terms = roofline_terms(flops, byts, colls.total_operand_bytes, fp8=is_fp8)
    rec.update(
        {
            "fp8": is_fp8,
            "per_device_flops": flops,
            "per_device_hbm_bytes": byts,
            "xla_body_flops": xla_flops,
            "xla_body_bytes": xla_bytes,
            "collectives": colls.as_dict(),
            "memory": mem,
            "roofline": terms,
            "dominant": dominant_term(terms),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "model_flops": model_flops(cfg, shape),
            "useful_ratio": model_flops(cfg, shape) / max(flops * mesh_cfg.num_devices, 1.0),
            "hlo_size": len(hlo),
            "knobs": vars(knobs) if knobs and not isinstance(knobs, dict) else None,
        }
    )
    if verbose:
        print(f"  memory_analysis: {compiled.memory_analysis()}")
        print(f"  jaxpr cost (global): flops={est['flops']:.4g} hbm_bytes={est['hbm_bytes']:.4g}")
        print(f"  cost_analysis (per-iter lower bound): flops={xla_flops:.4g} bytes={xla_bytes:.4g}")
        print(
            f"  collectives: { {k: f'{v/1e6:.1f}MB' for k, v in colls.operand_bytes.items()} }"
        )
        print(
            f"  roofline: compute={terms['compute_s']*1e3:.2f}ms memory={terms['memory_s']*1e3:.2f}ms "
            f"collective={terms['collective_s']*1e3:.2f}ms dominant={rec['dominant']}"
        )
    return rec


# ---------------------------------------------------------------------------
# sweep driver with incremental JSON persistence
# ---------------------------------------------------------------------------


def load_results(mesh_name: str) -> dict:
    path = RESULTS_DIR / f"dryrun_{mesh_name}.json"
    if path.exists():
        return json.loads(path.read_text())
    return {}


def save_results(mesh_name: str, results: dict) -> None:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / f"dryrun_{mesh_name}.json"
    path.write_text(json.dumps(results, indent=1, sort_keys=True))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--arch", default="all", help="'all' or comma-separated arch ids")
    ap.add_argument("--shape", default="all", help="'all' or comma-separated shape names")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument(
        "--fp8", action="store_true", help="lower train cells with FP8 quantized training enabled"
    )
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for mesh_name in meshes:
        mesh_cfg = MeshConfig(multi_pod=(mesh_name == "multi"))
        mesh = make_production_mesh(multi_pod=mesh_cfg.multi_pod)
        results = load_results(mesh_name)
        for arch in archs:
            for shape_name in shapes:
                # fp8 cells get their own cache rows so a sweep can hold both
                # precisions side by side (rec carries an "fp8" field too)
                key = f"{arch}|{shape_name}" + ("|fp8" if args.fp8 else "")
                if key in results and not args.force and "error" not in results[key]:
                    print(f"[{mesh_name}] {key}: cached ({results[key]['status']})")
                    continue
                print(f"[{mesh_name}] {key}: lowering...", flush=True)
                knobs = None
                if args.fp8:
                    import dataclasses

                    from repro.launch.specs import TRAIN_KNOBS, CellKnobs

                    knobs = dataclasses.replace(
                        TRAIN_KNOBS.get(arch, CellKnobs()), fp8=True
                    )
                try:
                    rec = run_cell(arch, shape_name, mesh_cfg, mesh, knobs)
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    rec = {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": mesh_name,
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                    }
                results[key] = rec
                save_results(mesh_name, results)
                status = rec["status"]
                extra = (
                    f" lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s dom={rec.get('dominant')}"
                    if status == "run"
                    else ""
                )
                print(f"[{mesh_name}] {key}: {status}{extra}", flush=True)

    # summary
    for mesh_name in meshes:
        results = load_results(mesh_name)
        ok = sum(1 for r in results.values() if r["status"] == "run" and "error" not in r)
        skip = sum(1 for r in results.values() if r["status"].startswith("skip"))
        err = sum(1 for r in results.values() if r["status"] == "error")
        print(f"[{mesh_name}] {ok} compiled, {skip} skipped (documented), {err} errors")


if __name__ == "__main__":
    main()
