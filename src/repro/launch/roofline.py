"""Roofline report generator: dry-run JSON -> EXPERIMENTS.md §Roofline table.

Definitions (per arch x shape cell, single-pod 256-chip mesh):

    compute_s     = global_FLOPs / (chips x 197e12)         [jaxpr cost model]
    memory_s      = global_HBM_bytes / (chips x 819e9)      [jaxpr byte model]
    collective_s  = per-device collective operand bytes / 50e9   [HLO parse]
    bound_s       = max of the three -> the dominant bottleneck
    model_time_s  = MODEL_FLOPS / (chips x 197e12), MODEL_FLOPS = 6·N·D
                    (2·N·D for inference kinds; N = active params for MoE)
    roofline_frac = model_time_s / bound_s   <- the §Perf score

``useful_ratio`` = MODEL_FLOPS / global_FLOPs exposes remat/attention/
dispatch overhead compute (the assignment's redundancy check).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch.hlo_analysis import peak_flops

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"
HBM_LIMIT = 16e9  # v5e HBM per chip

# one-sentence improvement note per dominant term (specialized per family)
NOTES = {
    ("memory_s", "train"): "cut HBM traffic: fuse attention (flash kernel), reuse gathered weights across microbatches",
    ("memory_s", "prefill"): "flash-attention fusion removes the S x S score traffic; keep KV in bf16",
    ("memory_s", "decode"): "KV-cache reads dominate: quantize KV to int8 or shard KV further (flash-decoding)",
    ("compute_s", "train"): "near compute roofline: reduce remat recompute (dots-saveable policy) to shed non-useful FLOPs",
    ("compute_s", "prefill"): "attention FLOPs dominate at 32k: sliding/block-sparse attention or chunked prefill",
    ("compute_s", "decode"): "matmul-bound decode: batch more requests per step (continuous batching)",
    ("collective_s", "train"): "overlap grad reduce-scatter with backward; compress cross-pod gradients",
    ("collective_s", "prefill"): "all-gather of sequence-parallel activations: overlap with per-layer compute",
    ("collective_s", "decode"): "per-layer TP all-reduce gates latency: widen TP grouping or duplicate small weights",
}


def load(mesh: str) -> dict:
    p = RESULTS_DIR / f"dryrun_{mesh}.json"
    return json.loads(p.read_text()) if p.exists() else {}


def cell_rows(results: dict) -> list[dict]:
    rows = []
    for key, r in sorted(results.items()):
        if r.get("status") != "run" or "roofline" not in r:
            rows.append(
                {
                    "arch": r["arch"],
                    "shape": r["shape"],
                    "status": r.get("status", "?"),
                }
            )
            continue
        t = r["roofline"]
        bound = max(t.values())
        chips = r["n_devices"]
        # fp8 cells are costed against the doubled 8-bit matmul peak
        model_time = r["model_flops"] / (chips * peak_flops(r.get("fp8", False)))
        kind = "train" if r["shape"].startswith("train") else ("prefill" if "prefill" in r["shape"] else "decode")
        rows.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "status": "ok",
                "kind": kind,
                "fp8": r.get("fp8", False),
                "params": r["params"],
                "active_params": r["active_params"],
                "compute_s": t["compute_s"],
                "memory_s": t["memory_s"],
                "collective_s": t["collective_s"],
                "dominant": r["dominant"],
                "model_flops": r["model_flops"],
                "useful_ratio": r["useful_ratio"],
                "roofline_frac": model_time / bound if bound > 0 else 0.0,
                "peak_gb": r["memory"]["peak_estimate_bytes"] / 1e9,
                "fits": r["memory"]["peak_estimate_bytes"] <= HBM_LIMIT,
                "note": NOTES.get((r["dominant"], kind), ""),
                "collectives": r.get("collectives", {}),
            }
        )
    return rows


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.2f}"


def markdown_table(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | N (act.) | compute ms | memory ms | coll. ms | dominant | "
        "6ND/HLO | roofline frac | peak GB/chip | fits 16GB | improvement lever |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    out = [hdr]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | — | — | {r['status']} |\n")
            continue
        ap = r["active_params"]
        n_str = f"{r['params']/1e9:.1f}B" + (f" ({ap/1e9:.1f}B)" if ap != r["params"] else "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {n_str} | {fmt_ms(r['compute_s'])} | {fmt_ms(r['memory_s'])} "
            f"| {fmt_ms(r['collective_s'])} | {r['dominant'].replace('_s','')} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_frac']:.1%} | {r['peak_gb']:.1f} | {'yes' if r['fits'] else 'NO'} | {r['note']} |\n"
        )
    return "".join(out)


def pick_hillclimb(rows: list[dict]) -> dict:
    ok = [r for r in rows if r.get("status") == "ok"]
    worst = min(ok, key=lambda r: r["roofline_frac"])
    coll = max(ok, key=lambda r: r["collective_s"] / max(max(r["compute_s"], r["memory_s"]), 1e-12))
    # most representative of the paper's technique: the large-scale MoE
    # training cell (the paper's raison d'être is frontier LLM training)
    rep = next(
        (r for r in ok if r["arch"] == "qwen3-moe-235b-a22b" and r["shape"] == "train_4k"),
        max(ok, key=lambda r: r["params"]),
    )
    return {"worst_fraction": worst, "most_collective_bound": coll, "most_representative": rep}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--pick", action="store_true", help="print hillclimb cell selection")
    args = ap.parse_args()
    rows = cell_rows(load(args.mesh))
    print(markdown_table(rows))
    if args.pick:
        sel = pick_hillclimb(rows)
        for why, r in sel.items():
            print(f"{why}: {r['arch']} x {r['shape']} (frac={r['roofline_frac']:.1%}, dom={r['dominant']})")


if __name__ == "__main__":
    main()
