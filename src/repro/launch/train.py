"""Training launcher: any assigned arch, real training on the local devices.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-235b-a22b \\
        --steps 5 --reduced --microbatches 2

On this CPU image ``--reduced`` (default) shrinks the config to the smoke
size; on a pod the same launcher takes ``--full`` and builds the production
mesh + sharding trees from ``repro.launch.specs``.  Fault tolerance
(heartbeats + checkpoint/restart) and DCIM energy accounting run in-line,
exactly as the paper's flex-start class requires.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.config import ParallelConfig, PrecisionConfig, RunConfig, TrainConfig
from repro.config.model import reduce_for_smoke
from repro.configs import ASSIGNED, get_config
from repro.core import Cluster, ClusterSpec, EnergyLedger, FaultTolerantRunner
from repro.data import make_batch_fn
from repro.train.step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ASSIGNED + ["bert-large"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", dest="reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=0, help="inject a node failure at this step (chaos test)")
    ap.add_argument("--fp8", action="store_true", help="FP8 quantized training (repro.fp8 delayed scaling)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_for_smoke(cfg)
    print(f"[train] {cfg.name} family={cfg.family} params={cfg.param_count()/1e6:.1f}M "
          f"(reduced={args.reduced})")

    run = RunConfig(
        arch=args.arch,
        train=TrainConfig(global_batch=args.batch, seq_len=args.seq, warmup_steps=5, total_steps=args.steps),
        parallel=ParallelConfig(num_microbatches=args.microbatches, remat="full"),
        precision=PrecisionConfig(fp8=args.fp8),
    )
    state = init_train_state(cfg, run, jax.random.PRNGKey(args.seed))
    if args.fp8:
        # 2 scale keys (x + w operand) per quantized GEMM site
        n_sites = 0 if state.fp8 is None else len(state.fp8.scale) // 2
        print(f"[train] fp8: {'ON' if state.fp8 is not None else 'unsupported family, bf16 fallback'}"
              f" ({n_sites} gemm sites, window={run.precision.fp8_amax_history})")
    step = jax.jit(make_train_step(cfg, run))
    batch_fn = make_batch_fn(cfg, global_batch=args.batch, seq_len=args.seq, seed=args.seed)

    cluster = Cluster(ClusterSpec("local", nodes_per_pod=2, num_pods=1))
    cluster.allocate([0, 1], "train")
    for n in cluster.nodes.values():
        cluster.heartbeat(n.node_id, 0.0)
    runner = FaultTolerantRunner(
        step_fn=step,
        init_state=state,
        batch_fn=batch_fn,
        cluster=cluster,
        ckpt=CheckpointManager(f"{args.ckpt_dir}/{cfg.name}", keep=2, async_save=False),
        job_id="train",
        checkpoint_every=args.ckpt_every,
        ledger=EnergyLedger(),
    )
    schedule = {args.fail_at: 1} if args.fail_at else None
    t0 = time.time()
    report = runner.run(args.steps, failure_schedule=schedule)
    dt = time.time() - t0
    last = max(report.losses)
    print(f"[train] {report.steps_run} steps in {dt:.1f}s  "
          f"loss {report.losses[min(report.losses)]:.4f} -> {report.losses[last]:.4f}  "
          f"failures={report.failures} restores={report.restores}")
    print(f"[train] energy: {runner.ledger.report()}")


if __name__ == "__main__":
    main()
