"""Jaxpr-level cost model: global FLOPs + modeled HBM traffic.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model is undercounted by ~num_layers (verified empirically —
see EXPERIMENTS.md §Dry-run notes).  This walker traverses the closed jaxpr of
the exact function the dry-run lowers and:

* multiplies ``scan`` bodies by their trip count,
* recurses into pjit/remat/custom-vjp call primitives (so activation-
  checkpoint *recompute* is counted, exactly what the MODEL_FLOPS/HLO_FLOPs
  ratio is meant to expose),
* counts matmul FLOPs exactly (2*M*N*K*batch) and elementwise/reduce ops as
  1 FLOP/element.

HBM bytes use a fusion-aware *model*: only materializing ops count
(dot/conv operands+results, scan carries, gathers/scatters, reduces);
elementwise/transpose/convert chains are assumed fused (VMEM-resident).
Numbers are GLOBAL; divide by chip count for the per-device roofline terms.
"""

from __future__ import annotations

from functools import lru_cache
from typing import Any

import jax
import numpy as np
from jax._src import core as jcore


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64)) * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64))
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval.shape, eqn.invars[1].aval.shape
    batch = 1
    for d in lb:
        batch *= lhs[d]
    k = 1
    for d in lc:
        k *= lhs[d]
    m = 1
    for i, d in enumerate(lhs):
        if i not in lc and i not in lb:
            m *= d
    n = 1
    for i, d in enumerate(rhs):
        if i not in rc and i not in rb:
            n *= d
    return 2 * batch * m * n * k


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elements * (kernel spatial * in_channels / groups)
    groups = eqn.params.get("feature_group_count", 1)
    k_elems = int(np.prod(rhs.shape, dtype=np.int64)) // max(rhs.shape[0], 1)  # per-out-channel
    return 2 * _size(out) * max(k_elems // max(groups, 1), 1)


# primitives whose operands/results we charge to HBM (materialization points)
_MATERIALIZING = {
    "dot_general",
    "conv_general_dilated",
    "gather",
    "scatter",
    "scatter-add",
    "scatter_add",
    "dynamic_slice",
    "dynamic_update_slice",
    "concatenate",
    "sort",
    "top_k",
    "cumsum",
    "cumlogsumexp",
    "cummax",
    "cumprod",
}

_REDUCE = {
    "reduce_sum",
    "reduce_max",
    "reduce_min",
    "reduce_prod",
    "reduce_and",
    "reduce_or",
    "argmax",
    "argmin",
    "reduce_precision",
}

# transcendentals: count a few flops per element
_TRANSCENDENTAL = {"exp", "log", "tanh", "erf", "logistic", "rsqrt", "sqrt", "sin", "cos", "pow", "exp2", "log1p", "expm1", "cbrt"}

_FREE = {
    "broadcast_in_dim",
    "reshape",
    "transpose",
    "convert_element_type",
    "squeeze",
    "slice",
    "rev",
    "iota",
    "copy",
    "stop_gradient",
    "bitcast_convert_type",
    "and",
    "or",
    "not",
    "xor",
}


def _sub_jaxprs(params: dict):
    """(jaxpr-like, multiplier) pairs found in a primitive's params."""
    out = []
    for k, v in params.items():
        if isinstance(v, jcore.ClosedJaxpr):
            out.append(v.jaxpr)
        elif isinstance(v, jcore.Jaxpr):
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for e in v:
                if isinstance(e, jcore.ClosedJaxpr):
                    out.append(e.jaxpr)
                elif isinstance(e, jcore.Jaxpr):
                    out.append(e)
    return out


def _cost_jaxpr(jaxpr) -> tuple[int, int]:
    flops = 0
    byts = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            f, b = _cost_jaxpr(inner)
            n = int(eqn.params["length"])
            flops += n * f
            # carry traffic: carries are read+written each iteration
            ncarry = int(eqn.params["num_carry"])
            carry_bytes = sum(_nbytes(v.aval) for v in eqn.invars[int(eqn.params["num_consts"]) :][:ncarry])
            byts += n * (b + 2 * carry_bytes)
            continue
        if name == "while":
            # shouldn't appear from our code (scan covers it); count once
            for sub in _sub_jaxprs(eqn.params):
                f, b = _cost_jaxpr(sub)
                flops += f
                byts += b
            continue
        if name == "cond":
            branches = [_cost_jaxpr(br.jaxpr) for br in eqn.params["branches"]]
            f = max(b[0] for b in branches)
            b_ = max(b[1] for b in branches)
            flops += f
            byts += b_
            continue
        subs = _sub_jaxprs(eqn.params)
        if subs:  # pjit / remat / custom_vjp / closed_call / ...
            for sub in subs:
                f, b = _cost_jaxpr(sub)
                flops += f
                byts += b
            continue
        if name == "dot_general":
            flops += _dot_flops(eqn)
            byts += sum(_nbytes(v.aval) for v in eqn.invars) + sum(_nbytes(v.aval) for v in eqn.outvars)
            continue
        if name == "conv_general_dilated":
            flops += _conv_flops(eqn)
            byts += sum(_nbytes(v.aval) for v in eqn.invars) + sum(_nbytes(v.aval) for v in eqn.outvars)
            continue
        if name in _REDUCE:
            flops += sum(_size(v.aval) for v in eqn.invars)
            byts += sum(_nbytes(v.aval) for v in eqn.invars)
            continue
        if name in _MATERIALIZING:
            byts += sum(_nbytes(v.aval) for v in eqn.invars if hasattr(v, "aval")) + sum(
                _nbytes(v.aval) for v in eqn.outvars
            )
            if name in ("cumsum", "cumlogsumexp", "cummax", "cumprod", "sort", "top_k"):
                flops += sum(_size(v.aval) for v in eqn.invars)
            continue
        if name in _FREE:
            continue
        # default: elementwise-ish — 1 flop (few for transcendentals) per output
        out_sz = sum(_size(v.aval) for v in eqn.outvars)
        flops += out_sz * (4 if name in _TRANSCENDENTAL else 1)
    return flops, byts


def estimate_cost(fn, *abstract_args) -> dict:
    """Global (unsharded) FLOPs + modeled HBM bytes for fn(*abstract_args)."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    flops, byts = _cost_jaxpr(closed.jaxpr)
    return {"flops": float(flops), "hbm_bytes": float(byts)}
