"""Production mesh construction (assignment-mandated shape).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — only the dry-run
launcher, which sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import, ever builds the full mesh.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips) mesh.

    Axis semantics: "pod" is the DCN boundary (data-parallel across pods),
    "data" the intra-pod FSDP/DP axis, "model" the TP/EP/SP axis.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return make_production_mesh(multi_pod=cfg.multi_pod)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — for tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(tp: int = 1):
    """(data=1, model=tp) mesh for tensor-parallel serving.

    One model instance spans ``tp`` devices — the paper's 4-way Grace-Hopper
    node is ``tp=4``.  The serving engine shards params and paged KV pools
    over the "model" axis; the data axis is kept (size 1) so the standard
    sharding rule tables apply unchanged.  On CPU, force multiple host
    devices first: ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    if tp < 1:
        raise ValueError(f"tp={tp} (need >= 1)")
    avail = jax.device_count()
    if tp > avail:
        raise ValueError(
            f"tp={tp} exceeds {avail} visible device(s); on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp} before "
            f"importing jax"
        )
    return make_local_mesh(1, tp)


def make_replica_meshes(replicas: int, tp: int = 1) -> list:
    """Partition the visible devices into ``replicas`` disjoint
    ``(data=1, model=tp)`` meshes — one independent serving engine per
    slice, the multi-replica analogue of ``make_serving_mesh``.

    Replica ``i`` owns devices ``[i*tp, (i+1)*tp)``, so replicas never
    contend for a device and one replica's failure cannot corrupt a peer's
    state — the isolation the router's failover model assumes.  Requires
    ``replicas * tp <= jax.device_count()``; on CPU force host devices
    first (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    if replicas < 1:
        raise ValueError(f"replicas={replicas} (need >= 1)")
    if tp < 1:
        raise ValueError(f"tp={tp} (need >= 1)")
    need = replicas * tp
    avail = jax.device_count()
    if need > avail:
        raise ValueError(
            f"replicas={replicas} x tp={tp} needs {need} devices, have "
            f"{avail}; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} before "
            f"importing jax"
        )
    devs = jax.devices()
    return [
        jax.sharding.Mesh(
            np.asarray(devs[i * tp : (i + 1) * tp]).reshape(1, tp), ("data", "model")
        )
        for i in range(replicas)
    ]
