"""Abstract input specs + per-cell run knobs for the dry-run.

``input_specs(model_cfg, shape)`` returns weak-type-correct
ShapeDtypeStruct stand-ins for every model input (tokens/labels for a train
step, frames for the audio stub frontend, patch embeddings for the VLM stub,
request batch + cache for decode) — no device allocation ever happens.

``cell_knobs`` holds the per-(arch x shape) baseline execution knobs
(microbatches, sequence parallelism, query chunking, precision policy) that
make every cell fit the 16 GB/chip v5e budget.  The §Perf hillclimb iterates
on these.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.config import (
    MeshConfig,
    ModelConfig,
    ParallelConfig,
    PrecisionConfig,
    RunConfig,
    ShapeConfig,
    TrainConfig,
    SHAPES,
)


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    specs: dict = {}
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "vlm":
        specs["vision_tokens"] = jax.ShapeDtypeStruct(
            (B, cfg.vision.num_image_tokens, cfg.d_model), jnp.bfloat16
        )
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    specs = train_input_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# per-cell knobs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CellKnobs:
    num_microbatches: int = 1
    sequence_parallel: bool = True
    q_chunk: int = 1024
    remat: str = "full"
    # precision overrides (None = RunConfig defaults)
    param_dtype: str | None = None
    optimizer_dtype: str | None = None
    grad_compression: str = "none"
    optimizer_layer_scan: bool = False
    # FP8 quantized training (train cells only; see repro.fp8)
    fp8: bool = False


# Baseline knobs chosen by napkin math (activation bytes/device <= ~4 GB,
# see EXPERIMENTS.md §Dry-run); hillclimbed cells get overrides in §Perf.
TRAIN_KNOBS: dict[str, CellKnobs] = {
    "rwkv6-7b": CellKnobs(num_microbatches=2),
    "olmo-1b": CellKnobs(num_microbatches=1),
    "mistral-nemo-12b": CellKnobs(num_microbatches=2),
    "stablelm-12b": CellKnobs(num_microbatches=2),
    "gemma-7b": CellKnobs(num_microbatches=4),
    "hubert-xlarge": CellKnobs(num_microbatches=2),
    # NOTE: optimizer_layer_scan measured WORSE on the CPU-XLA dry-run (scan
    # ys double-buffer the whole stacked tree: arctic 39.9 -> 57.2 GB); the
    # refuted hypothesis is logged in EXPERIMENTS.md §Perf.
    "arctic-480b": CellKnobs(num_microbatches=8, param_dtype="bfloat16", optimizer_dtype="bfloat16"),
    "qwen3-moe-235b-a22b": CellKnobs(num_microbatches=8, optimizer_dtype="bfloat16"),
    "hymba-1.5b": CellKnobs(num_microbatches=2),
    "llama-3.2-vision-90b": CellKnobs(num_microbatches=8, optimizer_dtype="bfloat16"),
    "bert-large": CellKnobs(num_microbatches=1),
}

PREFILL_Q_CHUNK = 512


def run_config_for(arch: str, shape: ShapeConfig, mesh: MeshConfig, knobs: CellKnobs | None = None) -> RunConfig:
    knobs = knobs or (TRAIN_KNOBS.get(arch, CellKnobs()) if shape.kind == "train" else CellKnobs())
    par = ParallelConfig(
        # decode: weights are model-sharded and STATIONARY — FSDP sharding on
        # the serving path makes XLA all-gather weight shards every step
        # (arctic decode: 7.2 GB/step of wo gathers; §Perf iteration 3)
        fsdp=shape.kind != "decode",
        tensor_parallel=True,
        sequence_parallel=knobs.sequence_parallel and shape.kind != "decode",
        num_microbatches=knobs.num_microbatches if shape.kind == "train" else 1,
        remat=knobs.remat if shape.kind == "train" else "none",
        grad_compression=knobs.grad_compression,
        optimizer_layer_scan=knobs.optimizer_layer_scan,
    )
    prec = PrecisionConfig(
        param_dtype=(knobs.param_dtype or ("bfloat16" if shape.kind != "train" else "float32")),
        optimizer_dtype=knobs.optimizer_dtype or "float32",
        fp8=knobs.fp8 and shape.kind == "train",
    )
    tr = TrainConfig(global_batch=shape.global_batch, seq_len=shape.seq_len)
    return RunConfig(arch=arch, mesh=mesh, parallel=par, precision=prec, train=tr)


# ---------------------------------------------------------------------------
# cell enumeration with the assignment's skip rules
# ---------------------------------------------------------------------------


def cell_status(cfg: ModelConfig, shape_name: str) -> str:
    """'run' | reason-for-skip (documented in DESIGN.md §Arch-applicability)."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode" and cfg.is_encoder_only:
        return "skip: encoder-only (no autoregressive decode step)"
    if shape_name == "long_500k" and not cfg.subquadratic:
        return "skip: pure full-attention arch (no sub-quadratic mechanism)"
    return "run"


def enumerate_cells(archs: list[str]) -> list[tuple[str, str, str]]:
    """[(arch, shape_name, status)] over the full 40-cell grid."""
    from repro.configs import get_config

    cells = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in SHAPES:
            cells.append((arch, shape_name, cell_status(cfg, shape_name)))
    return cells
