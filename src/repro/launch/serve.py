"""Serving launcher: continuous-batching engine over a synthetic request mix.

    PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \\
        --requests 12 --max-batch 4 --cache paged --block-size 16 \\
        --shared-prefix 32 --prefill-budget 16

    # tensor-parallel: one model instance over 2 devices (on CPU, force
    # host devices first)
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --tp 2

Runs the paper's inference QoS class end-to-end: online requests admitted
ahead of offline backfill, per-request TTFT, paged-pool block accounting and
engine utilization stats.  ``--shared-prefix N`` prepends a common N-token
system prompt to every request so the prefix cache's hit rate / saved
prefill tokens show up in the stats; ``--prefill-budget`` bounds prompt
tokens processed per engine step (chunked prefill interleaved with decode).
``--cache dense`` selects the slot-granular baseline; ``--quantize-kv
[int8|fp8]`` stores paged pools quantized (KIVI scales / e4m3);
``--fused`` lowers each scheduler tick to one jitted dispatch (plan →
unified batch → in-graph sample/accept); ``--spill-bytes N`` adds the tiered
KV cache — evicted prefix blocks spill to an N-byte host-RAM pool
(``--spill-dtype cache|int8|fp8`` picks the at-rest encoding) and swap back
on a prefix hit at ``--restore-budget`` blocks per step; ``--attn-impl
pallas`` routes decode
and prefill chunks through the paged-attention kernels; ``--spec-decode
ngram|draft`` turns on speculative decoding with ``--spec-k`` drafted tokens
per verify pass; ``--tp N`` shards params and the paged K/V pools over a
``(data=1, model=N)`` mesh — the paper's 4-way Grace-Hopper node is
``--tp 4`` (see docs/serving.md for the tuning guide and the
sharded-vs-replicated state matrix).  ``--metrics-json`` / ``--trace-out``
dump the observability layer's registry snapshot and Chrome trace after the
drain, and ``--profile`` turns on per-phase dispatch timing (see
docs/observability.md).

``--http`` switches from the synthetic closed-loop drive to the always-on
service: an asyncio stepping loop (``serving.async_engine``) plus a
stdlib HTTP/SSE front-end (``serving.http``) on ``--host``/``--port`` —
``POST /v1/generate`` streams tokens as Server-Sent Events, ``GET /metrics``
exposes the Prometheus registry, ``GET /stats`` / ``GET /healthz`` serve
JSON.  ``--policy slo|fcfs`` selects the scheduler: ``slo`` (default) orders
by per-request ``priority``/``deadline_s`` and preempts lower-priority work
under pool pressure; ``fcfs`` ignores SLO knobs.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --http \\
        --port 8731 --prefill-budget 16

``--replicas N`` runs N independent engines behind the prefix-affinity
``serving.router.Router`` — each replica on its own ``(data=1, model=tp)``
device slice when ``N*tp`` devices are visible (``launch.mesh
.make_replica_meshes``), all sharing one device otherwise (CPU smoke).
The router health-checks replicas, fails over in-flight requests and
supports graceful drain; combine with ``--http`` for an always-on
multi-replica service.  SIGTERM/SIGINT on the ``--http`` path triggers a
graceful drain (stop admission, finish active requests) before the
``--metrics-json`` / ``--trace-out`` flush.

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --replicas 2 \\
        --shared-prefix 32 --prefill-budget 16
"""

from __future__ import annotations

import argparse
import random

import jax
import jax.numpy as jnp

from repro.config.model import reduce_for_smoke
from repro.configs import ASSIGNED, get_config
from repro.models import init_params
from repro.serving import InferenceEngine

DTYPES = {"bf16": jnp.bfloat16, "fp32": jnp.float32}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b", choices=ASSIGNED)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache", default="paged", choices=("paged", "dense"))
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None)
    ap.add_argument("--cache-dtype", default="bf16", choices=sorted(DTYPES))
    ap.add_argument(
        "--quantize-kv", nargs="?", const="int8", default=False,
        choices=("int8", "fp8"),
        help="quantized paged block pools (bare flag = int8)",
    )
    ap.add_argument("--attn-impl", default="xla", choices=("xla", "pallas"))
    ap.add_argument(
        "--fused", action="store_true",
        help="fused one-dispatch step: one jitted dispatch + one host sync "
        "per scheduler tick (chunked paged families only)",
    )
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument(
        "--shared-prefix", type=int, default=0,
        help="prepend a common N-token system prompt to every request",
    )
    ap.add_argument(
        "--no-prefix-cache", action="store_true",
        help="disable prefix caching (measure the re-prefill baseline)",
    )
    ap.add_argument(
        "--prefill-budget", type=int, default=0,
        help="max prompt tokens prefilled per step (0 = unbounded)",
    )
    ap.add_argument(
        "--spill-bytes", type=int, default=0,
        help="host-RAM budget for the spill tier: evicted prefix blocks park "
        "in pinned host memory instead of being dropped (0 = drop on evict)",
    )
    ap.add_argument(
        "--spill-dtype", default="cache", choices=("cache", "int8", "fp8"),
        help="at-rest encoding for spilled blocks: 'cache' stores pool-native "
        "rows (bit-exact), 'int8'/'fp8' compress on the way out",
    )
    ap.add_argument(
        "--restore-budget", type=int, default=4,
        help="max spilled blocks swapped back per scheduler step (bounds "
        "host->device traffic interleaved with decode)",
    )
    ap.add_argument(
        "--spec-decode", default="off", choices=("off", "ngram", "draft"),
        help="speculative decoding: n-gram prompt lookup or a reduced-depth "
        "draft model (verify pass through the chunked-prefill kernel)",
    )
    ap.add_argument(
        "--spec-k", type=int, default=4,
        help="drafted tokens scored per verify pass (reserves spec-k "
        "positions of per-request block headroom)",
    )
    ap.add_argument(
        "--replicas", type=int, default=1,
        help="independent engine replicas behind the prefix-affinity router "
        "(each on its own (1, tp) device slice when replicas*tp devices are "
        "visible; health checks + failover + graceful drain)",
    )
    ap.add_argument(
        "--tp", type=int, default=1,
        help="tensor-parallel degree: shard params + paged KV pools over a "
        "(data=1, model=tp) mesh (CPU: set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=N first)",
    )
    ap.add_argument(
        "--metrics-json", default=None, metavar="PATH",
        help="write the metrics registry snapshot (counters/gauges/histogram "
        "percentiles) as JSON after the drain",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write the request-lifecycle trace as Chrome-trace JSON "
        "(open in chrome://tracing or ui.perfetto.dev)",
    )
    ap.add_argument(
        "--profile", action="store_true",
        help="bracket each jitted dispatch with block_until_ready so step "
        "latency decomposes by phase (adds host syncs; off by default)",
    )
    ap.add_argument(
        "--policy", default="slo", choices=("slo", "fcfs"),
        help="scheduler policy: 'slo' honors priority/deadline_s and "
        "preempts under pressure; 'fcfs' is strict arrival order",
    )
    ap.add_argument(
        "--http", action="store_true",
        help="serve an always-on HTTP/SSE front-end instead of draining a "
        "synthetic batch (POST /v1/generate streams tokens; GET /metrics, "
        "/stats, /healthz)",
    )
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only; no decode serving")
    if args.replicas < 1:
        raise SystemExit(f"--replicas {args.replicas} (need >= 1)")

    meshes: list = [None] * args.replicas
    if args.replicas * args.tp > 1:
        from repro.launch.mesh import make_replica_meshes

        try:
            meshes = make_replica_meshes(args.replicas, args.tp)
            print(
                f"[serve] {args.replicas} replica(s) x tp={args.tp}: "
                f"disjoint device slices"
            )
        except ValueError:
            if args.tp > 1:
                raise  # tensor parallelism genuinely needs the devices
            print(
                f"[serve] {args.replicas} replicas sharing "
                f"{jax.device_count()} device(s) (host-side replication)"
            )

    params = init_params(cfg, jax.random.PRNGKey(args.seed), jnp.float32)

    def build_engine(mesh):
        return InferenceEngine(
            cfg,
            params,
            mesh=mesh,
            max_batch=args.max_batch,
            max_seq=256,
            seed=args.seed,
            cache_kind=args.cache,
            block_size=args.block_size,
            num_blocks=args.num_blocks,
            cache_dtype=DTYPES[args.cache_dtype],
            quantize_kv=args.quantize_kv,
            attn_impl=args.attn_impl,
            fused=args.fused,
            prefix_cache=False if args.no_prefix_cache else None,
            prefill_budget=args.prefill_budget,
            spill_bytes=args.spill_bytes,
            spill_dtype=args.spill_dtype,
            restore_budget=args.restore_budget,
            policy=args.policy,
            spec_decode=args.spec_decode,
            spec_k=args.spec_k,
            profile=args.profile,
            trace_capacity=65536 if args.trace_out else 4096,
        )

    if args.replicas > 1:
        from repro.serving import Replica, Router

        replicas = [Replica(i, build_engine(meshes[i])) for i in range(args.replicas)]
        eng = Router(replicas, trace_capacity=65536 if args.trace_out else 4096)
    else:
        eng = build_engine(meshes[0])

    if args.http:
        import asyncio

        from repro.serving.http import serve_http

        try:
            asyncio.run(
                serve_http(
                    eng,
                    host=args.host,
                    port=args.port,
                    metrics_json=args.metrics_json,
                    trace_out=args.trace_out,
                )
            )
        except KeyboardInterrupt:
            print("[serve] shutting down")
        return

    rng = random.Random(args.seed)
    system = [rng.randrange(2, cfg.vocab_size) for _ in range(args.shared_prefix)]
    reqs = []
    for i in range(args.requests):
        prompt = system + [rng.randrange(2, cfg.vocab_size) for _ in range(rng.randint(2, 8))]
        reqs.append(
            eng.submit(
                prompt,
                max_new_tokens=args.max_new,
                online=(i % 3 != 0),
                temperature=args.temperature,
                top_k=args.top_k,
            )
        )
    eng.run_until_drained()
    for r in reqs:
        online = r.online if hasattr(r, "online") else r.kwargs.get("online", True)
        kind = "online " if online else "offline"
        ttft = f"{r.ttft*1e3:8.1f}ms" if r.ttft is not None else "   never admitted"
        hit_toks = getattr(r, "prefix_hit_tokens", 0)
        hit = f" prefix_hit={hit_toks:3d}" if hit_toks else ""
        rep = f" replica={r.replica_id}" if hasattr(r, "replica_id") else ""
        print(f"req {r.req_id:3d} [{kind}] ttft={ttft} len={len(r.generated)}{hit}{rep} head={r.generated[:6]}")
    print("[serve] stats:", eng.stats())
    for name in ("engine_ttft_seconds", "engine_tpot_seconds", "engine_step_seconds"):
        p = eng.metrics.percentiles(name)
        if p[50] is not None:
            pretty = "  ".join(f"p{int(k)}={v*1e3:.2f}ms" for k, v in p.items())
            print(f"[serve] {name}: {pretty}")
    if args.metrics_json:
        eng.metrics.write_json(args.metrics_json)
        print(f"[serve] metrics snapshot -> {args.metrics_json}")
    if args.trace_out:
        eng.tracer.write(args.trace_out)
        print(
            f"[serve] chrome trace -> {args.trace_out} "
            f"({len(eng.tracer.events)} events, {eng.tracer.dropped} dropped)"
        )


if __name__ == "__main__":
    main()
