"""Serving launcher: continuous-batching engine over a synthetic request mix.

    PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \\
        --requests 12 --max-batch 4

Runs the paper's inference QoS class end-to-end: online requests admitted
ahead of offline backfill, per-request TTFT, engine utilization stats.
"""

from __future__ import annotations

import argparse
import random

import jax
import jax.numpy as jnp

from repro.config.model import reduce_for_smoke
from repro.configs import ASSIGNED, get_config
from repro.models import init_params
from repro.serving import InferenceEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b", choices=ASSIGNED)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only; no decode serving")
    params = init_params(cfg, jax.random.PRNGKey(args.seed), jnp.float32)
    eng = InferenceEngine(cfg, params, max_batch=args.max_batch, max_seq=256, seed=args.seed)

    rng = random.Random(args.seed)
    reqs = []
    for i in range(args.requests):
        prompt = [rng.randrange(2, cfg.vocab_size) for _ in range(rng.randint(2, 8))]
        reqs.append(
            eng.submit(prompt, max_new_tokens=args.max_new, online=(i % 3 != 0), temperature=0.0)
        )
    eng.run_until_drained()
    for r in reqs:
        kind = "online " if r.online else "offline"
        print(f"req {r.req_id:3d} [{kind}] ttft={r.ttft*1e3:8.1f}ms len={len(r.generated)} head={r.generated[:6]}")
    print("[serve] stats:", eng.stats())


if __name__ == "__main__":
    main()
