"""FP8 quantized training: e4m3/e5m2 casts, delayed scaling, FP8 GEMMs.

See ``quantize`` (scales + ``Fp8State`` + ``fp8_dot``), ``gemm`` (Pallas
tiled kernel), ``gemm_ref`` (jnp oracle) and ``policy`` (site selection +
``Fp8Ctx`` forward context).  Enabled via ``PrecisionConfig.fp8``.
"""

from repro.fp8.gemm import fp8_gemm
from repro.fp8.gemm_ref import fp8_gemm_ref
from repro.fp8.policy import (
    Fp8Ctx,
    fp8_peak_applies,
    fp8_sites,
    fp8_supported,
    make_fp8_ctx,
    make_fp8_state,
    scale_keys,
)
from repro.fp8.quantize import (
    E4M3,
    E5M2,
    FP8_DTYPES,
    FP8_MAX,
    Fp8State,
    compute_scale,
    dequantize,
    fp8_dot,
    init_fp8_state,
    quantize,
    tensor_amax,
    update_fp8_state,
)

__all__ = [
    "E4M3",
    "E5M2",
    "FP8_DTYPES",
    "FP8_MAX",
    "Fp8Ctx",
    "Fp8State",
    "compute_scale",
    "dequantize",
    "fp8_dot",
    "fp8_gemm",
    "fp8_gemm_ref",
    "fp8_peak_applies",
    "fp8_sites",
    "fp8_supported",
    "init_fp8_state",
    "make_fp8_ctx",
    "make_fp8_state",
    "quantize",
    "scale_keys",
    "tensor_amax",
    "update_fp8_state",
]
