"""FP8 cast/dequant, per-tensor scales, and delayed scaling.

The Isambard-AI paper's headline training number is its **21 ExaFLOP/s of
8-bit floating point** — double the bf16 peak — so the compute path needs an
FP8 story to run "as fast as the hardware allows".  This module implements
the standard FP8 training recipe (Micikevicius et al., arXiv:2209.05433, as
productionized by Transformer Engine):

* **e4m3** for forward tensors (activations + weights): more mantissa,
  max-normal 448.
* **e5m2** for gradients: more range (max-normal 57344) for the long tail of
  small backward values.
* **per-tensor scales** map each tensor's dynamic range onto the FP8 window:
  ``q = cast(clip(x * scale))``, ``x ~= q / scale`` with
  ``scale = fp8_max / (2^margin * amax)``.
* **delayed scaling**: the scale used at step *t* is derived from an
  *amax history* window of the previous steps (``Fp8State``), so quantization
  is a cheap elementwise op with no data-dependent reduction on the forward
  critical path.  Gradients use just-in-time (current) scaling instead —
  their amax is only known during the backward pass.

Saturation note: JAX's ``astype(float8_*)`` maps out-of-range values to NaN,
so every cast here clips into the representable window first (saturating
quantization, matching TE's behavior).
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

E4M3 = jnp.float8_e4m3fn
E5M2 = jnp.float8_e5m2

FP8_DTYPES = {"e4m3": E4M3, "e5m2": E5M2}
FP8_MAX = {E4M3: 448.0, E5M2: 57344.0}

_AMAX_EPS = 1e-12  # guards the 0-amax (never-observed) scale


def compute_scale(amax: jax.Array, dtype, margin: float = 0.0) -> jax.Array:
    """Scale that maps [-amax, amax] onto the FP8 window (minus 2^margin headroom)."""
    amax = jnp.maximum(amax.astype(jnp.float32), _AMAX_EPS)
    return jnp.float32(FP8_MAX[dtype]) / (amax * jnp.float32(2.0**margin))


def quantize(x: jax.Array, scale: jax.Array, dtype=E4M3) -> jax.Array:
    """Saturating cast to FP8: clip(x * scale) in fp32, then narrow."""
    m = FP8_MAX[dtype]
    y = x.astype(jnp.float32) * scale
    return jnp.clip(y, -m, m).astype(dtype)


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) / scale).astype(dtype)


def tensor_amax(x: jax.Array) -> jax.Array:
    """Observed absolute max, detached (amaxes steer scales, not gradients)."""
    return jax.lax.stop_gradient(jnp.max(jnp.abs(x)).astype(jnp.float32))


# ---------------------------------------------------------------------------
# delayed-scaling state
# ---------------------------------------------------------------------------


class Fp8State(NamedTuple):
    """Per-tensor delayed-scaling state, carried as a pytree in ``TrainState``.

    Scales are per GEMM operand *per layer* (one quantized tensor = one
    scale, the TE recipe): ``amax_history``: dict site-key ->
    (num_layers, window) fp32, newest observation first along the window
    axis.  ``scale``: dict site-key -> (num_layers,) fp32, the scales *to
    use* at the next step (derived from the history).  ``step`` counts
    applied updates.
    """

    amax_history: Any
    scale: Any
    step: jax.Array


def init_fp8_state(keys: list[str], window: int, num_layers: int = 1) -> Fp8State:
    return Fp8State(
        amax_history={k: jnp.zeros((num_layers, window), jnp.float32) for k in keys},
        scale={k: jnp.ones((num_layers,), jnp.float32) for k in keys},
        step=jnp.zeros((), jnp.int32),
    )


def update_fp8_state(state: Fp8State, amaxes: dict, dtype=E4M3, margin: float = 0.0) -> Fp8State:
    """Roll each site's amax window and recompute its per-layer scales.

    ``amaxes``: dict site-key -> (num_layers,) fp32 observed this step (a
    site that was not exercised reports 0 and simply ages the window).
    """

    def roll(hist, obs):
        obs = jnp.broadcast_to(obs.astype(jnp.float32), (hist.shape[0],))
        return jnp.concatenate([obs[:, None], hist[:, :-1]], axis=1)

    new_hist = {k: roll(state.amax_history[k], amaxes[k]) for k in state.amax_history}
    new_scale = {k: compute_scale(jnp.max(h, axis=1), dtype, margin) for k, h in new_hist.items()}
    return Fp8State(amax_history=new_hist, scale=new_scale, step=state.step + 1)


# ---------------------------------------------------------------------------
# FP8 matmul with straight-through quantization gradients
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def fp8_dot(x, w, x_scale, w_scale, fwd_dtype, gemm_fn):
    """``x @ w`` through the FP8 path: quantize both operands with the given
    (delayed) scales, run the fp32-accumulating FP8 GEMM, dequantize.

    x: (M, K), w: (K, N); returns (M, N) fp32.  ``gemm_fn`` is one of the
    ``repro.fp8`` GEMM implementations (Pallas kernel or jnp reference) with
    signature ``(a_q, b_q, a_scale, b_scale) -> fp32``.
    """
    qx = quantize(x, x_scale, fwd_dtype)
    qw = quantize(w, w_scale, fwd_dtype)
    return gemm_fn(qx, qw, x_scale, w_scale)


def _fp8_dot_fwd(x, w, x_scale, w_scale, fwd_dtype, gemm_fn):
    qx = quantize(x, x_scale, fwd_dtype)
    qw = quantize(w, w_scale, fwd_dtype)
    out = gemm_fn(qx, qw, x_scale, w_scale)
    # zero-size dtype witnesses: cotangents must match the primal dtypes
    return out, (qx, qw, x_scale, w_scale, jnp.zeros((), x.dtype), jnp.zeros((), w.dtype))


def _fp8_dot_bwd(fwd_dtype, gemm_fn, res, g):
    """Backward GEMMs in e5m2 with current (just-in-time) scaling.

    dx = g @ w^T and dw = x^T @ g reuse the *quantized* forward operands —
    exactly the values the forward consumed — so the quantization gradient is
    straight-through (clip saturation included via the saved fp8 values).
    """
    qx, qw, sx, sw, x_wit, w_wit = res
    g_scale = compute_scale(tensor_amax(g), E5M2)
    qg = quantize(g, g_scale, E5M2)
    dx = gemm_fn(qg, qw.T, g_scale, sw).astype(x_wit.dtype)
    dw = gemm_fn(qx.T, qg, sx, g_scale).astype(w_wit.dtype)
    return dx, dw, jnp.zeros_like(sx), jnp.zeros_like(sw)


fp8_dot.defvjp(_fp8_dot_fwd, _fp8_dot_bwd)
