"""Which matmuls run FP8, and the per-forward quantization context.

Policy (the standard "FP8 for LLM training" recipe):

* **quantized** — the six/seven big projection GEMMs per block: attention
  q/k/v/o and the FFN up/gate/down.  These carry ~all of a transformer's
  FLOPs and are what the paper's 21 ExaFLOP/s FP8 peak is quoted for.
* **high precision** — everything numerically fragile stays on the existing
  mixed-precision path: logits (fp32), norms + softmax statistics (fp32),
  embeddings, router/MoE dispatch, RWKV/SSM scans, biases, residual stream.

Families: ``dense``/``audio``/``hybrid`` quantize attention + FFN; ``moe``
quantizes attention (+ the Arctic dense-residual FFN when present — routed
expert FFNs keep bf16: their per-expert token groups are too small to
amortize per-tensor scales).  ``ssm`` has no quantizable projections and
``vlm`` scans layer *groups* (amax collection across the nested scan is not
wired); both fall back to bf16, reported by ``fp8_supported``.

``Fp8Ctx`` is the per-forward bridge between the pure model functions and the
delayed-scaling state: ``matmul(site, x, w)`` routes one projection through
``fp8_dot`` using the scales carried in ``Fp8State`` and records the observed
amaxes; the train body drains them into the scan carry each layer, and the
train step folds them into the next step's ``Fp8State``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fp8 import gemm, gemm_ref
from repro.fp8.quantize import (
    FP8_DTYPES,
    Fp8State,
    fp8_dot,
    init_fp8_state,
    tensor_amax,
    update_fp8_state,
)

SUPPORTED_FAMILIES = ("dense", "audio", "moe", "hybrid")

ATTN_SITES = ("attn_q", "attn_k", "attn_v", "attn_o")
FFN_SITES = ("ffn_up", "ffn_gate", "ffn_down")

GEMM_IMPLS = {
    "ref": gemm_ref.fp8_gemm_ref,
    "pallas": gemm.fp8_gemm,
}


def fp8_supported(cfg) -> bool:
    return cfg.family in SUPPORTED_FAMILIES


def fp8_peak_applies(cfg) -> bool:
    """Whether a roofline should cost this arch's fp8 run at the FP8 peak.

    Only when the quantized sites carry the *dominant* GEMM FLOPs: moe is
    excluded — its routed expert FFNs (the bulk of active FLOPs) stay bf16,
    so costing the whole cell at 2x would understate compute_s by ~2x.
    """
    return fp8_supported(cfg) and cfg.family != "moe"


def fp8_sites(cfg) -> list[str]:
    """GEMM sites quantized for this architecture (stable order — the site
    list fixes the ``Fp8State`` pytree structure)."""
    from repro.models.ffn import is_gated

    sites: list[str] = []
    if cfg.has_attention:
        sites += list(ATTN_SITES)
    uses_dense_ffn = cfg.family != "moe" or (cfg.moe is not None and cfg.moe.dense_residual)
    if cfg.family in SUPPORTED_FAMILIES and uses_dense_ffn:
        for s in FFN_SITES:
            if s == "ffn_gate" and not is_gated(cfg.activation):
                continue
            sites.append(s)
    return sites


def scale_keys(cfg) -> list[str]:
    """One delayed scale per GEMM operand: ``<site>/x`` and ``<site>/w``."""
    return [f"{s}/{op}" for s in fp8_sites(cfg) for op in ("x", "w")]


def make_fp8_state(cfg, precision) -> Fp8State:
    # per-tensor scales: one (history, scale) row per GEMM operand per layer
    return init_fp8_state(
        scale_keys(cfg), window=precision.fp8_amax_history, num_layers=cfg.num_layers
    )


class Fp8Ctx:
    """Routes projection matmuls through FP8 and collects amax observations.

    One context is created per traced forward (it holds Python-side mutable
    observation state scoped to that trace): the model's scan body calls
    ``bind_layer_scales`` with this layer's slice of the delayed scales
    (threaded through the scan as an input alongside the stacked params),
    the block bodies call ``matmul``, and the scan body calls ``drain`` once
    per layer, emitting the observed amaxes as a per-layer scan output — so
    observations never leak across ``lax.scan``/``jax.checkpoint`` trace
    boundaries, and every quantized tensor gets its own scale.
    """

    def __init__(self, cfg, precision, state: Fp8State):
        if precision.fp8_dtype not in FP8_DTYPES:
            raise ValueError(
                f"precision.fp8_dtype={precision.fp8_dtype!r}: expected one of {sorted(FP8_DTYPES)}"
            )
        if precision.fp8_gemm not in GEMM_IMPLS:
            raise ValueError(
                f"precision.fp8_gemm={precision.fp8_gemm!r}: expected one of {sorted(GEMM_IMPLS)}"
            )
        self.cfg = cfg
        self.fwd_dtype = FP8_DTYPES[precision.fp8_dtype]
        self.margin = precision.fp8_margin
        self.gemm_fn = GEMM_IMPLS[precision.fp8_gemm]
        self.state = state
        self.keys = scale_keys(cfg)
        self._amax: dict[str, jax.Array] = {}
        self._layer_scale: dict[str, jax.Array] | None = None

    # -- observation plumbing ------------------------------------------------
    def layer_scales(self) -> dict:
        """The full per-layer scale tree, to be scanned over as an input
        (leading dim = num_layers, matching the stacked block params)."""
        return jax.lax.stop_gradient(self.state.scale)

    def bind_layer_scales(self, scales: dict) -> None:
        """Install this layer's () scale slice (called by the scan body)."""
        self._layer_scale = scales

    def _observe(self, key: str, amax: jax.Array) -> None:
        prev = self._amax.get(key)
        self._amax[key] = amax if prev is None else jnp.maximum(prev, amax)

    def drain(self) -> dict:
        """All site amaxes observed since the last drain (zeros elsewhere)."""
        obs = {k: self._amax.get(k, jnp.zeros((), jnp.float32)) for k in self.keys}
        self._amax = {}
        return obs

    # -- the quantized matmul ------------------------------------------------
    def matmul(self, site: str, x: jax.Array, w: jax.Array) -> jax.Array:
        """``x @ w`` through the FP8 path.

        x: (..., K) activations (compute dtype); w: (K, N) master weights.
        Returns (..., N) in ``x.dtype``.  Scales are this layer's slice of
        the delayed state, bound by the scan body (stop-gradient — they
        steer quantization, not learning).
        """
        if self._layer_scale is None:
            raise RuntimeError("Fp8Ctx.matmul called before bind_layer_scales (scan body)")
        kx, kw = f"{site}/x", f"{site}/w"
        x2 = x.reshape((-1, x.shape[-1]))
        self._observe(kx, tensor_amax(x2))
        self._observe(kw, tensor_amax(w))
        out = fp8_dot(
            x2, w, self._layer_scale[kx], self._layer_scale[kw], self.fwd_dtype, self.gemm_fn
        )
        return out.astype(x.dtype).reshape(x.shape[:-1] + (w.shape[-1],))


def make_fp8_ctx(cfg, precision, state: Fp8State) -> Fp8Ctx:
    return Fp8Ctx(cfg, precision, state)
