"""Pallas tiled FP8 GEMM: fp8 operand tiles, in-kernel dequant, fp32 accumulate.

Grid layout is the idiomatic TPU matmul formulation: a 3-D grid
``(M/bm, N/bn, K/bk)`` whose minormost (k) dimension *revisits* the output
block, carrying the running fp32 accumulator in VMEM scratch between k steps.
Tiles default to 128x128 — MXU-aligned (the systolic array is 128x128) and
comfortably VMEM-resident (an fp8 128x128 tile is 16 KiB; the fp32
accumulator 64 KiB).

The fp8 A/B tiles are upcast + dequantized *in-kernel*: the HBM->VMEM stream
moves 1 byte/element (the whole point of FP8 — half the bf16 wire/memory
traffic, and the MXU's fp8 throughput is 2x bf16 on GH200-class parts), while
every multiply-accumulate happens in fp32.  The per-tensor scales ride in
SMEM and divide the accumulator once, on the final k step.

On this CPU image the kernel runs through ``interpret=True``; TPU is the
target.  ``repro.fp8.gemm_ref.fp8_gemm_ref`` is the pure-jnp oracle.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _fp8_gemm_kernel(a_scale_ref, b_scale_ref, a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)  # (bm, bk) dequant deferred: scale is
    b = b_ref[...].astype(jnp.float32)  # (bk, bn) uniform, divide once at end
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finalize():
        inv = 1.0 / (a_scale_ref[0] * b_scale_ref[0])
        o_ref[...] = (acc_ref[...] * inv).astype(o_ref.dtype)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@partial(
    jax.jit, static_argnames=("block", "out_dtype", "interpret")
)
def fp8_gemm(
    a: jax.Array,  # (M, K) fp8
    b: jax.Array,  # (K, N) fp8
    a_scale: jax.Array,  # () fp32
    b_scale: jax.Array,  # () fp32
    *,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:
    """Dequantizing FP8 GEMM: returns ``(a/a_scale) @ (b/b_scale)``.

    Shapes need not be multiples of the block sizes — operands are
    zero-padded up (fp8 zero is exact, padding contributes nothing) and the
    output sliced back.
    """
    if interpret is None:
        interpret = not _on_tpu()
    (M, K), (K2, N) = a.shape, b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = (min(block[0], M), min(block[1], N), min(block[2], K))
    Mp, Np, Kp = -(-M // bm) * bm, -(-N // bn) * bn, -(-K // bk) * bk
    a = _pad_to(a, Mp, Kp)
    b = _pad_to(b, Kp, Np)
    scale_spec = pl.BlockSpec((1,), lambda i, j, k: (0,), memory_space=pltpu.SMEM)
    out = pl.pallas_call(
        _fp8_gemm_kernel,
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[
            scale_spec,
            scale_spec,
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_scale.reshape(1).astype(jnp.float32), b_scale.reshape(1).astype(jnp.float32), a, b)
    return out[:M, :N]
