"""Pure-jnp FP8 GEMM reference: the oracle for the Pallas kernel tests, and
the default model-path implementation (XLA lowers it straight to the native
FP8 MXU path on hardware that has one; in fp32 emulation on CPU it is
bit-faithful to the kernel's dequantize-then-accumulate order).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fp8_gemm_ref(
    a: jax.Array,  # (M, K) fp8
    b: jax.Array,  # (K, N) fp8
    a_scale: jax.Array,  # () fp32
    b_scale: jax.Array,  # () fp32
    out_dtype=jnp.float32,
) -> jax.Array:
    """Dequantizing GEMM: upcast fp8 operands, accumulate in fp32, divide by
    the combined scale."""
    acc = jax.lax.dot_general(
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return (acc / (a_scale * b_scale)).astype(out_dtype)
