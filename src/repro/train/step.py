"""Train-step factory: mixed precision, remat, microbatched grad accumulation.

``make_train_step(model_cfg, run_cfg, rules, mesh)`` returns a pure
``step(state, batch) -> (state, metrics)`` ready for ``jax.jit`` with the
sharding trees from ``state_shardings``.

Mixed precision follows the standard recipe: master params in
``precision.param_dtype`` (fp32), cast once to ``compute_dtype`` (bf16) at
step entry — under FSDP the all-gather then moves bf16, halving wire bytes —
softmax/norm statistics in fp32, logits in fp32.

With ``precision.fp8`` enabled, the FFN / attention-projection GEMMs run
through ``repro.fp8`` (e4m3 forward, e5m2 grads, delayed scaling): the step
carries an ``Fp8State`` in ``TrainState``, the forward reports per-site amax
observations, and the step folds them into the next step's scales.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.optim import AdamWState, adamw_init, adamw_update, make_schedule
from repro.parallel import compress_gradients, init_compression_state


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    compress_residual: Any  # None unless grad_compression enabled
    fp8: Any = None  # Fp8State unless precision.fp8 disabled/unsupported


DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


def _cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if x.dtype != dtype else x, tree)


def cross_entropy(logits: jax.Array, labels: jax.Array, *, z_loss: float = 0.0):
    """Mean CE over all positions (logits fp32), with optional z-loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss > 0:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def loss_fn(
    model_cfg,
    params,
    batch,
    *,
    sh=None,
    q_chunk=0,
    remat="none",
    z_loss=0.0,
    attn_impl="xla",
    compute_dtype=None,
    fp8=None,
):
    """With an ``fp8`` context the aux grows a third slot: the per-site amax
    observations the step needs for the delayed-scaling update."""
    out = forward(
        model_cfg,
        params,
        batch,
        sh=sh,
        q_chunk=q_chunk,
        remat=remat,
        attn_impl=attn_impl,
        compute_dtype=compute_dtype,
        fp8=fp8,
    )
    if fp8 is None:
        logits, aux = out
        ce = cross_entropy(logits, batch["labels"], z_loss=z_loss)
        return ce + aux, (ce, aux)
    logits, aux, amaxes = out
    ce = cross_entropy(logits, batch["labels"], z_loss=z_loss)
    return ce + aux, (ce, aux, amaxes)


def _fp8_enabled(model_cfg, prec) -> bool:
    from repro.fp8 import fp8_supported

    return bool(prec.fp8) and fp8_supported(model_cfg)


def init_train_state(model_cfg, run_cfg, key) -> TrainState:
    from repro.models import init_params

    prec = run_cfg.precision
    params = init_params(model_cfg, key, DTYPES[prec.param_dtype])
    opt = adamw_init(params, dtype=DTYPES[prec.optimizer_dtype])
    residual = init_compression_state(params, run_cfg.parallel.grad_compression)
    fp8 = None
    if _fp8_enabled(model_cfg, prec):
        from repro.fp8 import make_fp8_state

        fp8 = make_fp8_state(model_cfg, prec)
    return TrainState(params=params, opt=opt, compress_residual=residual, fp8=fp8)


def abstract_train_state(model_cfg, run_cfg) -> TrainState:
    """ShapeDtypeStruct twin of init_train_state for the dry-run."""
    from repro.models import abstract_params

    prec = run_cfg.precision
    params = abstract_params(model_cfg, DTYPES[prec.param_dtype])
    odt = DTYPES[prec.optimizer_dtype]
    mk = lambda p: jax.ShapeDtypeStruct(p.shape, odt)
    opt = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=jax.tree.map(mk, params), v=jax.tree.map(mk, params))
    residual = None
    if run_cfg.parallel.grad_compression != "none":
        residual = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    fp8 = None
    if _fp8_enabled(model_cfg, prec):
        from repro.fp8 import make_fp8_state

        # eval_shape: structs only, no device allocation (dry-run contract)
        fp8 = jax.eval_shape(lambda: make_fp8_state(model_cfg, prec))
    return TrainState(params=params, opt=opt, compress_residual=residual, fp8=fp8)


def state_shardings(model_cfg, run_cfg, rules, mesh, abstract_state: TrainState):
    """NamedSharding tree matching TrainState (moments inherit param specs)."""
    p_sh = rules.param_shardings(model_cfg, mesh, abstract_state.params)
    from jax.sharding import NamedSharding, PartitionSpec as P

    step_sh = NamedSharding(mesh, P())
    opt_sh = AdamWState(step=step_sh, m=p_sh, v=p_sh)
    res_sh = None if abstract_state.compress_residual is None else p_sh
    # fp8 scales/amax windows are O(sites) scalars — replicate everywhere
    fp8_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), abstract_state.fp8)
    return TrainState(params=p_sh, opt=opt_sh, compress_residual=res_sh, fp8=fp8_sh)


def make_train_step(model_cfg, run_cfg, rules=None, mesh=None, *, q_chunk=0, param_shardings=None):
    """Build step(state, batch) -> (state, metrics).

    ``param_shardings`` (NamedSharding tree matching params) pins the bf16
    compute-cast of the master weights to the FSDP sharding — the explicit
    ZeRO-3 boundary.  XLA then all-gathers each layer's weights *inside* the
    layer scan (on demand) and reduce-scatters its gradients per iteration,
    instead of materializing the whole stacked weight/grad tree per device
    (measured: 22 GB/device of unsharded fp32 grads on llama-90b without
    this).  Gradients arrive in compute dtype (bf16); Adam upcasts.
    """
    prec, par, tr = run_cfg.precision, run_cfg.parallel, run_cfg.train
    compute_dtype = DTYPES[prec.compute_dtype]
    sh = rules.make_sharder(mesh) if (rules is not None and mesh is not None) else None
    schedule = make_schedule(
        "cosine", base_lr=tr.learning_rate, warmup_steps=tr.warmup_steps, total_steps=tr.total_steps
    )
    use_fp8 = _fp8_enabled(model_cfg, prec)
    if use_fp8:
        from repro.fp8 import make_fp8_ctx

    def make_loss(fp8_state):
        def batch_loss(params, batch):
            # NOTE: no whole-tree pre-cast — each weight use casts its own layer
            # slice inside the scan body (see forward's compute_dtype docstring),
            # so stacked params AND their grads stay FSDP-sharded through the
            # loop.  A hoisted bf16 tree costs ~33 GB/device on llama-90b.
            # A fresh Fp8Ctx per trace: its amax observations are trace-local.
            fp8 = make_fp8_ctx(model_cfg, prec, fp8_state) if use_fp8 else None
            l, aux = loss_fn(
                model_cfg,
                params,
                batch,
                sh=sh,
                q_chunk=q_chunk,
                remat=par.remat,
                z_loss=tr.z_loss,
                compute_dtype=compute_dtype,
                fp8=fp8,
            )
            if not use_fp8:
                aux = aux + (None,)  # uniform (ce, aux_loss, amaxes) shape
            return l, aux

        return batch_loss

    def step(state: TrainState, batch):
        grad_fn = jax.value_and_grad(make_loss(state.fp8), has_aux=True)
        nmb = par.num_microbatches
        if nmb > 1:

            def micro(carry, mb):
                g_acc, l_acc, a_acc, am_acc = carry
                (l, (ce, aux, am)), g = grad_fn(state.params, mb)
                # keep the fp32 accumulator on the FSDP sharding
                if param_shardings is not None:
                    g = jax.tree.map(
                        lambda x, s: jax.lax.with_sharding_constraint(x, s), g, param_shardings
                    )
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                am_acc = jax.tree.map(jnp.maximum, am_acc, am)  # both None when fp8 off
                return (g_acc, l_acc + ce, a_acc + aux, am_acc), None

            mb_batch = jax.tree.map(
                lambda x: x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:]), batch
            )
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            am0 = jax.tree.map(jnp.zeros_like, state.fp8.scale) if use_fp8 else None
            (grads, ce, aux, amaxes), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32), am0), mb_batch
            )
            grads = jax.tree.map(lambda g: g / nmb, grads)
            ce, aux = ce / nmb, aux / nmb
        else:
            (_, (ce, aux, amaxes)), grads = grad_fn(state.params, batch)

        new_fp8 = state.fp8
        if use_fp8:
            from repro.fp8 import update_fp8_state
            from repro.fp8.quantize import FP8_DTYPES

            new_fp8 = update_fp8_state(
                state.fp8, amaxes, dtype=FP8_DTYPES[prec.fp8_dtype], margin=prec.fp8_margin
            )

        residual = state.compress_residual
        if par.grad_compression != "none":
            grads, residual = compress_gradients(grads, residual, par.grad_compression)

        lr = schedule(state.opt.step)
        new_params, new_opt, om = adamw_update(
            state.params,
            grads,
            state.opt,
            lr=lr,
            beta1=tr.beta1,
            beta2=tr.beta2,
            eps=tr.eps,
            weight_decay=tr.weight_decay,
            grad_clip=tr.grad_clip,
            layer_scan=par.optimizer_layer_scan,
        )
        metrics = {"loss": ce, "aux_loss": aux, "lr": lr, **om}
        return (
            TrainState(params=new_params, opt=new_opt, compress_residual=residual, fp8=new_fp8),
            metrics,
        )

    return step
