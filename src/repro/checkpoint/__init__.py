from repro.checkpoint.manager import CheckpointManager, SaveRecord
from repro.checkpoint.storage import QOS_TIER, TIERS, DataMover, StorageTier
from repro.checkpoint.tensorstore_lite import (
    available_steps,
    checkpoint_bytes,
    restore_pytree,
    save_pytree,
)

__all__ = [
    "CheckpointManager",
    "SaveRecord",
    "QOS_TIER",
    "TIERS",
    "DataMover",
    "StorageTier",
    "available_steps",
    "checkpoint_bytes",
    "restore_pytree",
    "save_pytree",
]
