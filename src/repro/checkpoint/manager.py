"""Async checkpoint manager with storage-tier awareness.

The flex-start guarantee (paper §IV.F) rests on periodic checkpoints being
cheap: saves run on a background thread (training never blocks on Lustre),
the newest-k retention policy garbage-collects, and the tier is picked per
QoS class (training -> lustre, fine-tuning/inference -> vast, scratch ->
node-local NVMe).  The manager also *models* what the save would cost on the
real facility tiers so the scheduler can reason about checkpoint cadence at
480 B-parameter scale.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Optional

from repro.checkpoint.storage import QOS_TIER, TIERS
from repro.checkpoint.tensorstore_lite import (
    available_steps,
    checkpoint_bytes,
    delete_step,
    restore_pytree,
    save_pytree,
)


@dataclass
class SaveRecord:
    step: int
    nbytes: int
    tier: str
    modeled_seconds: float  # what this save costs on the facility tier
    wall_seconds: float  # what it actually took locally
    path: str


class CheckpointManager:
    """Background-threaded, atomic, newest-k checkpointing."""

    def __init__(
        self,
        directory: str | Path,
        *,
        keep: int = 3,
        qos: str = "training",
        tier: Optional[str] = None,
        async_save: bool = True,
        nodes: int = 1,
    ):
        self.directory = Path(directory)
        self.keep = keep
        self.tier_name = tier or QOS_TIER.get(qos, "lustre")
        self.async_save = async_save
        self.nodes = nodes
        self.records: list[SaveRecord] = []
        self._q: queue.Queue = queue.Queue()
        self._error: Optional[BaseException] = None
        self._worker: Optional[threading.Thread] = None
        if async_save:
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------
    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            tree, step, extra = item
            try:
                self._save_now(tree, step, extra)
            except BaseException as e:  # surfaced on next wait()/save()
                self._error = e
            finally:
                self._q.task_done()

    def _save_now(self, tree: Any, step: int, extra: dict) -> SaveRecord:
        nbytes = checkpoint_bytes(tree)
        tier = TIERS[self.tier_name]
        files = len(list(self.directory.glob("*"))) + 1
        modeled = tier.write_seconds(nbytes, files=max(files, 1))
        t0 = time.monotonic()
        path = save_pytree(tree, self.directory, step=step, extra=extra)
        wall = time.monotonic() - t0
        rec = SaveRecord(step, nbytes, self.tier_name, modeled, wall, str(path))
        self.records.append(rec)
        self._gc()
        return rec

    def _gc(self) -> None:
        steps = available_steps(self.directory)
        for s in steps[: -self.keep]:
            delete_step(self.directory, s)

    # ------------------------------------------------------------------
    def save(self, tree: Any, *, step: int, extra: dict | None = None, block: bool = False):
        if self._error is not None:
            e, self._error = self._error, None
            raise e
        extra = extra or {}
        if self.async_save and not block:
            # snapshot to host memory so training can mutate device buffers
            import jax

            snap = jax.tree.map(lambda x: jax.device_get(x), tree)
            self._q.put((snap, step, extra))
            return None
        return self._save_now(tree, step, extra)

    def wait(self) -> None:
        if self.async_save:
            self._q.join()
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def restore(self, like: Any, *, step: int | None = None) -> tuple[Any, dict]:
        self.wait()
        tree, extra = restore_pytree(like, self.directory, step=step)
        rd = TIERS[self.tier_name]
        extra["modeled_restore_seconds"] = rd.read_seconds(checkpoint_bytes(tree))
        return tree, extra

    def latest_step(self) -> Optional[int]:
        steps = available_steps(self.directory)
        return steps[-1] if steps else None

    def close(self) -> None:
        if self._worker is not None:
            self._q.put(None)
            self._worker.join(timeout=10)
            self._worker = None

    # ------------------------------------------------------------------
    def cadence_advice(self, *, step_seconds: float, nbytes: int, mtbf_node_hours: float = 50_000.0) -> dict:
        """Young/Daly-style optimal checkpoint interval for this tier.

        MTBF of the JOB = node MTBF / nodes (independent failures).  The
        paper-scale reference: 1,320 nodes at 50k-hour node MTBF -> ~38 h job
        MTBF; with Lustre-speed saves the optimal cadence comes out minutes.
        """
        import math

        tier = TIERS[self.tier_name]
        save_s = tier.write_seconds(nbytes)
        mtbf_s = mtbf_node_hours * 3600.0 / max(self.nodes, 1)
        opt = math.sqrt(2.0 * save_s * mtbf_s)  # Young's approximation
        return {
            "save_seconds_modeled": save_s,
            "job_mtbf_hours": mtbf_s / 3600.0,
            "optimal_interval_seconds": opt,
            "optimal_interval_steps": max(1, int(opt / max(step_seconds, 1e-9))),
            "overhead_fraction": save_s / max(opt, 1e-9),
        }
