"""Sharded on-disk checkpoint format (no orbax/tensorstore in this image).

Layout (one directory per step):

    <dir>/step_000042/
        manifest.json       # tree structure, shapes, dtypes, step metadata
        <leaf-path>.npy     # one array file per pytree leaf ("/" -> "__")

Writes are atomic per step (directory renamed into place on commit) so a
failure mid-write never corrupts the restore point — the fault-tolerance
tests kill saves mid-flight on purpose.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.utils.pytree import flatten_with_paths

_SEP = "__"


def _fname(path: str) -> str:
    return path.replace("/", _SEP) + ".npy"


def save_pytree(tree: Any, directory: str | os.PathLike, *, step: int, extra: dict | None = None) -> Path:
    """Atomic checkpoint write. Returns the committed directory."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_"))
    leaves = flatten_with_paths(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    try:
        for path, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            store = arr
            if arr.dtype.kind not in "fiub" or str(arr.dtype) not in (
                "float64", "float32", "float16", "int64", "int32", "int16", "int8",
                "uint64", "uint32", "uint16", "uint8", "bool",
            ):
                # ml_dtypes (bfloat16, fp8) don't survive np.save: store raw bits
                store = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            np.save(tmp / _fname(path), store)
            manifest["leaves"][path] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def checkpoint_bytes(tree: Any) -> int:
    from repro.utils.pytree import tree_size_bytes

    return tree_size_bytes(tree)


def available_steps(directory: str | os.PathLike) -> list[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return sorted(steps)


def restore_pytree(like: Any, directory: str | os.PathLike, *, step: int | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (shape/dtype validated).

    Returns (tree, manifest_extra)."""
    directory = Path(directory)
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    src = directory / f"step_{step:08d}"
    manifest = json.loads((src / "manifest.json").read_text())

    import ml_dtypes  # noqa: F401 — registers bfloat16/fp8 dtype names

    paths = flatten_with_paths(like)
    leaves_out = []
    for path, leaf in paths:
        meta = manifest["leaves"].get(path)
        if meta is None:
            raise KeyError(f"checkpoint {src} missing leaf {path!r}")
        arr = np.load(src / _fname(path))
        saved_dtype = np.dtype(meta["dtype"])
        if arr.dtype != saved_dtype:
            arr = arr.view(saved_dtype)  # raw-bit storage of ml_dtypes arrays
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{path}: checkpoint shape {arr.shape} != expected {want_shape}")
        dtype = getattr(leaf, "dtype", arr.dtype)
        leaves_out.append(arr.astype(dtype) if arr.dtype != dtype else arr)
    treedef = jax.tree.structure(like)
    tree = jax.tree.unflatten(treedef, leaves_out)
    return tree, manifest.get("extra", {})


def delete_step(directory: str | os.PathLike, step: int) -> None:
    shutil.rmtree(Path(directory) / f"step_{step:08d}", ignore_errors=True)
