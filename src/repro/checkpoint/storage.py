"""Storage-tier models: the paper's three-tier AI storage architecture.

Paper §III.E/IV.E — Isambard-AI provisions *heterogeneous* storage because AI
I/O differs from HPC simulation I/O:

* ``lustre`` — all-flash ClusterStor E1000: 20.3 PiB, up to 1,980 GB/s write /
  2,500 GB/s read aggregate, 35 M read IOPS (training datasets + checkpoints)
* ``vast``   — VAST SDS: 3.56 PB native, multi-protocol QoS tier (inference
  model serving, sensitive multi-tenant data; read-optimized, dedup 1.6:1)
* ``local``  — 3.84 TB node-local NVMe (scratch, small/sensitive payloads)

plus DMF-style movers to ``tape`` and ``cloud`` object storage.  The tier
objects model transfer times + capacity so the checkpoint manager and the
scheduler can reason about checkpoint cadence cost (flex-start guarantee) —
and tests can assert e.g. that a 480 B-param checkpoint on Lustre stays
inside the paper's write envelope.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class StorageTier:
    name: str
    write_bw: float  # aggregate bytes/s
    read_bw: float
    write_iops: float
    read_iops: float
    capacity: float  # bytes
    scope: str  # "global" | "node"
    data_reduction: float = 1.0  # VAST similarity dedup (logical/physical)

    def write_seconds(self, nbytes: float, files: int = 1) -> float:
        return nbytes / self.data_reduction / self.write_bw + files / self.write_iops

    def read_seconds(self, nbytes: float, files: int = 1) -> float:
        return nbytes / self.data_reduction / self.read_bw + files / self.read_iops


TIB = 1024**4
PIB = 1024**5

TIERS: dict[str, StorageTier] = {
    "lustre": StorageTier(
        name="lustre",
        write_bw=1_980e9,
        read_bw=2_500e9,
        write_iops=3.7e6,
        read_iops=35e6,
        capacity=20.3 * PIB,
        scope="global",
    ),
    "vast": StorageTier(
        name="vast",
        write_bw=80e9,  # C-node bound; read-optimized tier
        read_bw=400e9,
        write_iops=1e6,
        read_iops=10e6,
        capacity=3.56e15,
        data_reduction=1.6,
        scope="global",
    ),
    "local": StorageTier(
        name="local",
        write_bw=3.0e9,  # per-node NVMe
        read_bw=6.0e9,
        write_iops=500e3,
        read_iops=1e6,
        capacity=3.84e12,
        scope="node",
    ),
    "tape": StorageTier(
        name="tape",
        write_bw=1.2e9,
        read_bw=1.2e9,
        write_iops=10,
        read_iops=10,
        capacity=500 * PIB,
        scope="archive",
    ),
    "cloud": StorageTier(
        name="cloud",
        write_bw=10e9,
        read_bw=10e9,
        write_iops=3e3,
        read_iops=3e3,
        capacity=float("inf"),
        scope="archive",
    ),
}

# QoS-class -> default checkpoint tier (paper: training writes to Lustre at
# full bandwidth; inference reads models from the VAST QoS tier; scratch on
# node-local NVMe)
QOS_TIER = {
    "training": "lustre",
    "fine_tuning": "vast",
    "experimentation": "local",
    "inference": "vast",
}


@dataclass
class DataMover:
    """DMF-style policy-driven data motion between tiers (paper §IV.E)."""

    log: list = field(default_factory=list)

    def move_seconds(self, nbytes: float, src: str, dst: str, files: int = 1) -> float:
        s, d = TIERS[src], TIERS[dst]
        t = max(s.read_seconds(nbytes, files), d.write_seconds(nbytes, files))
        self.log.append({"bytes": nbytes, "src": src, "dst": dst, "seconds": t})
        return t

    def archive_policy(self, age_days: float, accessed_days: float) -> str | None:
        """HSM policy: cold data tiers down (lustre -> vast -> tape)."""
        if accessed_days > 180:
            return "tape"
        if accessed_days > 30:
            return "vast"
        return None
