"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay.

arXiv:2404.05892.  The per-head recurrence (head size n):

    S_t   = diag(w_t) . S_{t-1} + k_t v_t^T          (state: n x n)
    out_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)

with w_t = exp(-exp(ww_t)) a *data-dependent* per-channel decay (the Finch
novelty vs RWKV5), and all of r,k,v,g,ww produced from token-shifted inputs
through low-rank adapters.

Training/prefill uses a **chunked parallel formulation** (GLA-style):
within a chunk of length L the pairwise decay tensor
``exp(la_{t-1} - la_s)`` (s <= t-1, always <= 0 in log space, hence safe)
is materialized per head, giving matmul-shaped work for the MXU; the state is
carried across chunks with a lax.scan.  ``repro/kernels/rwkv6_scan.py`` is the
Pallas version of the same scheme; ``repro/kernels/rwkv6_ref.py`` holds the
sequential oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ffn import ffn_specs
from repro.models.layers import ParamSpec, group_norm_heads


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def time_mix_specs(cfg) -> dict:
    D = cfg.d_model
    r = cfg.rwkv
    H, n = cfg.num_heads, r.head_size
    assert H * n == D, f"rwkv: heads({H}) * head_size({n}) != d_model({D})"
    return {
        "maa_x": ParamSpec((D,), ("embed",), "zeros"),
        "maa_w": ParamSpec((D,), ("embed",), "zeros"),
        "maa_k": ParamSpec((D,), ("embed",), "zeros"),
        "maa_v": ParamSpec((D,), ("embed",), "zeros"),
        "maa_r": ParamSpec((D,), ("embed",), "zeros"),
        "maa_g": ParamSpec((D,), ("embed",), "zeros"),
        "maa_w1": ParamSpec((D, 5 * r.lora_rank_mix), ("embed", None), "normal", 0.1),
        "maa_w2": ParamSpec((5, r.lora_rank_mix, D), (None, None, "embed"), "normal", 0.1),
        "decay": ParamSpec((D,), ("embed",), "rwkv_decay"),
        "decay_w1": ParamSpec((D, r.lora_rank_decay), ("embed", None), "normal", 0.1),
        "decay_w2": ParamSpec((r.lora_rank_decay, D), (None, "embed"), "normal", 0.1),
        "bonus": ParamSpec((H, n), ("heads", None), "normal"),  # "u" / time_faaaa
        "w_r": ParamSpec((D, D), ("embed", "heads_x_dim")),
        "w_k": ParamSpec((D, D), ("embed", "heads_x_dim")),
        "w_v": ParamSpec((D, D), ("embed", "heads_x_dim")),
        "w_g": ParamSpec((D, D), ("embed", "heads_x_dim")),
        "w_o": ParamSpec((D, D), ("heads_x_dim", "embed")),
        "ln_x_scale": ParamSpec((D,), ("embed",), "ones"),
        "ln_x_bias": ParamSpec((D,), ("embed",), "zeros"),
    }


def channel_mix_specs(cfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "maa_k": ParamSpec((D,), ("embed",), "zeros"),
        "maa_r": ParamSpec((D,), ("embed",), "zeros"),
        "w_k": ParamSpec((D, F), ("embed", "mlp")),
        "w_v": ParamSpec((F, D), ("mlp", "embed")),
        "w_r": ParamSpec((D, D), ("embed", "heads_x_dim")),
    }


# ---------------------------------------------------------------------------
# token shift
# ---------------------------------------------------------------------------


def token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x_{t-1} stream; ``prev`` is the last token of the previous segment."""
    B = x.shape[0]
    if prev is None:
        prev = jnp.zeros((B, 1, x.shape[-1]), x.dtype)
    else:
        prev = prev.reshape(B, 1, x.shape[-1]).astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


# ---------------------------------------------------------------------------
# time mix
# ---------------------------------------------------------------------------


def _projections(cfg, p, x, x_prev):
    """Token-shifted, LoRA-mixed projections -> r,k,v,g,logw (all (B,S,...))."""
    dt = x.dtype
    sx = x_prev - x
    xxx = x + sx * p["maa_x"].astype(dt)
    B, S, D = x.shape
    r_mix = cfg.rwkv.lora_rank_mix
    a = jnp.tanh(xxx @ p["maa_w1"].astype(dt)).reshape(B, S, 5, r_mix)
    mixes = jnp.einsum("bsfr,frd->bsfd", a, p["maa_w2"].astype(dt))  # (B,S,5,D)
    mw, mk, mv, mr, mg = [mixes[:, :, i] for i in range(5)]
    xw = x + sx * (p["maa_w"].astype(dt) + mw)
    xk = x + sx * (p["maa_k"].astype(dt) + mk)
    xv = x + sx * (p["maa_v"].astype(dt) + mv)
    xr = x + sx * (p["maa_r"].astype(dt) + mr)
    xg = x + sx * (p["maa_g"].astype(dt) + mg)

    r = xr @ p["w_r"].astype(dt)
    k = xk @ p["w_k"].astype(dt)
    v = xv @ p["w_v"].astype(dt)
    g = jax.nn.silu(xg @ p["w_g"].astype(dt))
    # data-dependent decay, fp32: logw = -exp(ww) <= 0
    ww = p["decay"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["decay_w1"].astype(dt)).astype(jnp.float32) @ p["decay_w2"].astype(jnp.float32)
    )
    logw = -jnp.exp(ww)  # (B,S,D)
    return r, k, v, g, logw


def _wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Chunked-parallel WKV. r,k,v: (B,S,H,n) fp32; logw: (B,S,H,n) fp32 (<=0);
    u: (H,n); state: (B,H,n,n) fp32. Returns (out (B,S,H,n), new_state)."""
    B, S, H, n = r.shape
    if S % chunk != 0:
        chunk = S  # fall back to a single chunk
    nc = S // chunk

    def reshape_c(x):
        return x.reshape(B, nc, chunk, H, n).transpose(1, 0, 3, 2, 4)  # (nc,B,H,L,n)

    rc, kc, vc, lwc = map(reshape_c, (r, k, v, logw))

    tri_strict = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def body(S0, inp):
        rr, kk, vv, lw = inp  # (B,H,L,n)
        la = jnp.cumsum(lw, axis=2)  # inclusive log-decay products
        la_prev = la - lw  # la_{t-1} (exclusive)
        # inter-chunk: r~_t = r_t * exp(la_{t-1}) (safe: la_prev <= 0)
        r_dec = rr * jnp.exp(la_prev)
        out = jnp.einsum("bhtc,bhcv->bhtv", r_dec, S0)
        # intra-chunk: pairwise-safe decay tensor exp(la_{t-1} - la_s), s < t
        ddiff = la_prev[:, :, :, None, :] - la[:, :, None, :, :]  # (B,H,t,s,n)
        ddiff = jnp.where(tri_strict[None, None, :, :, None], ddiff, -jnp.inf)
        scores = jnp.einsum("bhtc,bhsc,bhtsc->bhts", rr, kk, jnp.exp(ddiff))
        out = out + jnp.einsum("bhts,bhsv->bhtv", scores, vv)
        # diagonal bonus term: (r_t . (u * k_t)) v_t
        diag = jnp.sum(rr * u[None, :, None, :] * kk, axis=-1)  # (B,H,L)
        out = out + diag[..., None] * vv
        # state update: S' = diag(exp(la_L)) S0 + sum_s exp(la_L - la_s) k_s v_s^T
        la_last = la[:, :, -1:, :]  # (B,H,1,n)
        k_dec = kk * jnp.exp(la_last - la)  # safe: la_last >= la_s
        S1 = jnp.exp(la_last.squeeze(2))[..., None] * S0 + jnp.einsum("bhsc,bhsv->bhcv", k_dec, vv)
        return S1, out

    # remat: the pairwise decay tensor must not be saved for every chunk
    state, outs = jax.lax.scan(jax.checkpoint(body), state, (rc, kc, vc, lwc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, n)
    return out, state


def time_mix(cfg, p, x, *, prev_x=None, state=None, sh=None):
    """Full-sequence RWKV6 time mixing.

    Returns (out, (last_x, new_state)) so prefill can hand the recurrent state
    to the decode loop.
    """
    B, S, D = x.shape
    H, n = cfg.num_heads, cfg.rwkv.head_size
    x_prev = token_shift(x, prev_x)
    r, k, v, g, logw = _projections(cfg, p, x, x_prev)
    rh = r.reshape(B, S, H, n).astype(jnp.float32)
    kh = k.reshape(B, S, H, n).astype(jnp.float32)
    vh = v.reshape(B, S, H, n).astype(jnp.float32)
    lw = logw.reshape(B, S, H, n)
    if state is None:
        state = jnp.zeros((B, H, n, n), jnp.float32)
    u = p["bonus"].astype(jnp.float32)
    out, new_state = _wkv_chunked(rh, kh, vh, lw, u, state, cfg.rwkv.chunk_size)
    out = out.reshape(B, S, D).astype(x.dtype)
    out = group_norm_heads(out, p["ln_x_scale"], p["ln_x_bias"], H, 64e-5)
    out = out * g
    out = out @ p["w_o"].astype(x.dtype)
    return out, (x[:, -1], new_state)


def time_mix_step(cfg, p, x, prev_x, state):
    """Single-token decode step. x: (B,1,D); state: (B,H,n,n) fp32."""
    B, _, D = x.shape
    H, n = cfg.num_heads, cfg.rwkv.head_size
    x_prev = prev_x.reshape(B, 1, D).astype(x.dtype)
    r, k, v, g, logw = _projections(cfg, p, x, x_prev)
    rh = r.reshape(B, H, n).astype(jnp.float32)
    kh = k.reshape(B, H, n).astype(jnp.float32)
    vh = v.reshape(B, H, n).astype(jnp.float32)
    w = jnp.exp(logw.reshape(B, H, n))
    u = p["bonus"].astype(jnp.float32)
    a = kh[..., :, None] * vh[..., None, :]  # (B,H,n,n) outer product
    out = jnp.einsum("bhc,bhcv->bhv", rh, state + u[None, :, :, None] * a)
    new_state = w[..., None] * state + a
    out = out.reshape(B, 1, D).astype(x.dtype)
    out = group_norm_heads(out, p["ln_x_scale"], p["ln_x_bias"], H, 64e-5)
    out = out * g
    out = out @ p["w_o"].astype(x.dtype)
    return out, (x[:, -1], new_state)


# ---------------------------------------------------------------------------
# channel mix
# ---------------------------------------------------------------------------


def channel_mix(cfg, p, x, *, prev_x=None, sh=None):
    dt = x.dtype
    x_prev = token_shift(x, prev_x)
    sx = x_prev - x
    xk = x + sx * p["maa_k"].astype(dt)
    xr = x + sx * p["maa_r"].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(dt)))
    if sh is not None:
        k = sh(k, ("batch", "seq", "mlp"))
    kv = k @ p["w_v"].astype(dt)
    return jax.nn.sigmoid(xr @ p["w_r"].astype(dt)) * kv, x[:, -1]
