"""Shared model layers: norms, rotary embeddings, param-spec primitives.

Everything is a pure function over plain pytrees; ``ParamSpec`` trees describe
shapes/logical-axes/init so that the same tree definition serves
``init_params`` (real arrays), ``abstract_params`` (ShapeDtypeStructs for the
dry-run) and the sharding rule engine (logical axes -> PartitionSpec).
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis names, len == ndim
    init: str = "fan_in"  # "fan_in" | "normal" | "zeros" | "ones" | "rwkv_decay" | "ssm_a" | "ssm_dt"
    scale: float = 1.0  # extra multiplier on the init stddev / value


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_map(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------


def materialize(spec: ParamSpec, key: jax.Array, dtype) -> jax.Array:
    """Create a concrete parameter for ``spec``."""
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        return (spec.scale * 0.02 * jax.random.normal(key, shape)).astype(dtype)
    if spec.init == "fan_in":
        fan_in = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
        # For stacked (layers, ...) params the leading "layers" dim is not fan-in.
        if len(shape) >= 3 and spec.axes and spec.axes[0] == "layers":
            fan_in = int(np.prod(shape[1:-1]))
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.truncated_normal(key, -3.0, 3.0, shape)).astype(dtype)
    if spec.init == "rwkv_decay":
        # RWKV6 decay base: spread in [-6, -1] so exp(-exp(w)) spans slow/fast.
        n = shape[-1]
        ratio = jnp.arange(n) / max(n - 1, 1)
        base = -6.0 + 5.0 * ratio**0.7
        return jnp.broadcast_to(base, shape).astype(dtype)
    if spec.init == "ssm_a":
        # S4D-real init: A = -(1..N) per state channel.
        n = shape[-1]
        a = jnp.arange(1, n + 1, dtype=jnp.float32)
        return jnp.broadcast_to(jnp.log(a), shape).astype(dtype)
    if spec.init == "ssm_dt":
        # dt bias such that softplus(dt) ~ [1e-3, 1e-1] log-uniform.
        u = jax.random.uniform(key, shape)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return jnp.log(jnp.expm1(dt)).astype(dtype)  # inverse softplus
    raise ValueError(f"unknown init {spec.init!r}")


def init_tree(specs, key: jax.Array, dtype) -> Any:
    """Materialize a whole ParamSpec tree with per-leaf folded keys."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = [materialize(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_tree(specs, dtype) -> Any:
    return spec_tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_specs(cfg, d: int | None = None) -> dict:
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": ParamSpec((d,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((d,), ("embed",), "ones"), "bias": ParamSpec((d,), ("embed",), "zeros")}
    if cfg.norm == "layernorm_np":  # OLMo: non-parametric LN
        return {}
    raise ValueError(f"unknown norm {cfg.norm!r}")


def apply_norm(cfg, p: dict, x: jax.Array) -> jax.Array:
    """Normalize in fp32, cast back to input dtype (standard LM practice)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + cfg.norm_eps)
        xf = xf * p["scale"].astype(jnp.float32)
    else:  # layernorm / layernorm_np
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        if p:  # parametric
            xf = xf * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return xf.astype(dtype)


def group_norm_heads(x: jax.Array, scale: jax.Array, bias: jax.Array, num_heads: int, eps: float) -> jax.Array:
    """GroupNorm with one group per head over the channel dim (RWKV ln_x)."""
    dtype = x.dtype
    *lead, d = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, num_heads, d // num_heads)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(*lead, d) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return xf.astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, rotary_pct: float, theta: float) -> jax.Array:
    """Inverse frequencies for the rotated fraction of the head dim."""
    rot_dim = int(head_dim * rotary_pct) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, rotary_pct: float = 1.0, theta: float = 10000.0) -> jax.Array:
    """Apply RoPE. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    rot_dim = int(head_dim * rotary_pct) // 2 * 2
    inv_freq = rope_frequencies(head_dim, rotary_pct, theta)
    # angles: (..., seq, rot_dim/2)
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = x_rot[..., : rot_dim // 2], x_rot[..., rot_dim // 2 :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}
