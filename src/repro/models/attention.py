"""Attention: GQA/MQA self-attention, sliding window, cross-attention, decode.

Two execution paths:

* ``xla``   — pure-jnp attention with optional *query chunking* (a lax.scan over
  query blocks with a full softmax per block).  Memory is bounded by
  ``q_chunk x kv_len`` instead of ``q_len x kv_len``, which is what makes the
  32k prefill cells lowerable within a v5e HBM budget.  This is the path the
  dry-run lowers.
* ``flash`` — the Pallas TPU kernel in ``repro.kernels.flash_attention``
  (online-softmax VMEM tiling).  Selected via ``impl="flash"``; validated in
  interpret mode on CPU.

Shapes follow the (batch, seq, heads, head_dim) convention throughout.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, apply_norm, apply_rope, softcap

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def attention_specs(cfg, *, cross: bool = False) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.use_bias:
        specs["bq"] = ParamSpec((H, hd), ("heads", "head_dim"), "zeros")
        specs["bk"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), "zeros")
        specs["bv"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), "zeros")
        specs["bo"] = ParamSpec((D,), ("embed",), "zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), "ones")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), "ones")
    if cross:
        # Llama-3.2-vision style gating: cross-attn output enters the residual
        # through a zero-initialized tanh gate.
        specs["gate"] = ParamSpec((1,), (None,), "zeros")
    return specs


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------


def _qkv(cfg, p, x, kv_x=None, fp8=None):
    """Project to q,k,v. kv_x: source for k/v (cross-attention).

    ``fp8``: an ``repro.fp8.Fp8Ctx`` — routes the projection GEMMs through
    quantized matmuls (the head-split is a free reshape around a 2-D GEMM).
    """
    kv_src = x if kv_x is None else kv_x
    if fp8 is not None:
        D = p["wq"].shape[0]
        H, hd = p["wq"].shape[1], p["wq"].shape[2]
        KV = p["wk"].shape[1]
        q = fp8.matmul("attn_q", x, p["wq"].reshape(D, H * hd)).reshape(x.shape[:-1] + (H, hd))
        k = fp8.matmul("attn_k", kv_src, p["wk"].reshape(D, KV * hd)).reshape(kv_src.shape[:-1] + (KV, hd))
        v = fp8.matmul("attn_v", kv_src, p["wv"].reshape(D, KV * hd)).reshape(kv_src.shape[:-1] + (KV, hd))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(x.dtype))
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = _rms_head(q, p["q_norm"], cfg.norm_eps)
        k = _rms_head(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rms_head(x, scale, eps):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def _out(cfg, p, ctx, dtype, fp8=None):
    if fp8 is not None:
        H, hd, D = p["wo"].shape
        out = fp8.matmul("attn_o", ctx.reshape(ctx.shape[:-2] + (H * hd,)), p["wo"].reshape(H * hd, D))
        out = out.astype(dtype)
    else:
        out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(dtype))
    if cfg.use_bias:
        out = out + p["bo"].astype(dtype)
    return out


# ---------------------------------------------------------------------------
# core attention math (grouped heads, fp32 softmax)
# ---------------------------------------------------------------------------


def _scores(q, k, q_per_kv, scale):
    """q: (B,Sq,H,hd), k: (B,Skv,KV,hd) -> (B,KV,G,Sq,Skv) fp32."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, Sq, KV, q_per_kv, hd)
    s = jnp.einsum("bqhgk,bshk->bhgqs", qg, k)
    return s.astype(jnp.float32) * scale


def _attend_block(cfg, q, k, v, mask, q_per_kv):
    """Exact softmax attention for one (possibly chunked) query block.

    mask: (B?, 1, 1, Sq, Skv) additive fp32 mask (0 / NEG_INF).
    """
    scale = 1.0 / math.sqrt(cfg.head_dim)
    s = _scores(q, k, q_per_kv, scale)
    if cfg.attn_logit_softcap > 0:
        s = softcap(s, cfg.attn_logit_softcap)
    s = s + mask
    w = jax.nn.softmax(s, axis=-1)
    B, Sq = q.shape[0], q.shape[1]
    ctx = jnp.einsum("bhgqs,bshk->bqhgk", w.astype(v.dtype), v)
    return ctx.reshape(B, Sq, cfg.num_heads, cfg.head_dim)


def make_mask(q_pos, kv_pos, *, causal: bool, window: int) -> jax.Array:
    """Additive mask (..., Sq, Skv) from absolute positions."""
    rel = q_pos[..., :, None] - kv_pos[..., None, :]  # q - kv
    ok = jnp.ones(rel.shape, bool)
    if causal:
        ok &= rel >= 0
    if window > 0:
        ok &= rel < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def self_attention(
    cfg,
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array | None = None,
    q_chunk: int = 0,
    impl: str = "xla",
    sh=None,
    fp8=None,
) -> jax.Array:
    """Full-sequence self-attention (training / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _qkv(cfg, p, x, fp8=fp8)
    if cfg.rotary_pct > 0 and not cfg.learned_pos_embedding:
        q = apply_rope(q, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
        k = apply_rope(k, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
    if sh is not None:
        q = sh(q, ("batch", "seq", "heads", None))
        # K/V: head-sharded when kv_heads divides the model axis, else
        # REPLICATED (Megatron GQA duplication).  The seq-parallel fallback
        # is deliberately absent: seq-sharded K/V against head-sharded scores
        # forces XLA into "involuntary full rematerialization" reshards
        # inside every layer loop (measured 80+ s collective term on
        # mistral-nemo train_4k — EXPERIMENTS.md §Perf iteration 1).
        k = sh(k, ("batch", None, "kv_heads", None))
        v = sh(v, ("batch", None, "kv_heads", None))

    if impl == "flash":
        from repro.kernels import flash_attention_ops

        ctx = flash_attention_ops.flash_attention(
            q, k, v, causal=cfg.causal, window=cfg.sliding_window, softcap=cfg.attn_logit_softcap
        )
        return _out(cfg, p, ctx, x.dtype, fp8=fp8)

    qpk = cfg.q_per_kv
    if q_chunk and S > q_chunk and S % q_chunk == 0:
        nchunk = S // q_chunk
        qs = q.reshape(B, nchunk, q_chunk, cfg.num_heads, cfg.head_dim).transpose(1, 0, 2, 3, 4)
        pos_q = positions.reshape(B, nchunk, q_chunk).transpose(1, 0, 2)

        def body(carry, inp):
            qc, pq = inp
            m = make_mask(pq, positions, causal=cfg.causal, window=cfg.sliding_window)
            m = m[:, None, None]  # (B,1,1,qc,S)
            return carry, _attend_block(cfg, qc, k, v, m, qpk)

        # remat: without it the scan saves every chunk's (qc x S) score matrix
        _, ctx = jax.lax.scan(jax.checkpoint(body), None, (qs, pos_q))
        ctx = ctx.transpose(1, 0, 2, 3, 4).reshape(B, S, cfg.num_heads, cfg.head_dim)
    else:
        m = make_mask(positions, positions, causal=cfg.causal, window=cfg.sliding_window)
        ctx = _attend_block(cfg, q, k, v, m[:, None, None], qpk)
    if sh is not None:
        ctx = sh(ctx, ("batch", "seq", "heads", None))
    return _out(cfg, p, ctx, x.dtype, fp8=fp8)


def cross_attention(cfg, p: dict, x: jax.Array, kv_tokens: jax.Array, *, sh=None) -> jax.Array:
    """Cross-attention onto (unpositioned) vision tokens, with tanh gating."""
    q, k, v = _qkv(cfg, p, x, kv_x=kv_tokens)
    B, Sq = x.shape[:2]
    zero = jnp.zeros((B, 1, 1, Sq, kv_tokens.shape[1]), jnp.float32)
    ctx = _attend_block(cfg, q, k, v, zero, cfg.q_per_kv)
    out = _out(cfg, p, ctx, x.dtype)
    return jnp.tanh(p["gate"].astype(x.dtype)) * out


def prefill_attention(cfg, p, x, *, positions=None, q_chunk: int = 0, sh=None):
    """Self-attention that also returns the K/V tensors for the cache."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _qkv(cfg, p, x)
    if cfg.rotary_pct > 0 and not cfg.learned_pos_embedding:
        q = apply_rope(q, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
        k = apply_rope(k, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
    qpk = cfg.q_per_kv
    if q_chunk and S > q_chunk and S % q_chunk == 0:
        nchunk = S // q_chunk
        qs = q.reshape(B, nchunk, q_chunk, cfg.num_heads, cfg.head_dim).transpose(1, 0, 2, 3, 4)
        pos_q = positions.reshape(B, nchunk, q_chunk).transpose(1, 0, 2)

        def body(carry, inp):
            qc, pq = inp
            m = make_mask(pq, positions, causal=cfg.causal, window=cfg.sliding_window)
            return carry, _attend_block(cfg, qc, k, v, m[:, None, None], qpk)

        _, ctx = jax.lax.scan(jax.checkpoint(body), None, (qs, pos_q))
        ctx = ctx.transpose(1, 0, 2, 3, 4).reshape(B, S, cfg.num_heads, cfg.head_dim)
    else:
        m = make_mask(positions, positions, causal=cfg.causal, window=cfg.sliding_window)
        ctx = _attend_block(cfg, q, k, v, m[:, None, None], qpk)
    return _out(cfg, p, ctx, x.dtype), k, v


def decode_attention(
    cfg,
    p: dict,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cache_pos: jax.Array,
    pos: jax.Array,
    *,
    sh=None,
):
    """Single-token decode against a (possibly ring-buffered) KV cache.

    x:        (B, 1, D) current token embedding stream
    cache_k/v:(B, W, KV, hd) cache buffer (W = full seq or sliding window)
    cache_pos:(B, W) absolute position held in each slot (-1 = empty)
    pos:      (B,) absolute position of the current token
    Returns (out, new_k, new_v, new_cache_pos).
    """
    B, W = cache_pos.shape
    q, k, v = _qkv(cfg, p, x)
    if cfg.rotary_pct > 0 and not cfg.learned_pos_embedding:
        q = apply_rope(q, pos[:, None], rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
        k = apply_rope(k, pos[:, None], rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)

    slot = pos % W  # ring-buffer slot (full cache: W >= S so slot == pos)
    b_idx = jnp.arange(B)
    # scatter write: fuses into an in-place update on the donated cache buffer
    new_k = cache_k.at[b_idx, slot].set(k[:, 0].astype(cache_k.dtype))
    new_v = cache_v.at[b_idx, slot].set(v[:, 0].astype(cache_v.dtype))
    new_cache_pos = cache_pos.at[b_idx, slot].set(pos)

    m = make_mask(pos[:, None], new_cache_pos, causal=cfg.causal, window=cfg.sliding_window)
    m = jnp.where(new_cache_pos[:, None, :] < 0, NEG_INF, m)  # empty slots
    ctx = _attend_block(cfg, q, new_k, new_v, m[:, None, None], cfg.q_per_kv)
    return _out(cfg, p, ctx, x.dtype), new_k, new_v, new_cache_pos


def paged_chunk_attention(
    cfg,
    p: dict,
    x: jax.Array,
    cache: dict,
    tbl_row: jax.Array,
    start: jax.Array,
    *,
    impl: str = "xla",
    sh=None,
    mesh=None,
    widths: jax.Array | None = None,
):
    """Chunked-prefill attention against a paged (block-pooled) KV cache.

    x:       (B, C, D) chunk token embedding stream
    cache:   {"k","v": (N, bs, KV, hd) pools, "tbl": engine table (unused
             here — mid-prefill slots keep a null engine row so interleaved
             decode steps can't touch their blocks), ...}
    tbl_row: (B, nb) int32 — the *request's* block table, covering every
             logical block of prompt + generation
    start:   (B,) int32 absolute position of the chunk's first token.
    widths:  (B,) int32, optional — per-row count of VALID lanes.  Rows in a
             fused mixed batch feed fewer than C real tokens; lanes at or
             past ``widths[b]`` are redirected to the null block so their
             K/V scatter lands in scratch, never in a live block (same
             masked-scatter pattern as ``serving.kvcache.truncate_block_rows``).
             Their attention outputs are garbage the caller must discard.

    The chunk's K/V is scattered into its blocks first (position t lands in
    block ``tbl_row[b, t // bs]`` at offset ``t % bs``), then every chunk
    query attends causally over the logical view [0, start + offset] — the
    shared prefix blocks grafted by admission, earlier chunks, and this
    chunk itself.  ``impl="pallas"`` uses the multi-query-token
    ``kernels.paged_prefill_attention`` kernel, ``impl="xla"`` the jnp
    oracle; quantized (int8/fp8) pools quantize on the way in and take the
    dequantizing reference.  ``mesh``: tensor-parallel serving mesh — the
    Pallas kernel runs per-shard under ``shard_map`` on its local head slice
    (XLA reference fallback when the head counts don't divide the model
    axis).  Returns (out, new_cache) with the same keys as ``cache``.
    """
    from repro.serving.kvquant import kv_quant_mode_of

    k_pool, v_pool = cache["k"], cache["v"]
    B, C, _ = x.shape
    bs = k_pool.shape[1]
    quant_mode = kv_quant_mode_of(k_pool.dtype)
    quantized = quant_mode is not None

    positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (B, C)
    q, k, v = _qkv(cfg, p, x)
    if cfg.rotary_pct > 0 and not cfg.learned_pos_embedding:
        q = apply_rope(q, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
        k = apply_rope(k, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)

    # dead lanes may index past the table; clamp — their write goes to scratch
    idx = jnp.minimum(positions // bs, tbl_row.shape[1] - 1)
    phys = jnp.take_along_axis(tbl_row, idx, axis=1)  # (B, C)
    if widths is not None:
        from repro.models.cache import NULL_BLOCK

        lane = jnp.arange(C, dtype=jnp.int32)[None, :]
        phys = jnp.where(lane < widths[:, None], phys, NULL_BLOCK)
    off = positions % bs
    new_cache = dict(cache)
    if quantized:
        from repro.serving.kvquant import quantize

        kq, ks = quantize(k, quant_mode)
        vq, vs = quantize(v, quant_mode)
        new_cache["k"] = k_pool.at[phys, off].set(kq)
        new_cache["v"] = v_pool.at[phys, off].set(vq)
        new_cache["k_scale"] = cache["k_scale"].at[phys, off].set(ks)
        new_cache["v_scale"] = cache["v_scale"].at[phys, off].set(vs)
    else:
        new_cache["k"] = k_pool.at[phys, off].set(k.astype(k_pool.dtype))
        new_cache["v"] = v_pool.at[phys, off].set(v.astype(v_pool.dtype))

    if quantized:
        from repro.kernels.paged_attention_ops import paged_prefill_attention_quantized

        ctx = paged_prefill_attention_quantized(
            q,
            new_cache["k"],
            new_cache["v"],
            new_cache["k_scale"],
            new_cache["v_scale"],
            tbl_row,
            start,
            softcap=cfg.attn_logit_softcap,
            window=cfg.sliding_window,
        )
    elif impl == "pallas":
        from repro.kernels.paged_attention_ops import paged_prefill_attention

        ctx = paged_prefill_attention(
            q,
            new_cache["k"],
            new_cache["v"],
            tbl_row,
            start,
            softcap=cfg.attn_logit_softcap,
            window=cfg.sliding_window,
            mesh=mesh,
        )
    else:
        from repro.kernels.paged_attention_ref import paged_prefill_attention_ref

        ctx = paged_prefill_attention_ref(
            q,
            new_cache["k"],
            new_cache["v"],
            tbl_row,
            start,
            softcap=cfg.attn_logit_softcap,
            window=cfg.sliding_window,
        )
    return _out(cfg, p, ctx, x.dtype), new_cache


def paged_decode_attention(
    cfg,
    p: dict,
    x: jax.Array,
    cache: dict,
    pos: jax.Array,
    *,
    impl: str = "xla",
    sh=None,
    mesh=None,
):
    """Single-token decode against a paged (block-pooled) KV cache.

    x:     (B, 1, D) current token embedding stream
    cache: {"k","v": (N, bs, KV, hd) pools, "tbl": (B, nb) block table,
            ["k_scale","v_scale": (N, bs, KV, 1) for int8 pools]}
    pos:   (B,) absolute position of the current token.

    The new K/V lands in block ``tbl[b, pos // bs]`` at offset ``pos % bs``.
    Inactive batch slots carry all-null block tables, so their writes hit the
    reserved null block, never a live request's memory.  Attention runs over
    the logical view [0, pos] via the block table — ``impl="pallas"`` uses the
    ``kernels.paged_attention`` gather kernel, ``impl="xla"`` the jnp oracle.
    ``mesh``: tensor-parallel serving mesh for the Pallas path (see
    ``paged_chunk_attention``).

    Returns (out, new_cache) with the same keys as ``cache``.
    """
    from repro.serving.kvquant import kv_quant_mode_of

    k_pool, v_pool, tbl = cache["k"], cache["v"], cache["tbl"]
    B = x.shape[0]
    bs = k_pool.shape[1]
    quant_mode = kv_quant_mode_of(k_pool.dtype)
    quantized = quant_mode is not None

    q, k, v = _qkv(cfg, p, x)
    if cfg.rotary_pct > 0 and not cfg.learned_pos_embedding:
        q = apply_rope(q, pos[:, None], rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
        k = apply_rope(k, pos[:, None], rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)

    b_idx = jnp.arange(B)
    phys = tbl[b_idx, pos // bs]  # physical block holding this position
    off = pos % bs
    new_cache = dict(cache)
    if quantized:
        from repro.serving.kvquant import quantize

        kq, ks = quantize(k[:, 0], quant_mode)
        vq, vs = quantize(v[:, 0], quant_mode)
        new_cache["k"] = k_pool.at[phys, off].set(kq)
        new_cache["v"] = v_pool.at[phys, off].set(vq)
        new_cache["k_scale"] = cache["k_scale"].at[phys, off].set(ks)
        new_cache["v_scale"] = cache["v_scale"].at[phys, off].set(vs)
    else:
        new_cache["k"] = k_pool.at[phys, off].set(k[:, 0].astype(k_pool.dtype))
        new_cache["v"] = v_pool.at[phys, off].set(v[:, 0].astype(v_pool.dtype))

    seq_lens = pos + 1
    if quantized:
        from repro.kernels.paged_attention_ops import paged_attention_quantized

        ctx = paged_attention_quantized(
            q[:, 0],
            new_cache["k"],
            new_cache["v"],
            new_cache["k_scale"],
            new_cache["v_scale"],
            tbl,
            seq_lens,
            softcap=cfg.attn_logit_softcap,
            window=cfg.sliding_window,
        )
    elif impl == "pallas":
        from repro.kernels.paged_attention_ops import paged_attention

        ctx = paged_attention(
            q[:, 0],
            new_cache["k"],
            new_cache["v"],
            tbl,
            seq_lens,
            softcap=cfg.attn_logit_softcap,
            window=cfg.sliding_window,
            mesh=mesh,
        )
    else:
        from repro.kernels.paged_attention_ref import paged_attention_ref

        ctx = paged_attention_ref(
            q[:, 0],
            new_cache["k"],
            new_cache["v"],
            tbl,
            seq_lens,
            softcap=cfg.attn_logit_softcap,
            window=cfg.sliding_window,
        )
    out = _out(cfg, p, ctx[:, None], x.dtype)
    return out, new_cache
