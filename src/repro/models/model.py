"""The full model: embedding -> scanned block stack -> head.

Three entry points, all pure functions of (cfg, params, batch):

* ``forward``     — training forward pass: (logits, aux_loss)
* ``prefill``     — inference prefill: (last-position logits, stacked cache)
* ``decode_step`` — one-token decode:  (logits, new cache)

The block stack is a ``lax.scan`` over stacked (L, ...) parameters with a
configurable activation-checkpoint policy, so HLO size (and CPU compile time
in the dry-run) is independent of depth.  VLM architectures scan over
*layer groups* (cross_attn_every-1 self layers + 1 cross layer).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.layers import apply_norm, softcap

REMAT_POLICIES = {
    "none": None,
    "full": "nothing_saveable",
    "dots": "checkpoint_dots",
    "dots_no_batch": "checkpoint_dots_with_no_batch_dims",
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    policy = REMAT_POLICIES[remat]
    if policy == "nothing_saveable":
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=getattr(jax.checkpoint_policies, policy))


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def embed_input(cfg, params, batch, *, sh=None):
    """Returns (x, positions). batch keys: tokens|frames [, positions]."""
    e = params["embed"]
    if cfg.family == "audio":
        frames = batch["frames"]
        x = frames @ e["frame_proj"].astype(frames.dtype)
        S = x.shape[1]
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), x.shape[:2])
        x = x + e["pos"][:S][None].astype(x.dtype)
    else:
        tokens = batch["tokens"]
        x = jnp.take(e["tok"], tokens, axis=0)
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(tokens.shape[1], dtype=jnp.int32), tokens.shape)
        if cfg.learned_pos_embedding:
            x = x + jnp.take(e["pos"], pos, axis=0).astype(x.dtype)
        if cfg.scale_embedding:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if sh is not None:
        x = sh(x, ("batch", "seq", "embed"))
    return x, pos


def lm_logits(cfg, params, x, *, logits_dtype=jnp.float32, sh=None):
    """Final norm + output projection (tied or untied; padded-vocab mask)."""
    x = apply_norm(cfg, params["final_norm"], x)
    if sh is not None:
        # logits must be VOCAB-sharded, not seq-sharded: inheriting the
        # sequence-parallel sharding forces XLA to all-gather the fp32 vocab
        # table (measured 2.7 GB/device x several copies on mistral-nemo)
        x = sh(x, ("batch",) + ("seq_unsharded",) * (x.ndim - 2) + ("embed",))
    if "lm_head" in params:
        w = params["lm_head"].astype(x.dtype)
        logits = x @ w
    else:
        w = params["embed"]["tok"].astype(x.dtype)
        logits = jnp.einsum("...d,vd->...v", x, w)
    logits = logits.astype(logits_dtype)
    if sh is not None:
        logits = sh(logits, ("batch",) + ("seq_unsharded",) * (logits.ndim - 2) + ("vocab",))
    if cfg.padded_vocab != cfg.vocab_size:
        # mask the alignment-padding columns (never predicted / never labeled)
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col >= cfg.vocab_size, jnp.asarray(-1e9, logits.dtype), logits)
    return logits


# ---------------------------------------------------------------------------
# block-stack runners
# ---------------------------------------------------------------------------


def _train_body(cfg, *, positions, q_chunk, sh, attn_impl, vision_tokens=None, fp8=None):
    fam = cfg.family
    kw = dict(positions=positions, q_chunk=q_chunk, sh=sh, attn_impl=attn_impl)
    if fp8 is not None:
        from repro.fp8 import fp8_supported

        if not fp8_supported(cfg):
            # ssm has no quantizable projections; vlm scans layer *groups*
            # (amax drain across the nested scan is not wired)
            raise ValueError(f"fp8 training is not supported for family={fam}")
        kw["fp8"] = fp8

    if fam in ("dense", "audio"):

        def body(carry, p_layer):
            return (B.dense_block(cfg, p_layer, carry[0], **kw), carry[1]), None

    elif fam == "moe":

        def body(carry, p_layer):
            x, aux = carry
            x, a = B.moe_block(cfg, p_layer, x, **kw)
            return (x, aux + a), None

    elif fam == "ssm":

        def body(carry, p_layer):
            return (B.rwkv_block(cfg, p_layer, carry[0], sh=sh), carry[1]), None

    elif fam == "hybrid":

        def body(carry, p_layer):
            return (B.hybrid_block(cfg, p_layer, carry[0], **kw), carry[1]), None

    elif fam == "vlm":

        def body(carry, p_group):
            x, aux = carry

            def self_body(xc, p_layer):
                return B.dense_block(cfg, p_layer, xc, **kw), None

            x, _ = jax.lax.scan(self_body, x, p_group["self"])
            x = B.cross_block(cfg, p_group["cross"], x, vision_tokens, sh=sh)
            return (x, aux), None

    else:
        raise ValueError(fam)
    if fp8 is None:
        return body

    def body_fp8(carry, xs):
        # bind this layer's scale slice, run the family body, then emit the
        # layer's observed amaxes as a scan output (drain inside the body:
        # observations are tracers of THIS scan/remat trace and must not
        # escape it; per-layer ys keep one delayed scale per tensor)
        p_layer, scales = xs
        fp8.bind_layer_scales(scales)
        carry, _ = body(carry, p_layer)
        return carry, fp8.drain()

    return body_fp8


def forward(
    cfg, params, batch, *, sh=None, q_chunk=0, remat="none", attn_impl="xla", compute_dtype=None, fp8=None
):
    """Training forward. Returns (logits, aux_loss), or (logits, aux_loss,
    amaxes) when an ``fp8`` context is passed (see ``repro.fp8.policy``).

    ``compute_dtype``: cast the activation stream (not the master weights) —
    every weight use casts its own layer slice via ``.astype(x.dtype)``, which
    keeps the stacked fp32 params (and their gradients) on the FSDP sharding
    through the layer scan instead of materializing an unsharded bf16 tree.
    (FP8 sites instead quantize the fp32 slice directly — same sharding
    property, 1-byte wire format.)
    """
    x, positions = embed_input(cfg, params, batch, sh=sh)
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
    vision_tokens = batch.get("vision_tokens")
    if vision_tokens is not None and compute_dtype is not None:
        vision_tokens = vision_tokens.astype(compute_dtype)
    body = _train_body(
        cfg,
        positions=positions,
        q_chunk=q_chunk,
        sh=sh,
        attn_impl=attn_impl,
        vision_tokens=vision_tokens,
        fp8=fp8,
    )
    body = _maybe_remat(body, remat)
    aux0 = jnp.zeros((), jnp.float32)
    if fp8 is None:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
        logits = lm_logits(cfg, params, x, sh=sh)
        return logits, aux / cfg.num_layers
    # scan the per-layer scale slices alongside the stacked params; the ys
    # are each layer's observed amaxes -> dict site-key -> (num_layers,)
    (x, aux), amaxes = jax.lax.scan(body, (x, aux0), (params["blocks"], fp8.layer_scales()))
    logits = lm_logits(cfg, params, x, sh=sh)
    return logits, aux / cfg.num_layers, amaxes


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def prefill(cfg, params, batch, *, sh=None, q_chunk=0, remat="none"):
    """Inference prefill. Returns (last-position logits (B,V), raw cache).

    The raw cache holds full-length K/V; ``repro.serving.kvcache`` converts it
    into the ring-buffered decode cache (or grafts it into paged blocks).

    ``batch["last_index"]`` (optional, (B,) int32): per-sequence index of the
    last *real* token — the logits are taken there instead of at position
    S-1.  This is what makes right-padded length-bucketed prefill (the
    serving engine's recompilation fix) exact for causal attention archs: pad
    positions beyond ``last_index`` can never influence earlier K/V.
    """
    x, positions = embed_input(cfg, params, batch, sh=sh)
    vision_tokens = batch.get("vision_tokens")
    fam = cfg.family
    kw = dict(positions=positions, q_chunk=q_chunk, sh=sh)

    if fam in ("dense", "audio"):

        def body(x, p_layer):
            return B.dense_block_prefill(cfg, p_layer, x, **kw)

    elif fam == "moe":

        def body(x, p_layer):
            return B.moe_block_prefill(cfg, p_layer, x, **kw)

    elif fam == "ssm":

        def body(x, p_layer):
            return B.rwkv_block_prefill(cfg, p_layer, x, sh=sh)

    elif fam == "hybrid":

        def body(x, p_layer):
            return B.hybrid_block_prefill(cfg, p_layer, x, **kw)

    elif fam == "vlm":

        def body(x, p_group):
            def self_body(xc, p_layer):
                return B.dense_block_prefill(cfg, p_layer, xc, **kw)

            x, self_cache = jax.lax.scan(self_body, x, p_group["self"])
            x, cross_cache = B.cross_block_prefill(cfg, p_group["cross"], x, vision_tokens, sh=sh)
            return x, {"self": self_cache, "cross": cross_cache}

    else:
        raise ValueError(fam)

    body = _maybe_remat(body, remat)
    x, raw_cache = jax.lax.scan(body, x, params["blocks"])
    last_index = batch.get("last_index")
    if last_index is None:
        x_last = x[:, -1:]
    else:
        x_last = jnp.take_along_axis(x, last_index.astype(jnp.int32)[:, None, None], axis=1)
    logits = lm_logits(cfg, params, x_last, sh=sh)[:, 0]
    return logits, raw_cache


# ---------------------------------------------------------------------------
# chunked prefill (paged cache)
# ---------------------------------------------------------------------------

CHUNKED_PREFILL_FAMILIES = ("dense", "moe")


def supports_chunked_prefill(cfg) -> bool:
    """Chunked prefill needs (a) a paged cache and (b) per-chunk state that is
    fully captured by the written K/V.  Hybrid conv/SSM (and rwkv) recurrent
    states absorb the whole prompt in one pass and cannot be resumed
    mid-prompt, so those families keep the blocking prefill+graft path."""
    return cfg.family in CHUNKED_PREFILL_FAMILIES


def _chunk_stack(
    cfg, params, cache, tokens, start, tbl_row, *, sh=None, attn_impl="xla", mesh=None, widths=None
):
    """Shared chunk runner: embed C tokens at ``start + [0, C)``, scatter
    their K/V into the paged cache through ``tbl_row`` and attend causally
    over the paged history.  Returns (x (B, C, D), new cache).

    ``widths`` ((B,) int32, optional): per-row valid-lane counts for fused
    mixed batches — lanes at or past ``widths[b]`` scatter to the null block
    and their outputs are garbage the caller discards."""
    if not supports_chunked_prefill(cfg):
        raise ValueError(f"no chunked prefill for family {cfg.family!r} ({cfg.name})")
    C = tokens.shape[1]
    positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    x, _ = embed_input(cfg, params, {"tokens": tokens, "positions": positions}, sh=sh)
    step = B.dense_block_chunk if cfg.family == "dense" else B.moe_block_chunk

    def body(x, xs):
        p_layer, c_layer = xs
        x, nc = step(
            cfg, p_layer, x, c_layer, tbl_row, start,
            sh=sh, attn_impl=attn_impl, mesh=mesh, widths=widths,
        )
        return x, nc

    return jax.lax.scan(body, x, (params["blocks"], cache))


def prefill_step(cfg, params, cache, tokens, start, tbl_row, *, sh=None, attn_impl="xla", mesh=None):
    """Process one prompt *chunk* against a paged cache.

    tokens:  (B, C) int32 — C consecutive prompt tokens
    start:   (B,) int32 absolute position of the chunk's first token
    tbl_row: (B, nb) int32 — the request's block table (the engine's
             ``cache["tbl"]`` rows stay null until the prompt completes, so
             interleaved decode steps can't touch a half-prefilled request).

    Writes the chunk's K/V into the request's blocks, attends causally over
    the paged history [0, start + C) — shared prefix blocks included — and
    returns (logits (B, V) at the chunk's LAST token, new cache); the final
    chunk's logits are the prompt logits admission samples from.

    Exactness: for dense archs chaining chunks reproduces full-prompt
    ``prefill`` exactly (attention is causal, FFN/norms per-token).  For MoE
    the expert-capacity limit is computed per routed batch, so when capacity
    *binds* (low ``capacity_factor``) which tokens overflow can differ
    between chunked, exact-length, and pad-bucketed prefill — all three are
    defensible GShard semantics (the chunked path is the only one where pad
    tokens never compete for capacity), but they only coincide token-for-
    token when no token is dropped.
    """
    x, new_cache = _chunk_stack(
        cfg, params, cache, tokens, start, tbl_row, sh=sh, attn_impl=attn_impl, mesh=mesh
    )
    logits = lm_logits(cfg, params, x[:, -1], sh=sh)
    return logits, new_cache


def verify_step(cfg, params, cache, tokens, start, tbl_row, *, sh=None, attn_impl="xla", mesh=None):
    """Score C candidate tokens against a paged cache in one pass.

    Same chunk machinery as ``prefill_step`` (scatter-then-attend through
    ``kernels.paged_prefill_attention``), but returns the logits at EVERY
    chunk position, (B, C, V) — the speculative-decoding verification pass:
    feeding ``[last_committed, d_1, ..., d_k]`` yields the target model's
    distribution after each drafted token, so ``sampler.spec_accept`` can
    accept/reject the whole draft window from one model call instead of k
    sequential ``decode_step``s.

    The fed tokens' K/V is written to the cache as a side effect; the caller
    rolls back (``serving.kvcache.truncate_block_rows``) whatever the
    accept/reject pass does not commit.  MoE caveat as ``prefill_step``: the
    expert-capacity limit is computed over the B*C routed batch, so chunked
    scoring coincides with one-token decode only when capacity doesn't bind.
    """
    x, new_cache = _chunk_stack(
        cfg, params, cache, tokens, start, tbl_row, sh=sh, attn_impl=attn_impl, mesh=mesh
    )
    logits = lm_logits(cfg, params, x, sh=sh)
    return logits, new_cache


def unified_step(
    cfg, params, cache, tokens, start, widths, tbl_rows, *, sh=None, attn_impl="xla", mesh=None
):
    """One fused dispatch over a mixed row batch (the one-dispatch step).

    tokens:   (R, W) int32 — each row feeds up to W consecutive tokens
    start:    (R,) int32 absolute position of each row's first token
    widths:   (R,) int32 valid lanes per row — a decode row feeds 1, a
              prefill-chunk row feeds its chunk length, a spec-verify row
              feeds spec_k + 1; lanes past the width scatter to the null
              block and their logits are garbage the caller discards
    tbl_rows: (R, nb) int32 per-row block tables (a mid-prefill row's table
              is its private block list; decode/verify rows pass the
              published engine row)

    Rows are independent batch entries through the same chunk machinery as
    ``prefill_step`` / ``verify_step``; because every layer scatters all
    rows' K/V before attending, several chunk rows of ONE request may ride
    in the same dispatch (a later chunk reads the earlier chunk's same-layer
    K/V exactly as sequential chunking would).  Returns (logits (R, W, V),
    new cache) — all-lane logits so the caller can fold sampling and
    speculative accept into the same compiled graph.
    """
    x, new_cache = _chunk_stack(
        cfg, params, cache, tokens, start, tbl_rows,
        sh=sh, attn_impl=attn_impl, mesh=mesh, widths=widths,
    )
    logits = lm_logits(cfg, params, x, sh=sh)
    return logits, new_cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_step(cfg, params, cache, token, pos, *, sh=None, attn_impl="xla", mesh=None):
    """One decode step.

    token: (B, 1) int32 (ignored dims for audio); pos: (B,) int32 absolute
    position of this token.  Returns (logits (B, V), new cache).

    The cache may be the dense slot layout (``models.cache.init_cache``) or
    the paged block-pool layout (``models.cache.init_paged_cache``) for
    dense/moe/hybrid families — the per-layer cache keys select the path.
    ``attn_impl``: "xla" | "pallas" — paged decode attention backend (dense
    slot caches always use the jnp path).  ``mesh``: tensor-parallel serving
    mesh — the Pallas paged kernels run per-shard under ``shard_map`` on
    their local head slice (jnp paths partition via GSPMD and ignore it).
    """
    if cfg.is_encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    batch = {"tokens": token, "positions": pos[:, None]}
    x, _ = embed_input(cfg, params, batch, sh=sh)
    fam = cfg.family

    if fam in ("dense", "moe"):
        step = B.dense_block_decode if fam == "dense" else B.moe_block_decode

        def body(x, xs):
            p_layer, c_layer = xs
            x, nc = step(cfg, p_layer, x, c_layer, pos, sh=sh, attn_impl=attn_impl, mesh=mesh)
            return x, nc

    elif fam == "ssm":

        def body(x, xs):
            p_layer, c_layer = xs
            x, nc = B.rwkv_block_decode(cfg, p_layer, x, c_layer, pos, sh=sh)
            return x, nc

    elif fam == "hybrid":

        def body(x, xs):
            p_layer, c_layer = xs
            x, nc = B.hybrid_block_decode(
                cfg, p_layer, x, c_layer, pos, sh=sh, attn_impl=attn_impl, mesh=mesh
            )
            return x, nc

    elif fam == "vlm":

        def body(x, xs):
            p_group, c_group = xs

            def self_body(xc, inner):
                p_layer, c_layer = inner
                xc, nc = B.dense_block_decode(cfg, p_layer, xc, c_layer, pos, sh=sh)
                return xc, nc

            x, new_self = jax.lax.scan(self_body, x, (p_group["self"], c_group["self"]))
            x, new_cross = B.cross_block_decode(cfg, p_group["cross"], x, c_group["cross"], sh=sh)
            return x, {"self": new_self, "cross": new_cross}

    else:
        raise ValueError(fam)

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    logits = lm_logits(cfg, params, x[:, 0], sh=sh)
    return logits, new_cache
