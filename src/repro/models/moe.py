"""Mixture-of-Experts FFN: GShard-style grouped dense dispatch, TPU-native.

The paper's platform hosts MoE training at the 100s-of-billions scale
(arctic-480b, qwen3-moe in the assignment).  On TPU the idiomatic formulation
is *static-shape dense dispatch* (GShard / MaxText style) rather than the
CUDA gather/scatter of MegaBlocks:

  1. router: logits (T, E) -> top-k gates
  2. capacity: each expert accepts C tokens per group; overflow is dropped
     (standard GShard semantics, capacity_factor controls drop rate)
  3. dispatch einsum: one-hot (T, E, C) matmuls tokens into (E, C, D)
  4. expert FFN: batched matmul over the expert dim (sharded on "model" = EP)
  5. combine einsum: gates scatter expert outputs back to (T, D)

To bound the O(T*E*C) one-hot tensor at 32k-token sequence cells, tokens are
processed in groups of ``moe.group_size`` via lax.scan (step 3's tensor then
lives only inside one scan step).

An auxiliary load-balance loss (Switch/GShard) is returned alongside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ffn import gate_fn, is_gated
from repro.models.layers import ParamSpec


def moe_specs(cfg) -> dict:
    m = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.expert_d_ff
    specs = {
        "router": ParamSpec((D, E), ("embed", "expert_router"), "normal"),
    }
    gated = is_gated(cfg.activation)
    if gated:
        specs["w_gate"] = ParamSpec((E, D, F), ("expert", "embed", "expert_mlp"))
        specs["w_up"] = ParamSpec((E, D, F), ("expert", "embed", "expert_mlp"))
    else:
        specs["w_up"] = ParamSpec((E, D, F), ("expert", "embed", "expert_mlp"))
    specs["w_down"] = ParamSpec((E, F, D), ("expert", "expert_mlp", "embed"))
    if m.num_shared_experts > 0:
        S = m.num_shared_experts * F
        if gated:
            specs["shared_w_gate"] = ParamSpec((D, S), ("embed", "mlp"))
            specs["shared_w_up"] = ParamSpec((D, S), ("embed", "mlp"))
        else:
            specs["shared_w_up"] = ParamSpec((D, S), ("embed", "mlp"))
        specs["shared_w_down"] = ParamSpec((S, D), ("mlp", "embed"))
    return specs


def _capacity(m, tokens_per_group: int) -> int:
    c = int(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts)
    return max(4, (c + 3) // 4 * 4)  # round up to a multiple of 4, floor 4


def _route(cfg, router_w, x_group):
    """x_group: (T, D) -> gates (T, E) with only top-k nonzero, aux loss."""
    m = cfg.moe
    rdt = jnp.float32 if m.router_dtype == "float32" else x_group.dtype
    logits = x_group.astype(rdt) @ router_w.astype(rdt)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, m.top_k)  # (T, k)
    # renormalize the top-k gate values
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(top_idx, m.num_experts, dtype=probs.dtype)  # (T,k,E)
    gates = jnp.einsum("tk,tke->te", top_vals, onehot)
    # Switch-style load-balance loss: E * mean(fraction) . mean(prob)
    frac = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # (E,) fraction routed
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac * mean_prob)
    return gates, onehot, aux


def _dispatch_combine(cfg, p, x_group, gates, onehot):
    """Dense dispatch/expert/combine for one token group. x_group: (T, D)."""
    m = cfg.moe
    T = x_group.shape[0]
    C = _capacity(m, T)
    E = m.num_experts

    # position of each (token, k) pair within its expert's capacity buffer
    flat = onehot.reshape(T * m.top_k, E)  # routing order: token-major
    pos = jnp.cumsum(flat, axis=0) - 1.0  # (T*k, E)
    keep = (pos < C) & (flat > 0)
    pos = jnp.where(keep, pos, 0.0)
    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=x_group.dtype) * keep[..., None].astype(
        x_group.dtype
    )  # (T*k, E, C)
    slot_oh = slot_oh.reshape(T, m.top_k, E, C).sum(axis=1)  # (T, E, C)

    # dispatch: (T,D) x (T,E,C) -> (E,C,D)
    xe = jnp.einsum("td,tec->ecd", x_group, slot_oh)

    act = gate_fn(cfg.activation)
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(xe.dtype))
    if is_gated(cfg.activation):
        g = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(xe.dtype)))
        h = g * up
    else:
        h = act(up)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(xe.dtype))

    # combine: gates weight each token's expert outputs
    combine = slot_oh * gates[:, :, None].astype(x_group.dtype)  # (T,E,C)
    return jnp.einsum("tec,ecd->td", combine, ye)


def moe_ffn(cfg, p: dict, x: jax.Array, *, sh=None):
    """MoE FFN over (B, S, D). Returns (out, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    G = m.group_size if (m.group_size and T > m.group_size and T % m.group_size == 0) else T

    def one_group(xg):
        gates, onehot, aux = _route(cfg, p["router"], xg)
        out = _dispatch_combine(cfg, p, xg, gates, onehot)
        return out, aux

    if G == T:
        out, aux = one_group(xt)
    else:
        xg = xt.reshape(T // G, G, D)

        def body(carry, xg_i):
            out_i, aux_i = one_group(xg_i)
            return carry + aux_i, out_i

        # remat: dispatch one-hots / expert buffers recompute in backward
        aux_sum, out = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32), xg)
        out = out.reshape(T, D)
        aux = aux_sum / (T // G)

    # always-on shared experts (DeepSeek-style)
    if m.num_shared_experts > 0:
        act = gate_fn(cfg.activation)
        up = xt @ p["shared_w_up"].astype(xt.dtype)
        if is_gated(cfg.activation):
            up = act(xt @ p["shared_w_gate"].astype(xt.dtype)) * up
        else:
            up = act(up)
        out = out + up @ p["shared_w_down"].astype(xt.dtype)

    return out.reshape(B, S, D), aux * m.aux_loss_weight
