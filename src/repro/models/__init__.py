from repro.models.initializers import (
    abstract_params,
    init_params,
    param_logical_axes,
    param_specs,
)
from repro.models.model import (
    decode_step,
    forward,
    prefill,
    prefill_step,
    supports_chunked_prefill,
    unified_step,
    verify_step,
)
from repro.models.cache import (
    abstract_cache,
    cache_bytes,
    init_cache,
    init_paged_cache,
    paged_cache_axes,
    paged_cache_bytes,
    stacked_cache_axes,
    supports_paged,
)

__all__ = [
    "abstract_params",
    "init_params",
    "param_logical_axes",
    "param_specs",
    "decode_step",
    "forward",
    "prefill",
    "prefill_step",
    "supports_chunked_prefill",
    "unified_step",
    "verify_step",
    "abstract_cache",
    "cache_bytes",
    "init_cache",
    "init_paged_cache",
    "paged_cache_axes",
    "paged_cache_bytes",
    "stacked_cache_axes",
    "supports_paged",
]
