"""Decode-state (KV cache / recurrent state) structures, per family.

Caches are stacked over layers (leading L dim) so the decode step can scan
over (layer_params, layer_cache) pairs.  Every builder has a concrete
(``init_cache``) and an abstract (``abstract_cache``) twin — the latter feeds
the dry-run's ``jit(...).lower()`` without allocating 32k-token caches on the
host.  ``cache_logical_axes`` mirrors the tree with logical-axis tuples for
the sharding rule engine.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ssm as ssm_mod


def cache_window(cfg, seq_len: int) -> int:
    """Slots the attention cache needs for a decode run of length seq_len."""
    if cfg.sliding_window > 0:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def _attn_entry(cfg, B: int, W: int, dtype):
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": ((B, W, KV, hd), dtype),
        "v": ((B, W, KV, hd), dtype),
        "pos": ((B, W), jnp.int32),
    }


def _attn_axes():
    return {
        "k": ("kv_batch", "kv_seq", "kv_heads", None),
        "v": ("kv_batch", "kv_seq", "kv_heads", None),
        "pos": ("kv_batch", "kv_seq"),
    }


def layer_cache_layout(cfg, B: int, seq_len: int, dtype) -> dict:
    """(shape, dtype) tree for ONE layer's cache."""
    W = cache_window(cfg, seq_len)
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _attn_entry(cfg, B, W, dtype)
    if fam == "ssm":
        H, n = cfg.num_heads, cfg.rwkv.head_size
        D = cfg.d_model
        return {
            "tm_x": ((B, D), dtype),
            "cm_x": ((B, D), dtype),
            "state": ((B, H, n, n), jnp.float32),
        }
    if fam == "hybrid":
        H, P = cfg.num_heads, ssm_mod.head_dim_inner(cfg)
        di, K, N = ssm_mod.d_inner(cfg), cfg.ssm.conv_width, cfg.ssm.state_size
        ent = _attn_entry(cfg, B, W, dtype)
        ent.update(
            {
                "conv": ((B, K - 1, di), dtype),
                "ssm": ((B, H, P, N), jnp.float32),
            }
        )
        return ent
    if fam == "vlm":
        g = cfg.vision.cross_attn_every - 1  # self layers per group
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        self_ent = _attn_entry(cfg, B, W, dtype)
        return {
            "self": {k: ((g,) + s, d) for k, (s, d) in self_ent.items()},
            "cross": {
                "ck": ((B, cfg.vision.num_image_tokens, KV, hd), dtype),
                "cv": ((B, cfg.vision.num_image_tokens, KV, hd), dtype),
            },
        }
    raise ValueError(f"no decode cache for family {fam!r} ({cfg.name})")


def cache_logical_axes(cfg) -> dict:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _attn_axes()
    if fam == "ssm":
        return {
            "tm_x": ("kv_batch", "embed"),
            "cm_x": ("kv_batch", "embed"),
            "state": ("kv_batch", "heads", None, None),
        }
    if fam == "hybrid":
        ax = _attn_axes()
        ax.update(
            {
                "conv": ("kv_batch", None, "ssm_inner"),
                "ssm": ("kv_batch", "heads", None, None),
            }
        )
        return ax
    if fam == "vlm":
        sax = {k: ("layers_inner",) + v for k, v in _attn_axes().items()}
        return {
            "self": sax,
            "cross": {
                "ck": ("kv_batch", None, "kv_heads", None),
                "cv": ("kv_batch", None, "kv_heads", None),
            },
        }
    raise ValueError(fam)


def raw_cache_axes(cfg) -> dict:
    """Logical axes of the cache tree *as returned by prefill* (full-length
    K/V stacked over layers, no position ring buffer)."""
    fam = cfg.family
    kv = lambda: {
        "k": ("layers", "kv_batch", "kv_seq", "kv_heads", None),
        "v": ("layers", "kv_batch", "kv_seq", "kv_heads", None),
    }
    if fam in ("dense", "moe", "audio"):
        return kv()
    if fam == "ssm":
        return {
            "tm_x": ("layers", "kv_batch", "embed"),
            "cm_x": ("layers", "kv_batch", "embed"),
            "state": ("layers", "kv_batch", "heads", None, None),
        }
    if fam == "hybrid":
        ax = kv()
        ax.update(
            {
                "conv": ("layers", "kv_batch", None, "ssm_inner"),
                "ssm": ("layers", "kv_batch", "heads", None, None),
            }
        )
        return ax
    if fam == "vlm":
        sax = {k: ("layers", "layers_inner") + v[1:] for k, v in kv().items()}
        return {
            "self": sax,
            "cross": {
                "ck": ("layers", "kv_batch", None, "kv_heads", None),
                "cv": ("layers", "kv_batch", None, "kv_heads", None),
            },
        }
    raise ValueError(fam)


def num_scan_groups(cfg) -> int:
    """Leading scan dim of the stacked block params / cache."""
    if cfg.family == "vlm":
        assert cfg.num_layers % cfg.vision.cross_attn_every == 0
        return cfg.num_layers // cfg.vision.cross_attn_every
    return cfg.num_layers


def _stack(layout: dict, L: int):
    return jax.tree.map(
        lambda sd: ((L,) + sd[0], sd[1]),
        layout,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple),
    )


def stacked_cache_layout(cfg, B: int, seq_len: int, dtype) -> dict:
    return _stack(layer_cache_layout(cfg, B, seq_len, dtype), num_scan_groups(cfg))


def _is_layout_leaf(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)


def abstract_cache(cfg, B: int, seq_len: int, dtype):
    lay = stacked_cache_layout(cfg, B, seq_len, dtype)
    return jax.tree.map(lambda sd: jax.ShapeDtypeStruct(*sd), lay, is_leaf=_is_layout_leaf)


def init_cache(cfg, B: int, seq_len: int, dtype):
    lay = stacked_cache_layout(cfg, B, seq_len, dtype)

    def make(path_leaf):
        shape, dt = path_leaf
        return jnp.zeros(shape, dt)

    cache = jax.tree.map(make, lay, is_leaf=_is_layout_leaf)
    # position buffers start empty (-1)
    return _reset_pos(cache)


def _reset_pos(cache):
    def fix(path, leaf):
        if path and path[-1] == "pos":
            return jnp.full(leaf.shape, -1, leaf.dtype)
        return leaf

    from repro.utils.pytree import tree_map_with_path

    return tree_map_with_path(lambda p, l: fix(p.split("/"), l), cache)


def stacked_cache_axes(cfg) -> dict:
    """Logical axes for the STACKED cache (leading 'layers')."""
    ax = cache_logical_axes(cfg)
    return jax.tree.map(
        lambda t: ("layers",) + t,
        ax,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def cache_bytes(cfg, B: int, seq_len: int, dtype) -> int:
    lay = stacked_cache_layout(cfg, B, seq_len, dtype)
    total = 0
    for shape, dt in jax.tree.leaves(lay, is_leaf=_is_layout_leaf):
        total += int(np.prod(shape, dtype=np.int64)) * np.dtype(dt).itemsize
    return total
