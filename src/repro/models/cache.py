"""Decode-state (KV cache / recurrent state) structures, per family.

Caches are stacked over layers (leading L dim) so the decode step can scan
over (layer_params, layer_cache) pairs.  Every builder has a concrete
(``init_cache``) and an abstract (``abstract_cache``) twin — the latter feeds
the dry-run's ``jit(...).lower()`` without allocating 32k-token caches on the
host.  ``cache_logical_axes`` mirrors the tree with logical-axis tuples for
the sharding rule engine.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ssm as ssm_mod


def cache_window(cfg, seq_len: int) -> int:
    """Slots the attention cache needs for a decode run of length seq_len."""
    if cfg.sliding_window > 0:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def _attn_entry(cfg, B: int, W: int, dtype):
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": ((B, W, KV, hd), dtype),
        "v": ((B, W, KV, hd), dtype),
        "pos": ((B, W), jnp.int32),
    }


def _attn_axes():
    return {
        "k": ("kv_batch", "kv_seq", "kv_heads", None),
        "v": ("kv_batch", "kv_seq", "kv_heads", None),
        "pos": ("kv_batch", "kv_seq"),
    }


def layer_cache_layout(cfg, B: int, seq_len: int, dtype) -> dict:
    """(shape, dtype) tree for ONE layer's cache."""
    W = cache_window(cfg, seq_len)
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _attn_entry(cfg, B, W, dtype)
    if fam == "ssm":
        H, n = cfg.num_heads, cfg.rwkv.head_size
        D = cfg.d_model
        return {
            "tm_x": ((B, D), dtype),
            "cm_x": ((B, D), dtype),
            "state": ((B, H, n, n), jnp.float32),
        }
    if fam == "hybrid":
        H, P = cfg.num_heads, ssm_mod.head_dim_inner(cfg)
        di, K, N = ssm_mod.d_inner(cfg), cfg.ssm.conv_width, cfg.ssm.state_size
        ent = _attn_entry(cfg, B, W, dtype)
        ent.update(
            {
                "conv": ((B, K - 1, di), dtype),
                "ssm": ((B, H, P, N), jnp.float32),
            }
        )
        return ent
    if fam == "vlm":
        g = cfg.vision.cross_attn_every - 1  # self layers per group
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        self_ent = _attn_entry(cfg, B, W, dtype)
        return {
            "self": {k: ((g,) + s, d) for k, (s, d) in self_ent.items()},
            "cross": {
                "ck": ((B, cfg.vision.num_image_tokens, KV, hd), dtype),
                "cv": ((B, cfg.vision.num_image_tokens, KV, hd), dtype),
            },
        }
    raise ValueError(f"no decode cache for family {fam!r} ({cfg.name})")


def cache_logical_axes(cfg) -> dict:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _attn_axes()
    if fam == "ssm":
        return {
            "tm_x": ("kv_batch", "embed"),
            "cm_x": ("kv_batch", "embed"),
            "state": ("kv_batch", "heads", None, None),
        }
    if fam == "hybrid":
        ax = _attn_axes()
        ax.update(
            {
                "conv": ("kv_batch", None, "ssm_inner"),
                "ssm": ("kv_batch", "heads", None, None),
            }
        )
        return ax
    if fam == "vlm":
        sax = {k: ("layers_inner",) + v for k, v in _attn_axes().items()}
        return {
            "self": sax,
            "cross": {
                "ck": ("kv_batch", None, "kv_heads", None),
                "cv": ("kv_batch", None, "kv_heads", None),
            },
        }
    raise ValueError(fam)


def raw_cache_axes(cfg) -> dict:
    """Logical axes of the cache tree *as returned by prefill* (full-length
    K/V stacked over layers, no position ring buffer)."""
    fam = cfg.family
    kv = lambda: {
        "k": ("layers", "kv_batch", "kv_seq", "kv_heads", None),
        "v": ("layers", "kv_batch", "kv_seq", "kv_heads", None),
    }
    if fam in ("dense", "moe", "audio"):
        return kv()
    if fam == "ssm":
        return {
            "tm_x": ("layers", "kv_batch", "embed"),
            "cm_x": ("layers", "kv_batch", "embed"),
            "state": ("layers", "kv_batch", "heads", None, None),
        }
    if fam == "hybrid":
        ax = kv()
        ax.update(
            {
                "conv": ("layers", "kv_batch", None, "ssm_inner"),
                "ssm": ("layers", "kv_batch", "heads", None, None),
            }
        )
        return ax
    if fam == "vlm":
        sax = {k: ("layers", "layers_inner") + v[1:] for k, v in kv().items()}
        return {
            "self": sax,
            "cross": {
                "ck": ("layers", "kv_batch", None, "kv_heads", None),
                "cv": ("layers", "kv_batch", None, "kv_heads", None),
            },
        }
    raise ValueError(fam)


def num_scan_groups(cfg) -> int:
    """Leading scan dim of the stacked block params / cache."""
    if cfg.family == "vlm":
        assert cfg.num_layers % cfg.vision.cross_attn_every == 0
        return cfg.num_layers // cfg.vision.cross_attn_every
    return cfg.num_layers


def _stack(layout: dict, L: int):
    return jax.tree.map(
        lambda sd: ((L,) + sd[0], sd[1]),
        layout,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple),
    )


def stacked_cache_layout(cfg, B: int, seq_len: int, dtype) -> dict:
    return _stack(layer_cache_layout(cfg, B, seq_len, dtype), num_scan_groups(cfg))


def _is_layout_leaf(x):
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)


def abstract_cache(cfg, B: int, seq_len: int, dtype):
    lay = stacked_cache_layout(cfg, B, seq_len, dtype)
    return jax.tree.map(lambda sd: jax.ShapeDtypeStruct(*sd), lay, is_leaf=_is_layout_leaf)


def init_cache(cfg, B: int, seq_len: int, dtype):
    lay = stacked_cache_layout(cfg, B, seq_len, dtype)

    def make(path_leaf):
        shape, dt = path_leaf
        return jnp.zeros(shape, dt)

    cache = jax.tree.map(make, lay, is_leaf=_is_layout_leaf)
    # position buffers start empty (-1)
    return _reset_pos(cache)


def _reset_pos(cache):
    def fix(path, leaf):
        if path and path[-1] == "pos":
            return jnp.full(leaf.shape, -1, leaf.dtype)
        return leaf

    from repro.utils.pytree import tree_map_with_path

    return tree_map_with_path(lambda p, l: fix(p.split("/"), l), cache)


# ---------------------------------------------------------------------------
# paged KV cache (vLLM-style block pool + per-request block tables)
#
# Physical blocks are position-independent and may appear in SEVERAL slots'
# table rows at once: the prefix cache (serving/prefix.py) maps full
# token-aligned prompt blocks by content hash and shares them across
# requests by refcount (serving/paged.py).  Shared blocks are write-once —
# decode and chunked prefill only ever write positions past the shared
# prefix, which land in blocks owned by exactly one row.
# ---------------------------------------------------------------------------

PAGED_FAMILIES = ("dense", "moe", "hybrid")

NULL_BLOCK = 0  # physical block 0 is never allocated: inactive batch slots
# and padding entries of short block tables point here, so their (masked)
# decode writes/reads can never touch a live request's blocks.


def supports_paged(cfg) -> bool:
    """Paged caching applies to the growing-KV attention families.  ssm/rwkv
    states are O(1) per request (nothing to page); vlm's grouped layer scan
    keeps the dense layout."""
    return cfg.family in PAGED_FAMILIES


def paged_layer_cache_layout(
    cfg,
    num_blocks: int,
    block_size: int,
    max_batch: int,
    max_blocks_per_seq: int,
    dtype,
    *,
    quantized: bool | str = False,
) -> dict:
    """(shape, dtype) tree for ONE layer's paged cache.

    ``k``/``v`` are the global block pools — physical blocks are shared
    across batch slots and handed out by ``serving.paged.BlockAllocator``.
    ``tbl`` maps each slot's logical block index to a physical block id.
    ``quantized`` stores the pools quantized with per-(token, head) fp32
    scales (the ``serving.kvquant`` layout): ``True``/``"int8"`` for int8,
    ``"fp8"`` for e4m3 blocks.
    """
    if not supports_paged(cfg):
        raise ValueError(f"no paged cache for family {cfg.family!r} ({cfg.name})")
    from repro.serving.kvquant import kv_storage_dtype

    KV, hd = cfg.num_kv_heads, cfg.head_dim
    kv_dtype = kv_storage_dtype(quantized) if quantized else dtype
    ent = {
        "k": ((num_blocks, block_size, KV, hd), kv_dtype),
        "v": ((num_blocks, block_size, KV, hd), kv_dtype),
        "tbl": ((max_batch, max_blocks_per_seq), jnp.int32),
    }
    if quantized:
        ent["k_scale"] = ((num_blocks, block_size, KV, 1), jnp.float32)
        ent["v_scale"] = ((num_blocks, block_size, KV, 1), jnp.float32)
    if cfg.family == "hybrid":
        # recurrent states stay slot-dense: O(1) per request, nothing to page
        H, P = cfg.num_heads, ssm_mod.head_dim_inner(cfg)
        di, K = ssm_mod.d_inner(cfg), cfg.ssm.conv_width
        ent["conv"] = ((max_batch, K - 1, di), dtype)
        ent["ssm"] = ((max_batch, H, P, cfg.ssm.state_size), jnp.float32)
    return ent


def init_paged_cache(
    cfg,
    num_blocks: int,
    block_size: int,
    max_batch: int,
    max_blocks_per_seq: int,
    dtype,
    *,
    quantized: bool | str = False,
):
    """Zero-initialized stacked (L, ...) paged cache; tables point at the
    null block."""
    lay = _stack(
        paged_layer_cache_layout(
            cfg, num_blocks, block_size, max_batch, max_blocks_per_seq, dtype, quantized=quantized
        ),
        num_scan_groups(cfg),
    )
    return jax.tree.map(lambda sd: jnp.zeros(*sd), lay, is_leaf=_is_layout_leaf)


def paged_cache_bytes(
    cfg,
    num_blocks: int,
    block_size: int,
    max_batch: int,
    max_blocks_per_seq: int,
    dtype,
    *,
    quantized: bool | str = False,
) -> int:
    lay = _stack(
        paged_layer_cache_layout(
            cfg, num_blocks, block_size, max_batch, max_blocks_per_seq, dtype, quantized=quantized
        ),
        num_scan_groups(cfg),
    )
    total = 0
    for shape, dt in jax.tree.leaves(lay, is_leaf=_is_layout_leaf):
        total += int(np.prod(shape, dtype=np.int64)) * np.dtype(dt).itemsize
    return total


def stacked_cache_axes(cfg) -> dict:
    """Logical axes for the STACKED cache (leading 'layers')."""
    ax = cache_logical_axes(cfg)
    return jax.tree.map(
        lambda t: ("layers",) + t,
        ax,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def paged_cache_axes(cfg, *, quantized: bool | str = False) -> dict:
    """Logical axes for the stacked PAGED cache (tensor-parallel serving).

    The pools shard along ``kv_heads`` (the "model" mesh axis): every device
    holds the full block pool but only its head slice of each block, so the
    host-side block allocator / prefix index / block tables stay mesh-size
    invariant — block ids mean the same thing on every device.  The block
    dims (``num_blocks``, ``block_size``) are deliberately NOT sharded:
    splitting blocks across devices would make allocation device-aware and
    break prefix sharing.  ``tbl`` and the hybrid recurrent states are
    replicated (slot-dense host-managed state)."""
    if not supports_paged(cfg):
        raise ValueError(f"no paged cache for family {cfg.family!r} ({cfg.name})")
    pool = ("layers", None, None, "kv_heads", None)
    ax = {"k": pool, "v": pool, "tbl": ("layers", None, None)}
    if quantized:
        ax["k_scale"] = pool
        ax["v_scale"] = pool
    if cfg.family == "hybrid":
        # genuinely replicated (all-None, not logical-axis mapped): the
        # engine performs host-driven per-slot surgery on these states and
        # the documented TP contract is "recurrent state replicates"
        ax["conv"] = ("layers", None, None, None)
        ax["ssm"] = ("layers", None, None, None, None)
    return ax


def cache_bytes(cfg, B: int, seq_len: int, dtype) -> int:
    lay = stacked_cache_layout(cfg, B, seq_len, dtype)
    total = 0
    for shape, dt in jax.tree.leaves(lay, is_leaf=_is_layout_leaf):
        total += int(np.prod(shape, dtype=np.int64)) * np.dtype(dt).itemsize
    return total
