"""Selective-SSM head for the Hymba hybrid blocks (TPU-adapted).

Hymba (arXiv:2411.13676) pairs attention heads with Mamba heads.  Mamba-1's
per-channel dt makes the chunked-parallel form materialize an
O(chunk^2 * d_inner * N) tensor — ~13 GB per chunk at Hymba width, fine for a
sequential CUDA scan kernel but hostile to the MXU.  Following Mamba-2/SSD
(arXiv:2405.21060) we give each SSM *head* a scalar dt (A keeps its (H, N)
diagonal structure), after which every term factors into matmuls:

    decay:  la_t[h,j] = A[h,j] * cumsum(dt)[t,h]                 (<= 0)
    intra:  score[t,s,h] = sum_j C_t[j] B_s[j] exp(la_t - la_s)  (s <= t)
            y2[t,h,p]    = sum_s score[t,s,h] * dt_s[h] * x_s[h,p]
    inter:  y1[t,h,p]    = sum_j C_t[j] exp(la_t[h,j]) h0[h,p,j]
    state:  h1[h,p,j]    = exp(la_L) h0 + sum_s exp(la_L - la_s) dt_s B_s[j] x_s[h,p]

All exponents are differences of a monotone cumulative sum, hence <= 0 and
numerically safe.  This hardware adaptation is recorded in DESIGN.md §2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec


def d_inner(cfg) -> int:
    return cfg.ssm.expand * cfg.d_model


def head_dim_inner(cfg) -> int:
    di = d_inner(cfg)
    assert di % cfg.num_heads == 0, f"ssm: d_inner({di}) % heads({cfg.num_heads}) != 0"
    return di // cfg.num_heads


def ssm_specs(cfg) -> dict:
    D = cfg.d_model
    s = cfg.ssm
    di, N, H = d_inner(cfg), s.state_size, cfg.num_heads
    return {
        "in_proj": ParamSpec((D, 2 * di), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((s.conv_width, di), (None, "ssm_inner")),
        "conv_b": ParamSpec((di,), ("ssm_inner",), "zeros"),
        # per-token SSM params: dt per head, B and C per state index
        "x_proj": ParamSpec((di, 2 * N), ("ssm_inner", None)),
        "dt_proj": ParamSpec((di, H), ("ssm_inner", "heads"), "normal"),
        "dt_bias": ParamSpec((H,), ("heads",), "ssm_dt"),
        "a_log": ParamSpec((H, N), ("heads", None), "ssm_a"),
        "d_skip": ParamSpec((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamSpec((di, D), ("ssm_inner", "embed")),
    }


def _conv1d(x, w, b, conv_state=None):
    """Depthwise causal conv. x: (B,S,di), w: (K,di). Returns (y, new_state)."""
    K = w.shape[0]
    B, S, di = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, K - 1, di), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # (B, S+K-1, di)
    y = sum(xp[:, i : i + S] * w[i].astype(x.dtype) for i in range(K))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(K - 1) :] if K > 1 else jnp.zeros((B, 0, di), x.dtype)
    return y, new_state


def _selective_params(cfg, p, xc):
    """xc: (B,S,di) post-conv -> dt (B,S,H) fp32, B/C (B,S,N) fp32."""
    N = cfg.ssm.state_size
    proj = xc @ p["x_proj"].astype(xc.dtype)  # (B,S,2N)
    Bm, Cm = jnp.split(proj, 2, axis=-1)
    dt = jax.nn.softplus(xc @ p["dt_proj"].astype(xc.dtype) + p["dt_bias"].astype(xc.dtype))
    return dt.astype(jnp.float32), Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _ssd_chunked(A, dt, Bm, Cm, xh, state, chunk):
    """Chunked scan. A: (H,N); dt: (B,S,H); Bm/Cm: (B,S,N);
    xh: (B,S,H,P) fp32; state: (B,H,P,N) fp32. Returns (y (B,S,H,P), state)."""
    B, S, H, P = xh.shape
    N = A.shape[1]
    if S % chunk != 0:
        chunk = S
    nc = S // chunk

    def rc(x):
        shp = (B, nc, chunk) + x.shape[2:]
        perm = (1, 0) + tuple(range(2, len(shp)))
        return x.reshape(shp).transpose(perm)

    dt_c, B_c, C_c, x_c = rc(dt), rc(Bm), rc(Cm), rc(xh)
    tri_incl = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(h0, xs):
        dtc, Bb, Cb, xb = xs  # (B,L,H), (B,L,N), (B,L,N), (B,L,H,P)
        sdt = jnp.cumsum(dtc, axis=1)  # (B,L,H) inclusive
        la = sdt[..., None] * A[None, None]  # (B,L,H,N) <= 0
        # inter-chunk: y1 = C_t . exp(la_t) h0
        y1 = jnp.einsum("blj,blhj,bhpj->blhp", Cb, jnp.exp(la), h0)
        # intra-chunk pairwise decays (t,s): la_t - la_s <= 0 for s <= t
        dd = la[:, :, None] - la[:, None, :]  # (B,t,s,H,N)
        dd = jnp.where(tri_incl[None, :, :, None, None], dd, -jnp.inf)
        score = jnp.einsum("btj,bsj,btshj->btsh", Cb, Bb, jnp.exp(dd))
        xin = dtc[..., None] * xb  # (B,L,H,P) dt-scaled inputs
        y2 = jnp.einsum("btsh,bshp->bthp", score, xin)
        # state update
        la_last = la[:, -1:]  # (B,1,H,N)
        dec_in = jnp.exp(la_last - la)  # (B,L,H,N) safe
        h1 = jnp.exp(la_last[:, 0])[:, :, None, :] * h0 + jnp.einsum(
            "blhj,blj,blhp->bhpj", dec_in, Bb, xin
        )
        return h1, y1 + y2

    # remat: the (t,s,H,N) pairwise tensor must not be saved per chunk
    state, ys = jax.lax.scan(jax.checkpoint(body), state, (dt_c, B_c, C_c, x_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, state


def ssm_mix(cfg, p, x, *, conv_state=None, ssm_state=None, sh=None):
    """Full-sequence selective SSM. x: (B,S,D).

    Returns (out, (new_conv_state, new_ssm_state))."""
    s = cfg.ssm
    B, S, D = x.shape
    di, H, P = d_inner(cfg), cfg.num_heads, head_dim_inner(cfg)
    xz = x @ p["in_proj"].astype(x.dtype)  # (B,S,2di)
    xi, z = jnp.split(xz, 2, axis=-1)
    if sh is not None:
        xi = sh(xi, ("batch", "seq", "ssm_inner"))
        z = sh(z, ("batch", "seq", "ssm_inner"))
    xc, new_conv = _conv1d(xi, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _selective_params(cfg, p, xc)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,N), negative
    if ssm_state is None:
        ssm_state = jnp.zeros((B, H, P, s.state_size), jnp.float32)
    xh = xc.astype(jnp.float32).reshape(B, S, H, P)
    y, new_state = _ssd_chunked(A, dt, Bm, Cm, xh, ssm_state, s.chunk_size)
    y = y.reshape(B, S, di).astype(x.dtype) + xc * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, (new_conv, new_state)


def ssm_step(cfg, p, x, conv_state, ssm_state):
    """One-token decode. x: (B,1,D); conv_state: (B,K-1,di);
    ssm_state: (B,H,P,N) fp32."""
    B = x.shape[0]
    di, H, P = d_inner(cfg), cfg.num_heads, head_dim_inner(cfg)
    xz = x @ p["in_proj"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _conv1d(xi, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _selective_params(cfg, p, xc)  # (B,1,H), (B,1,N)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt[:, 0, :, None] * A[None])  # (B,H,N)
    xh = xc.astype(jnp.float32).reshape(B, H, P)
    u = jnp.einsum("bh,bj,bhp->bhpj", dt[:, 0], Bm[:, 0], xh)
    new_state = dec[:, :, None, :] * ssm_state + u
    y = jnp.einsum("bhpj,bj->bhp", new_state, Cm[:, 0]).reshape(B, 1, di)
    y = y.astype(x.dtype) + xc * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, (new_conv, new_state)
