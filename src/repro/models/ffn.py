"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain (GELU/SiLU/ReLU^2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ACTIVATIONS, ParamSpec


def is_gated(activation: str) -> bool:
    return activation in ("swiglu", "geglu")


def gate_fn(activation: str):
    if activation == "swiglu":
        return jax.nn.silu
    if activation == "geglu":
        return jax.nn.gelu
    return ACTIVATIONS[activation]


def ffn_specs(cfg, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    specs = {}
    if is_gated(cfg.activation):
        specs["w_gate"] = ParamSpec((D, F), ("embed", "mlp"))
        specs["w_up"] = ParamSpec((D, F), ("embed", "mlp"))
    else:
        specs["w_up"] = ParamSpec((D, F), ("embed", "mlp"))
    specs["w_down"] = ParamSpec((F, D), ("mlp", "embed"))
    if cfg.use_bias:
        specs["b_up"] = ParamSpec((F,), ("mlp",), "zeros")
        specs["b_down"] = ParamSpec((D,), ("embed",), "zeros")
    return specs


def ffn(cfg, p: dict, x: jax.Array, *, sh=None, fp8=None) -> jax.Array:
    """``fp8``: an ``repro.fp8.Fp8Ctx`` — routes the up/gate/down GEMMs
    through quantized matmuls (biases/activation stay in compute dtype)."""
    act = gate_fn(cfg.activation)
    if fp8 is not None:
        up = fp8.matmul("ffn_up", x, p["w_up"])
    else:
        up = x @ p["w_up"].astype(x.dtype)
    if cfg.use_bias:
        up = up + p["b_up"].astype(x.dtype)
    if is_gated(cfg.activation):
        if fp8 is not None:
            gate = act(fp8.matmul("ffn_gate", x, p["w_gate"]))
        else:
            gate = act(x @ p["w_gate"].astype(x.dtype))
        h = gate * up
    else:
        h = act(up)
    if sh is not None:
        h = sh(h, ("batch", "seq", "mlp"))
    if fp8 is not None:
        out = fp8.matmul("ffn_down", h, p["w_down"])
    else:
        out = h @ p["w_down"].astype(x.dtype)
    if cfg.use_bias:
        out = out + p["b_down"].astype(x.dtype)
    return out
