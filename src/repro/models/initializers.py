"""Whole-model ParamSpec assembly, initialization and abstract twins."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.cache import num_scan_groups
from repro.models.layers import ParamSpec, abstract_tree, init_tree, is_spec, norm_specs, spec_tree_map


def _stack_specs(specs, n: int, axis_name: str = "layers"):
    return spec_tree_map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale), specs
    )


def block_specs(cfg) -> dict:
    fam = cfg.family
    if fam in ("dense", "audio"):
        return B.dense_block_specs(cfg)
    if fam == "moe":
        return B.moe_block_specs(cfg)
    if fam == "ssm":
        return B.rwkv_block_specs(cfg)
    if fam == "hybrid":
        return B.hybrid_block_specs(cfg)
    if fam == "vlm":
        g = cfg.vision.cross_attn_every - 1
        return {
            "self": _stack_specs(B.dense_block_specs(cfg), g, "layers_inner"),
            "cross": B.cross_block_specs(cfg),
        }
    raise ValueError(f"unknown family {fam!r}")


def param_specs(cfg) -> dict:
    D, V = cfg.d_model, cfg.padded_vocab
    specs: dict = {}
    if cfg.family == "audio":
        specs["embed"] = {
            "frame_proj": ParamSpec((D, D), ("embed", "heads_x_dim")),
            "pos": ParamSpec((cfg.max_position, D), (None, "embed"), "normal"),
        }
    else:
        # vocab tables shard ONLY over "model" on the vocab dim ("embed_v" is
        # never sharded): a table whose embed dim is FSDP-sharded forces XLA
        # to all-gather the whole fp32 table around the gather/logits ops
        # (measured 4.2 GB/device x4 copies on llama-90b).
        specs["embed"] = {"tok": ParamSpec((V, D), ("vocab", "embed_v"), "normal")}
        if cfg.learned_pos_embedding:
            specs["embed"]["pos"] = ParamSpec((cfg.max_position, D), (None, "embed_v"), "normal")
    specs["blocks"] = _stack_specs(block_specs(cfg), num_scan_groups(cfg))
    specs["final_norm"] = norm_specs(cfg)
    if not cfg.tie_embeddings or cfg.family == "audio":
        specs["lm_head"] = ParamSpec((D, V), ("embed_v", "vocab"))
    return specs


def init_params(cfg, key: jax.Array, dtype=jnp.float32):
    return init_tree(param_specs(cfg), key, dtype)


def abstract_params(cfg, dtype=jnp.float32):
    return abstract_tree(param_specs(cfg), dtype)


def param_logical_axes(cfg):
    """Tree of logical-axis tuples matching param_specs."""
    return spec_tree_map(lambda s: s.axes, param_specs(cfg))
