"""Block assembly per architecture family.

Every family exposes three entry points used by ``models/model.py``:

* ``block_specs(cfg)``              — ParamSpec tree for ONE layer (unstacked)
* ``block_apply(cfg, p, x, ...)``   — full-sequence forward (train / prefill)
* ``block_decode(cfg, p, x, cache)``— one-token step against a layer cache

Caches are per-layer pytrees; ``models/cache.py`` builds the stacked
(L, ...) versions and their abstract ShapeDtypeStruct twins for the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import (
    attention_specs,
    cross_attention,
    decode_attention,
    paged_chunk_attention,
    paged_decode_attention,
    prefill_attention,
    self_attention,
)
from repro.models.ffn import ffn, ffn_specs
from repro.models.layers import ParamSpec, apply_norm, norm_specs
from repro.models.moe import moe_ffn, moe_specs


def _rmsn(x, eps=1e-5):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)).astype(x.dtype)


def _decode_attn(cfg, p_attn, h, cache, pos, *, sh=None, attn_impl="xla", mesh=None):
    """Decode attention against either cache layout.

    Paged caches (block pools + ``tbl`` block tables) and dense slot caches
    share the block decode path — the cache tree's keys select the layout, so
    ``decode_step``'s layer scan is layout-agnostic.  Returns (out, new
    attention-cache entries).
    """
    if "tbl" in cache:
        return paged_decode_attention(cfg, p_attn, h, cache, pos, impl=attn_impl, sh=sh, mesh=mesh)
    a, nk, nv, npos = decode_attention(cfg, p_attn, h, cache["k"], cache["v"], cache["pos"], pos, sh=sh)
    return a, {"k": nk, "v": nv, "pos": npos}


# ---------------------------------------------------------------------------
# dense (olmo, mistral-nemo, stablelm, gemma) and audio encoder (hubert)
# ---------------------------------------------------------------------------


def dense_block_specs(cfg) -> dict:
    specs = {
        "norm1": norm_specs(cfg),
        "attn": attention_specs(cfg),
        "norm2": norm_specs(cfg),
        "mlp": ffn_specs(cfg),
    }
    return specs


def dense_block(cfg, p, x, *, positions=None, q_chunk=0, sh=None, attn_impl="xla", fp8=None):
    h = apply_norm(cfg, p["norm1"], x)
    a = self_attention(
        cfg, p["attn"], h, positions=positions, q_chunk=q_chunk, sh=sh, impl=attn_impl, fp8=fp8
    )
    if cfg.parallel_residual:
        # GPT-NeoX / StableLM parallel form: one LN, attn + FFN both from it
        f = ffn(cfg, p["mlp"], h, sh=sh, fp8=fp8)
        x = x + a + f
    else:
        x = x + a
        h2 = apply_norm(cfg, p["norm2"], x)
        x = x + ffn(cfg, p["mlp"], h2, sh=sh, fp8=fp8)
    if sh is not None:
        x = sh(x, ("batch", "seq", "embed"))
    return x


def dense_block_prefill(cfg, p, x, *, positions=None, q_chunk=0, sh=None):
    h = apply_norm(cfg, p["norm1"], x)
    a, k, v = prefill_attention(cfg, p["attn"], h, positions=positions, q_chunk=q_chunk, sh=sh)
    if cfg.parallel_residual:
        f = ffn(cfg, p["mlp"], h, sh=sh)
        x = x + a + f
    else:
        x = x + a
        x = x + ffn(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x), sh=sh)
    if sh is not None:
        x = sh(x, ("batch", "seq", "embed"))
    return x, {"k": k, "v": v}


def dense_block_chunk(cfg, p, x, cache, tbl_row, start, *, sh=None, attn_impl="xla", mesh=None, widths=None):
    """Chunked-prefill step: like ``dense_block_decode`` but for a C-token
    chunk written/attended through the request's own paged block table.
    ``widths`` (fused mixed batches): per-row valid-lane counts — pad lanes
    scatter to the null block and their outputs are discarded upstream."""
    h = apply_norm(cfg, p["norm1"], x)
    a, new_attn = paged_chunk_attention(
        cfg, p["attn"], h, cache, tbl_row, start, sh=sh, impl=attn_impl, mesh=mesh, widths=widths
    )
    if cfg.parallel_residual:
        f = ffn(cfg, p["mlp"], h, sh=sh)
        x = x + a + f
    else:
        x = x + a
        x = x + ffn(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x), sh=sh)
    return x, new_attn


def dense_block_decode(cfg, p, x, cache, pos, *, sh=None, attn_impl="xla", mesh=None):
    h = apply_norm(cfg, p["norm1"], x)
    a, new_attn = _decode_attn(cfg, p["attn"], h, cache, pos, sh=sh, attn_impl=attn_impl, mesh=mesh)
    if cfg.parallel_residual:
        f = ffn(cfg, p["mlp"], h, sh=sh)
        x = x + a + f
    else:
        x = x + a
        x = x + ffn(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x), sh=sh)
    return x, new_attn


# ---------------------------------------------------------------------------
# MoE (arctic: +dense residual FFN; qwen3: plain top-8)
# ---------------------------------------------------------------------------


def moe_block_specs(cfg) -> dict:
    specs = {
        "norm1": norm_specs(cfg),
        "attn": attention_specs(cfg),
        "norm2": norm_specs(cfg),
        "moe": moe_specs(cfg),
    }
    if cfg.moe.dense_residual:
        specs["dense_mlp"] = ffn_specs(cfg, cfg.d_ff)
        specs["norm_dense"] = norm_specs(cfg)
    return specs


def moe_block(cfg, p, x, *, positions=None, q_chunk=0, sh=None, attn_impl="xla", fp8=None):
    """Returns (x, aux_loss).  ``fp8`` quantizes attention projections (+ the
    dense-residual FFN); routed expert FFNs stay in compute dtype."""
    h = apply_norm(cfg, p["norm1"], x)
    a = self_attention(
        cfg, p["attn"], h, positions=positions, q_chunk=q_chunk, sh=sh, impl=attn_impl, fp8=fp8
    )
    x = x + a
    h2 = apply_norm(cfg, p["norm2"], x)
    mo, aux = moe_ffn(cfg, p["moe"], h2, sh=sh)
    if cfg.moe.dense_residual:
        # Arctic: dense FFN in parallel with the routed experts
        mo = mo + ffn(cfg, p["dense_mlp"], apply_norm(cfg, p["norm_dense"], x), sh=sh, fp8=fp8)
    x = x + mo
    if sh is not None:
        x = sh(x, ("batch", "seq", "embed"))
    return x, aux


def moe_block_prefill(cfg, p, x, *, positions=None, q_chunk=0, sh=None):
    h = apply_norm(cfg, p["norm1"], x)
    a, k, v = prefill_attention(cfg, p["attn"], h, positions=positions, q_chunk=q_chunk, sh=sh)
    x = x + a
    h2 = apply_norm(cfg, p["norm2"], x)
    mo, aux = moe_ffn(cfg, p["moe"], h2, sh=sh)
    if cfg.moe.dense_residual:
        mo = mo + ffn(cfg, p["dense_mlp"], apply_norm(cfg, p["norm_dense"], x), sh=sh)
    x = x + mo
    return x, {"k": k, "v": v}


def moe_block_chunk(cfg, p, x, cache, tbl_row, start, *, sh=None, attn_impl="xla", mesh=None, widths=None):
    """Chunked-prefill step for MoE blocks.  Routing sees exactly the chunk's
    tokens (no length-bucket pad tokens competing for expert capacity).
    Fused mixed batches (``widths``) reintroduce pad lanes into the routed
    batch — same expert-capacity caveat as bucketed prefill."""
    h = apply_norm(cfg, p["norm1"], x)
    a, new_attn = paged_chunk_attention(
        cfg, p["attn"], h, cache, tbl_row, start, sh=sh, impl=attn_impl, mesh=mesh, widths=widths
    )
    x = x + a
    h2 = apply_norm(cfg, p["norm2"], x)
    mo, _ = moe_ffn(cfg, p["moe"], h2, sh=sh)
    if cfg.moe.dense_residual:
        mo = mo + ffn(cfg, p["dense_mlp"], apply_norm(cfg, p["norm_dense"], x), sh=sh)
    x = x + mo
    return x, new_attn


def moe_block_decode(cfg, p, x, cache, pos, *, sh=None, attn_impl="xla", mesh=None):
    h = apply_norm(cfg, p["norm1"], x)
    a, new_attn = _decode_attn(cfg, p["attn"], h, cache, pos, sh=sh, attn_impl=attn_impl, mesh=mesh)
    x = x + a
    h2 = apply_norm(cfg, p["norm2"], x)
    mo, _ = moe_ffn(cfg, p["moe"], h2, sh=sh)
    if cfg.moe.dense_residual:
        mo = mo + ffn(cfg, p["dense_mlp"], apply_norm(cfg, p["norm_dense"], x), sh=sh)
    x = x + mo
    return x, new_attn


# ---------------------------------------------------------------------------
# RWKV6 (attention-free)
# ---------------------------------------------------------------------------


def rwkv_block_specs(cfg) -> dict:
    return {
        "norm1": norm_specs(cfg),
        "time_mix": rwkv_mod.time_mix_specs(cfg),
        "norm2": norm_specs(cfg),
        "channel_mix": rwkv_mod.channel_mix_specs(cfg),
    }


def rwkv_block(cfg, p, x, *, sh=None, **_):
    out, _state = rwkv_mod.time_mix(cfg, p["time_mix"], apply_norm(cfg, p["norm1"], x))
    x = x + out
    out, _cmx = rwkv_mod.channel_mix(cfg, p["channel_mix"], apply_norm(cfg, p["norm2"], x), sh=sh)
    x = x + out
    if sh is not None:
        x = sh(x, ("batch", "seq", "embed"))
    return x


def rwkv_block_prefill(cfg, p, x, *, sh=None, **_):
    h = apply_norm(cfg, p["norm1"], x)
    out, (tm_x, state) = rwkv_mod.time_mix(cfg, p["time_mix"], h)
    x = x + out
    h2 = apply_norm(cfg, p["norm2"], x)
    out, cm_x = rwkv_mod.channel_mix(cfg, p["channel_mix"], h2, sh=sh)
    x = x + out
    return x, {"tm_x": tm_x, "cm_x": cm_x, "state": state}


def rwkv_block_decode(cfg, p, x, cache, pos, *, sh=None):
    h = apply_norm(cfg, p["norm1"], x)
    out, (tm_x, state) = rwkv_mod.time_mix_step(cfg, p["time_mix"], h, cache["tm_x"], cache["state"])
    x = x + out
    h2 = apply_norm(cfg, p["norm2"], x)
    out, cm_x = rwkv_mod.channel_mix(cfg, p["channel_mix"], h2, prev_x=cache["cm_x"], sh=sh)
    x = x + out
    return x, {"tm_x": tm_x, "cm_x": cm_x, "state": state}


# ---------------------------------------------------------------------------
# Hymba hybrid: parallel attention + SSM heads
# ---------------------------------------------------------------------------


def hybrid_block_specs(cfg) -> dict:
    D = cfg.d_model
    return {
        "norm1": norm_specs(cfg),
        "attn": attention_specs(cfg),
        "ssm": ssm_mod.ssm_specs(cfg),
        "beta_attn": ParamSpec((D,), ("embed",), "ones"),
        "beta_ssm": ParamSpec((D,), ("embed",), "ones"),
        "norm2": norm_specs(cfg),
        "mlp": ffn_specs(cfg),
    }


def _hybrid_combine(p, a, m, dtype):
    return 0.5 * (p["beta_attn"].astype(dtype) * _rmsn(a) + p["beta_ssm"].astype(dtype) * _rmsn(m))


def hybrid_block(cfg, p, x, *, positions=None, q_chunk=0, sh=None, attn_impl="xla", fp8=None):
    h = apply_norm(cfg, p["norm1"], x)
    a = self_attention(
        cfg, p["attn"], h, positions=positions, q_chunk=q_chunk, sh=sh, impl=attn_impl, fp8=fp8
    )
    m, _states = ssm_mod.ssm_mix(cfg, p["ssm"], h, sh=sh)
    x = x + _hybrid_combine(p, a, m, x.dtype)
    x = x + ffn(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x), sh=sh, fp8=fp8)
    if sh is not None:
        x = sh(x, ("batch", "seq", "embed"))
    return x


def hybrid_block_prefill(cfg, p, x, *, positions=None, q_chunk=0, sh=None):
    h = apply_norm(cfg, p["norm1"], x)
    a, k, v = prefill_attention(cfg, p["attn"], h, positions=positions, q_chunk=q_chunk, sh=sh)
    m, (conv_state, ssm_state) = ssm_mod.ssm_mix(cfg, p["ssm"], h, sh=sh)
    x = x + _hybrid_combine(p, a, m, x.dtype)
    x = x + ffn(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x), sh=sh)
    return x, {"k": k, "v": v, "conv": conv_state, "ssm": ssm_state}


def hybrid_block_decode(cfg, p, x, cache, pos, *, sh=None, attn_impl="xla", mesh=None):
    h = apply_norm(cfg, p["norm1"], x)
    a, new_attn = _decode_attn(cfg, p["attn"], h, cache, pos, sh=sh, attn_impl=attn_impl, mesh=mesh)
    m, (conv_state, ssm_state) = ssm_mod.ssm_step(cfg, p["ssm"], h, cache["conv"], cache["ssm"])
    x = x + _hybrid_combine(p, a, m, x.dtype)
    x = x + ffn(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x), sh=sh)
    return x, dict(new_attn, conv=conv_state, ssm=ssm_state)


# ---------------------------------------------------------------------------
# VLM cross-attention layer (llama-3.2-vision)
# ---------------------------------------------------------------------------


def cross_block_specs(cfg) -> dict:
    return {
        "norm1": norm_specs(cfg),
        "attn": attention_specs(cfg, cross=True),
        "norm2": norm_specs(cfg),
        "mlp": ffn_specs(cfg),
        "gate_mlp": ParamSpec((1,), (None,), "zeros"),
    }


def cross_block(cfg, p, x, vision_tokens, *, sh=None):
    h = apply_norm(cfg, p["norm1"], x)
    a = cross_attention(cfg, p["attn"], h, vision_tokens, sh=sh)
    x = x + a
    h2 = apply_norm(cfg, p["norm2"], x)
    x = x + jnp.tanh(p["gate_mlp"].astype(x.dtype)) * ffn(cfg, p["mlp"], h2, sh=sh)
    if sh is not None:
        x = sh(x, ("batch", "seq", "embed"))
    return x


def cross_block_prefill(cfg, p, x, vision_tokens, *, sh=None):
    """Cross-attention at prefill; caches the projected vision K/V (static
    thereafter — image tokens never grow during decode)."""
    from repro.models.attention import _out, _attend_block, _qkv  # shared internals

    h = apply_norm(cfg, p["norm1"], x)
    q, ck, cv = _qkv(cfg, p["attn"], h, kv_x=vision_tokens)
    B, Sq = h.shape[:2]
    zero = jnp.zeros((B, 1, 1, Sq, vision_tokens.shape[1]), jnp.float32)
    ctx = _attend_block(cfg, q, ck, cv, zero, cfg.q_per_kv)
    a = jnp.tanh(p["attn"]["gate"].astype(x.dtype)) * _out(cfg, p["attn"], ctx, x.dtype)
    x = x + a
    h2 = apply_norm(cfg, p["norm2"], x)
    x = x + jnp.tanh(p["gate_mlp"].astype(x.dtype)) * ffn(cfg, p["mlp"], h2, sh=sh)
    return x, {"ck": ck, "cv": cv}


def cross_block_decode(cfg, p, x, cache, *, sh=None):
    from repro.models.attention import _out, _attend_block, _qkv

    h = apply_norm(cfg, p["norm1"], x)
    pa = p["attn"]
    q = jnp.einsum("bsd,dhk->bshk", h, pa["wq"].astype(h.dtype))
    if cfg.qk_norm:
        from repro.models.attention import _rms_head

        q = _rms_head(q, pa["q_norm"], cfg.norm_eps)
    B = h.shape[0]
    zero = jnp.zeros((B, 1, 1, 1, cache["ck"].shape[1]), jnp.float32)
    ctx = _attend_block(cfg, q, cache["ck"], cache["cv"], zero, cfg.q_per_kv)
    a = jnp.tanh(pa["gate"].astype(x.dtype)) * _out(cfg, pa, ctx, x.dtype)
    x = x + a
    h2 = apply_norm(cfg, p["norm2"], x)
    x = x + jnp.tanh(p["gate_mlp"].astype(x.dtype)) * ffn(cfg, p["mlp"], h2, sh=sh)
    return x, cache
