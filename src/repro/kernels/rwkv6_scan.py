"""RWKV6 WKV chunked scan — Pallas TPU kernel.

The one assigned architecture whose hot loop is NOT a matmul: Finch's
data-dependent-decay recurrence (arXiv:2404.05892).  The reference CUDA
kernel is a sequential per-(batch, head) scan; the TPU adaptation runs the
chunked-parallel formulation from ``models/rwkv.py`` inside one kernel:

* grid = (batch*heads, num_chunks); the chunk axis *revisits* a VMEM scratch
  carrying the (n x n) state matrix, so the recurrence crosses chunks without
  leaving VMEM;
* within a chunk everything is matmul/VPU-shaped: cumulative log-decays,
  pairwise-safe decay tensor (all exponents <= 0), two (chunk x n) dots and
  the rank-1 state update.

Operands arrive head-major (BH, S, n) so BlockSpecs are clean 1:1 tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import pl_scratch


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)  # (chunk, n)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)  # log decays, <= 0
    u = u_ref[0].astype(jnp.float32)  # (1, n) bonus
    S0 = state_ref[...]  # (n, n) fp32

    la = jnp.cumsum(lw, axis=0)  # inclusive
    la_prev = la - lw  # exclusive

    # inter-chunk: r~_t = r_t * exp(la_{t-1}); out_inter = r~ @ S0
    r_dec = r * jnp.exp(la_prev)
    out = jax.lax.dot(r_dec, S0)  # (chunk, n)

    # intra-chunk: scores_ts = sum_c r_t[c] k_s[c] exp(la_{t-1}[c] - la_s[c]), s < t
    dd = la_prev[:, None, :] - la[None, :, :]  # (t, s, n) <= 0 for s < t
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = t_idx > s_idx
    scores = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(dd), axis=-1)
    scores = jnp.where(strict, scores, 0.0)
    out = out + jax.lax.dot(scores, v)

    # diagonal bonus: (r_t . (u * k_t)) v_t
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)
    out = out + diag * v

    # state update: S' = diag(exp(la_L)) S0 + sum_s exp(la_L - la_s) k_s v_s^T
    la_last = la[-1:]  # (1, n)
    k_dec = k * jnp.exp(la_last - la)
    state_ref[...] = jnp.exp(la_last).T * S0 + jax.lax.dot(k_dec.T, v)

    o_ref[0, ...] = out.astype(o_ref.dtype)


def wkv6_chunked(
    r: jax.Array,  # (BH, S, n)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # (BH, S, n), log decay <= 0
    u: jax.Array,  # (BH, n) per-head bonus
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jax.Array:
    BH, S, n = r.shape
    assert S % chunk == 0, (S, chunk)
    grid = (BH, S // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, n), jnp.float32),
        scratch_shapes=[pl_scratch((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
