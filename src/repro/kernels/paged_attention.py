"""Paged attention — Pallas TPU kernels (block-table gather, online softmax).

vLLM-style attention over a paged KV cache: each sequence's K/V lives in
non-contiguous fixed-size blocks of a global pool, addressed through a
per-sequence block table.  The kernels never materialize the gathered
(B, S, KV, hd) view — the block table is a *scalar-prefetch* operand
(``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index_map dereferences it
to DMA exactly the physical block each grid step needs:

    grid = (batch, kv_head, logical_block)
    k/v spec: (1, block_size, 1, hd) @ (table[b, i], 0, kv, 0)

The minormost grid dimension walks a sequence's logical blocks and *revisits*
the output block, carrying the running max / denominator / fp32 accumulator
in VMEM scratch between steps — the same grid-order online-softmax
formulation as ``kernels/flash_attention.py``.

Two entry points share that structure:

* ``paged_attention_bhd``     — decode: one query token per sequence.
* ``paged_prefill_attention_bhd`` — **chunked prefill**: ``C`` query tokens
  per sequence at absolute positions ``start + [0, C)``, attending causally
  over everything already written to the paged cache (shared prefix blocks,
  earlier chunks, and this chunk's own K/V — which the caller scatters in
  *before* calling).  Queries are laid out (B, KV, C*qpk, hd) with row
  ``r -> chunk offset r // qpk``, so the in-kernel causal/window mask is a
  per-row position compare.  This is what lets a long prompt be processed in
  budgeted chunks interleaved with decode steps instead of one blocking
  batch=1 prefill.

Tile notes: the (block_size, hd) K/V tile should be 128-aligned on real TPUs
(block_size a multiple of the sublane tile, hd = 128 lanes for the assigned
archs); interpret mode (this CPU image) accepts the smoke sizes.  Sequences
shorter than ``nb * block_size`` are handled by masking against ``seq_lens``;
table entries past a sequence's last block must point at a valid (e.g. null)
block — they are DMA'd and fully masked.  ``seq_lens`` must be >= 1 so the
first logical block always contributes a finite row-max.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e38


def _paged_kernel(
    tbl_ref,  # scalar-prefetch (B, nb) int32
    len_ref,  # scalar-prefetch (B,) int32
    q_ref,  # (1, 1, qpk, hd)
    k_ref,  # (1, bs, 1, hd) — physical block picked by the index_map
    v_ref,
    o_ref,  # (1, 1, qpk, hd), revisited across the block dimension
    acc_ref,  # VMEM (qpk, hd) fp32
    m_ref,  # VMEM (qpk, 1) fp32
    l_ref,  # VMEM (qpk, 1) fp32
    *,
    scale: float,
    softcap: float,
    window: int,
    block_size: int,
):
    b = pl.program_id(0)
    i = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (qpk, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bs, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (qpk, bs)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    seq_len = len_ref[b]
    kv_pos = i * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = kv_pos < seq_len  # causal over everything written so far
    if window > 0:
        ok &= (seq_len - 1 - kv_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = (alpha * l_ref[:, 0] + jnp.sum(p, axis=1))[:, None]
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_cur[:, None]

    @pl.when(i == nb - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_attention_bhd(
    q: jax.Array,  # (B, H, hd) current-token queries
    k_pool: jax.Array,  # (N, bs, KV, hd) global block pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32 physical block ids
    seq_lens: jax.Array,  # (B,) int32 valid kv length (>= 1)
    *,
    softcap: float = 0.0,
    window: int = 0,
    interpret: bool = True,
) -> jax.Array:
    B, H, hd = q.shape
    N, bs, KV, _ = k_pool.shape
    nb = block_tables.shape[1]
    assert H % KV == 0, (H, KV)
    qpk = H // KV
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(B, KV, qpk, hd)
    kernel = functools.partial(
        _paged_kernel,
        scale=scale,
        softcap=softcap,
        window=window,
        block_size=bs,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, qpk, hd), lambda b, kv, i, tbl, sl: (b, kv, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, kv, i, tbl, sl: (tbl[b, i], 0, kv, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, kv, i, tbl, sl: (tbl[b, i], 0, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qpk, hd), lambda b, kv, i, tbl, sl: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((qpk, hd), jnp.float32),
            pltpu.VMEM((qpk, 1), jnp.float32),
            pltpu.VMEM((qpk, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, qpk, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), seq_lens.astype(jnp.int32), qg, k_pool, v_pool)
    return out.reshape(B, H, hd)


def _paged_prefill_kernel(
    tbl_ref,  # scalar-prefetch (B, nb) int32
    start_ref,  # scalar-prefetch (B,) int32 — absolute position of chunk row 0
    q_ref,  # (1, 1, rt, hd) — row tile of the (C*qpk) query rows
    k_ref,  # (1, bs, 1, hd) — physical block picked by the index_map
    v_ref,
    o_ref,  # (1, 1, rt, hd), revisited across the block dimension
    acc_ref,  # VMEM (rt, hd) fp32
    m_ref,  # VMEM (rt, 1) fp32
    l_ref,  # VMEM (rt, 1) fp32
    *,
    scale: float,
    softcap: float,
    window: int,
    block_size: int,
    qpk: int,
    row_tile: int,
):
    b = pl.program_id(0)
    t = pl.program_id(2)  # query-row tile (autotuned; nt == 1 when untiled)
    i = pl.program_id(3)
    nb = pl.num_programs(3)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (rt, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bs, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (rt, bs)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    start = start_ref[b]
    # global row r = t*rt + local row; row r is chunk offset r // qpk
    row0 = t * row_tile
    q_pos = start + (row0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)) // qpk
    kv_pos = i * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = kv_pos <= q_pos  # causal: the chunk's own K/V is already written
    if window > 0:
        ok &= (q_pos - kv_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = (alpha * l_ref[:, 0] + jnp.sum(p, axis=1))[:, None]
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_cur[:, None]

    @pl.when(i == nb - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_prefill_attention_bhd(
    q: jax.Array,  # (B, C, H, hd) chunk queries
    k_pool: jax.Array,  # (N, bs, KV, hd) global block pool (chunk K/V written)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32 physical block ids
    start: jax.Array,  # (B,) int32 absolute position of the chunk's first token
    *,
    softcap: float = 0.0,
    window: int = 0,
    interpret: bool = True,
    rows_per_tile: int = 0,
) -> jax.Array:
    """Chunked-prefill attention: every chunk token attends causally over the
    paged logical view [0, start + its offset].  Table entries past the last
    written block must point at a valid (e.g. null) block — they are DMA'd
    and fully masked by the causal compare.  Returns (B, C, H, hd).

    ``rows_per_tile`` (autotuned, ``kernels.autotune``): tile the C*qpk
    query-row dimension so each grid step streams a ``(rows_per_tile, hd)``
    query block against one K/V page — smaller VMEM scratch at the cost of
    re-reading pages once per tile.  Rows are independent queries, so any
    divisor of the row count is numerically identical; 0 (or a non-divisor)
    means one tile holding every row.
    """
    B, C, H, hd = q.shape
    N, bs, KV, _ = k_pool.shape
    nb = block_tables.shape[1]
    assert H % KV == 0, (H, KV)
    qpk = H // KV
    rows = C * qpk
    if rows_per_tile <= 0 or rows % rows_per_tile != 0:
        rows_per_tile = rows
    nt = rows // rows_per_tile
    rt = rows_per_tile
    scale = 1.0 / math.sqrt(hd)

    # (B, C, H, hd) -> (B, KV, C*qpk, hd), row r = (chunk offset r//qpk, group r%qpk)
    qg = q.reshape(B, C, KV, qpk, hd).transpose(0, 2, 1, 3, 4).reshape(B, KV, rows, hd)
    kernel = functools.partial(
        _paged_prefill_kernel,
        scale=scale,
        softcap=softcap,
        window=window,
        block_size=bs,
        qpk=qpk,
        row_tile=rt,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        # nb innermost: for a fixed (b, kv, t) the online-softmax scratch walks
        # every page before the next row tile re-initializes it
        grid=(B, KV, nt, nb),
        in_specs=[
            pl.BlockSpec((1, 1, rt, hd), lambda b, kv, t, i, tbl, st: (b, kv, t, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, kv, t, i, tbl, st: (tbl[b, i], 0, kv, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, kv, t, i, tbl, st: (tbl[b, i], 0, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rt, hd), lambda b, kv, t, i, tbl, st: (b, kv, t, 0)),
        scratch_shapes=[
            pltpu.VMEM((rt, hd), jnp.float32),
            pltpu.VMEM((rt, 1), jnp.float32),
            pltpu.VMEM((rt, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, rows, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), start.astype(jnp.int32), qg, k_pool, v_pool)
    return out.reshape(B, KV, C, qpk, hd).transpose(0, 2, 1, 3, 4).reshape(B, C, H, hd)
