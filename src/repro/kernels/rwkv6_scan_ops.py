"""Jit'd wrapper: model-layout (B, S, H, n) -> kernel layout (B*H, S, n)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan import wkv6_chunked


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, logw, u, *, chunk: int = 64):
    """r,k,v,logw: (B, S, H, n); u: (H, n). Returns (B, S, H, n) fp32."""
    B, S, H, n = r.shape

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, n)

    ub = jnp.broadcast_to(u[None], (B, H, n)).reshape(B * H, n)
    out = wkv6_chunked(
        to_bh(r), to_bh(k), to_bh(v), to_bh(logw), ub, chunk=chunk, interpret=not _on_tpu()
    )
    return out.reshape(B, H, S, n).transpose(0, 2, 1, 3)
