"""Sequential pure-jnp oracle for the RWKV6 WKV recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u):
    """Sequential scan. r,k,v,logw: (BH, S, n); u: (BH, n) -> (BH, S, n)."""
    BH, S, n = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))  # (BH, S, n) decay factors
    uf = u.astype(jnp.float32)

    def step(state, inputs):
        rt, kt, vt, wt = inputs  # (BH, n) each
        a = kt[:, :, None] * vt[:, None, :]  # (BH, n, n) outer product
        out = jnp.einsum("bc,bcv->bv", rt, state + uf[:, :, None] * a)
        new_state = wt[:, :, None] * state + a
        return new_state, out

    init = jnp.zeros((BH, n, n), jnp.float32)
    xs = (rf.transpose(1, 0, 2), kf.transpose(1, 0, 2), vf.transpose(1, 0, 2), w.transpose(1, 0, 2))
    _, outs = jax.lax.scan(step, init, xs)
    return outs.transpose(1, 0, 2)  # (BH, S, n)
