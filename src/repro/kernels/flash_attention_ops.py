"""Jit'd public wrapper for the flash attention kernel.

Accepts the model's (B, S, H, hd) layout, transposes to the kernel's
head-major layout, and picks interpret mode automatically off-TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "q_block", "kv_block"))
def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd) — model layout
    k: jax.Array,  # (B, Skv, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_block: int = 128,
    kv_block: int = 128,
) -> jax.Array:
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    Sq, Skv = qt.shape[2], kt.shape[2]
    qb = min(q_block, Sq) if Sq % min(q_block, Sq) == 0 else Sq
    kb = min(kv_block, Skv) if Skv % min(kv_block, Skv) == 0 else Skv
    out = flash_attention_bhsd(
        qt,
        kt,
        vt,
        causal=causal,
        window=window,
        softcap=softcap,
        q_block=qb,
        kv_block=kb,
        interpret=not _on_tpu(),
    )
    return out.transpose(0, 2, 1, 3)
