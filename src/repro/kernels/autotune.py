"""Pallas paged-attention autotuner: sweep layouts, cache the winners.

The paged kernels expose two layout knobs whose best setting depends on the
problem shape, not the code:

* ``prefill_rows_per_tile`` — how many of the C*qpk query rows each grid
  step streams against a K/V page (``paged_prefill_attention_bhd``).  Small
  tiles shrink VMEM scratch but re-DMA every page once per tile; one big
  tile amortizes page reads but can blow the ~16 MB VMEM budget at long
  chunks.
* ``decode_kernel`` — single-token rows can run the dedicated decode kernel
  (``"paged"``, qpk-row tiles) or the multi-query prefill kernel at C=1
  (``"prefill1"``) whose masks degenerate to the decode masks exactly; on
  some shapes one layout pipelines better than the other.

``autotune()`` times every candidate per case with the same
block-until-ready loop as ``benchmarks/paged_attention.py`` (which exposes
the sweep as ``--autotune``) and records the winner under a key derived
from ``(head_dim, block_size, page_count, dtype)``.  Lookup order:

1. user cache — ``$REPRO_AUTOTUNE_CACHE`` or
   ``~/.cache/repro/pallas_autotune.json`` (written by ``autotune()``)
2. in-repo defaults — ``src/repro/kernels/autotune_defaults.json``
3. the ``"default"`` entry of either file

``get_config`` is pure given the cache files (no timing at lookup), so a
compiled graph's layout is deterministic — the CI ``fused-step`` lane
asserts that two lookups and a cache round-trip agree byte-for-byte.
"""

from __future__ import annotations

import json
import os
import time
from functools import lru_cache
from pathlib import Path

DEFAULTS_PATH = Path(__file__).with_name("autotune_defaults.json")
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

DECODE_KERNELS = ("paged", "prefill1")
ROW_TILE_CANDIDATES = (0, 8, 16, 32)  # 0 = one tile holding every row

_DEFAULT_CONFIG = {"prefill_rows_per_tile": 0, "decode_kernel": "paged"}


def cache_key(head_dim: int, block_size: int, page_count: int, dtype) -> str:
    return f"hd{head_dim}_bs{block_size}_pages{page_count}_{str(dtype)}"


def user_cache_path() -> Path:
    env = os.environ.get(CACHE_ENV)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "pallas_autotune.json"


def _read_json(path: Path) -> dict:
    try:
        with open(path) as f:
            table = json.load(f)
    except (OSError, ValueError):
        return {}
    return table if isinstance(table, dict) else {}


@lru_cache(maxsize=None)
def _load_table(defaults: str, user: str) -> dict:
    table = _read_json(Path(defaults))
    table.update(_read_json(Path(user)))
    return table


def load_table(refresh: bool = False) -> dict:
    """Merged tuning table (user cache entries shadow in-repo defaults)."""
    if refresh:
        _load_table.cache_clear()
    return _load_table(str(DEFAULTS_PATH), str(user_cache_path()))


def _sanitize(entry) -> dict:
    cfg = dict(_DEFAULT_CONFIG)
    if isinstance(entry, dict):
        rt = entry.get("prefill_rows_per_tile", 0)
        if isinstance(rt, int) and rt >= 0:
            cfg["prefill_rows_per_tile"] = rt
        dk = entry.get("decode_kernel", "paged")
        if dk in DECODE_KERNELS:
            cfg["decode_kernel"] = dk
    return cfg


def get_config(head_dim: int, block_size: int, page_count: int, dtype) -> dict:
    """Tuned kernel config for one problem shape (falls back to defaults).

    Called at trace time by ``kernels.paged_attention_ops`` — shapes are
    static there, so the choice bakes into the compiled graph.
    """
    table = load_table()
    entry = table.get(cache_key(head_dim, block_size, page_count, dtype))
    if entry is None:
        entry = table.get(cache_key(head_dim, block_size, 0, dtype))  # any page count
    if entry is None:
        entry = table.get("default")
    return _sanitize(entry)


# ---------------------------------------------------------------------------
# sweep
# ---------------------------------------------------------------------------


def _time(fn, *args, iters: int = 5) -> float:
    import jax

    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _build_case(B: int, nb: int, bs: int, H: int, KV: int, hd: int, dtype):
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(B * 131 + nb * 17 + hd)
    N = 1 + B * nb
    ks = jax.random.split(key, 4)
    q1 = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
    qc = jax.random.normal(ks[1], (B, 8, H, hd), jnp.float32).astype(dtype)
    k_pool = jax.random.normal(ks[2], (N, bs, KV, hd), jnp.float32).astype(dtype)
    v_pool = jax.random.normal(ks[3], (N, bs, KV, hd), jnp.float32).astype(dtype)
    tbl = jnp.arange(1, 1 + B * nb, dtype=jnp.int32).reshape(B, nb)
    lens = jnp.full((B,), nb * bs, jnp.int32)
    return q1, qc, k_pool, v_pool, tbl, lens


def tune_case(B: int, nb: int, bs: int, H: int, KV: int, hd: int, dtype="bfloat16", iters: int = 5) -> dict:
    """Time every candidate for one shape; return the winning config."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.paged_attention import paged_attention_bhd, paged_prefill_attention_bhd

    interpret = jax.default_backend() != "tpu"
    dt = jnp.dtype(dtype)
    q1, qc, k_pool, v_pool, tbl, lens = _build_case(B, nb, bs, H, KV, hd, dt)

    best_rt, best_rt_t = 0, float("inf")
    rows = qc.shape[1] * (H // KV)
    for rt in ROW_TILE_CANDIDATES:
        if rt and (rt >= rows or rows % rt):
            continue
        fn = jax.jit(
            lambda q, k, v, t, s, _rt=rt: paged_prefill_attention_bhd(
                q, k, v, t, s, interpret=interpret, rows_per_tile=_rt
            )
        )
        dt_s = _time(fn, qc, k_pool, v_pool, tbl, jnp.zeros((B,), jnp.int32), iters=iters)
        if dt_s < best_rt_t:
            best_rt, best_rt_t = rt, dt_s

    decode_fns = {
        "paged": jax.jit(
            lambda q, k, v, t, sl: paged_attention_bhd(q, k, v, t, sl, interpret=interpret)
        ),
        "prefill1": jax.jit(
            lambda q, k, v, t, sl: paged_prefill_attention_bhd(
                q[:, None], k, v, t, sl - 1, interpret=interpret
            )[:, 0]
        ),
    }
    best_dk, best_dk_t = "paged", float("inf")
    for name, fn in decode_fns.items():
        dt_s = _time(fn, q1, k_pool, v_pool, tbl, lens, iters=iters)
        if dt_s < best_dk_t:
            best_dk, best_dk_t = name, dt_s

    return {
        "prefill_rows_per_tile": best_rt,
        "decode_kernel": best_dk,
        "prefill_s": best_rt_t,
        "decode_s": best_dk_t,
    }


def autotune(cases, dtype="bfloat16", iters: int = 5, out_path: Path | None = None) -> dict:
    """Sweep ``cases`` (tuples of (B, nb, block_size, H, KV, hd)) and write
    the winners to the user cache (creating parent dirs)."""
    out_path = Path(out_path) if out_path else user_cache_path()
    table = _read_json(out_path)
    for B, nb, bs, H, KV, hd in cases:
        won = tune_case(B, nb, bs, H, KV, hd, dtype=dtype, iters=iters)
        key = cache_key(hd, bs, nb, dtype)
        table[key] = {
            "prefill_rows_per_tile": won["prefill_rows_per_tile"],
            "decode_kernel": won["decode_kernel"],
        }
        print(f"{key}: {table[key]}  (prefill {won['prefill_s']*1e3:.3f} ms, decode {won['decode_s']*1e3:.3f} ms)")
    out_path.parent.mkdir(parents=True, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
        f.write("\n")
    load_table(refresh=True)
    return table


def check_determinism() -> None:
    """CI guard: lookups are pure and the cache round-trips byte-stably."""
    table = load_table(refresh=True)
    assert isinstance(table, dict) and "default" in table, "defaults file must define 'default'"
    for key, entry in table.items():
        cfg = _sanitize(entry)
        assert cfg["decode_kernel"] in DECODE_KERNELS, (key, cfg)
        assert cfg["prefill_rows_per_tile"] >= 0, (key, cfg)
    a = get_config(64, 16, 8, "bfloat16")
    b = get_config(64, 16, 8, "bfloat16")
    assert a == b, "get_config must be deterministic"
    dumped = json.dumps(table, indent=2, sort_keys=True)
    assert json.dumps(json.loads(dumped), indent=2, sort_keys=True) == dumped
    print("autotune cache deterministic:", len(table), "entries")


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description="Pallas paged-attention autotuner")
    ap.add_argument("--check", action="store_true", help="verify cache determinism, no sweep")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--out", default=None, help="cache path (default: user cache)")
    args = ap.parse_args(argv)
    if args.check:
        check_determinism()
        return
    try:  # canonical sweep shapes live with the benchmark harness
        from benchmarks.paged_attention import CASES
    except ImportError:
        CASES = [(4, 4, 16, 8, 2, 64), (8, 8, 16, 8, 2, 64), (4, 4, 32, 16, 4, 128)]
    autotune(CASES, dtype=args.dtype, iters=args.iters, out_path=args.out)


if __name__ == "__main__":
    main()
