"""Flash attention — Pallas TPU kernel (online softmax, VMEM-tiled).

TPU adaptation of FlashAttention-2 (arXiv:2307.08691): the CUDA version's
shared-memory tiles + warp scheduling become VMEM blocks + a 4-D Pallas grid
``(batch, q_head, q_blocks, kv_blocks)`` whose minormost (kv) dimension
*revisits* the output block, carrying the running max / denominator /
accumulator in VMEM scratch between kv steps — the idiomatic TPU formulation
(grid-order accumulation instead of a thread-block inner loop).

Block sizes default to 128x128: MXU-aligned (128 lanes) and small enough
that q/k/v/acc tiles fit VMEM at head_dim <= 256 (gemma-7b's 256 included).
Supports causal masking, sliding windows (mistral/hymba), logit soft-cap
(gemma) and GQA head grouping — the feature set the ten assigned archs need.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e38


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    q_block: int,
    kv_block: int,
    kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (q_block, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (kv_block, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (q_blk, kv_blk)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kv_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = kv_pos < kv_len
    if causal:
        ok &= q_pos >= kv_pos
    if window > 0:
        ok &= (q_pos - kv_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[:, 0]  # (q_block,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_cur = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p.astype(v.dtype), v).astype(jnp.float32)
    m_ref[...] = m_cur[:, None]
    l_ref[...] = l_cur[:, None]

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def pl_scratch(shape, dtype):
    """VMEM scratch allocation (TPU target; interpret mode emulates it)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KV, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    qpk = H // KV
    assert Sq % q_block == 0 and Skv % kv_block == 0, (Sq, Skv, q_block, kv_block)
    scale = 1.0 / math.sqrt(hd)
    grid = (B, H, Sq // q_block, Skv // kv_block)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        q_block=q_block,
        kv_block=kv_block,
        kv_len=Skv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kv_block, hd), lambda b, h, qi, ki, _qpk=qpk: (b, h // _qpk, ki, 0)),
            pl.BlockSpec((1, 1, kv_block, hd), lambda b, h, qi, ki, _qpk=qpk: (b, h // _qpk, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pl_scratch((q_block, hd), jnp.float32),
            pl_scratch((q_block, 1), jnp.float32),
            pl_scratch((q_block, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
