"""Pure-jnp oracle for paged-attention decode.

Gathers the K/V blocks addressed by each sequence's block table into a
contiguous (B, nb*bs, KV, hd) view and runs exact fp32 softmax attention for
the single query token.  This is both the allclose reference for the Pallas
kernel and the ``attn_impl="xla"`` decode path of the paged serving engine
(at smoke scale the gather materialization is irrelevant; on TPU the Pallas
kernel avoids it).

Optionally consumes int8 block pools with per-(token, head) fp32 scales (the
``serving.kvquant`` KIVI layout) — dequantization happens after the gather.

Block-table contract (shared with the Pallas kernels): entries past a
sequence's last live block — inactive slots, mid-prefill slots, positions
beyond ``seq_lens`` — point at the reserved null block (id 0).  They are
gathered like any other block and then fully masked by the position
compare, so the null block's contents never influence an output.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def gather_blocks(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """pool: (N, bs, ...) + tables (B, nb) -> (B, nb*bs, ...) logical view."""
    B, nb = block_tables.shape
    bs = pool.shape[1]
    g = pool[block_tables]  # (B, nb, bs, ...)
    return g.reshape((B, nb * bs) + pool.shape[2:])


def paged_attention_ref(
    q: jax.Array,  # (B, H, hd) current-token queries
    k_pool: jax.Array,  # (N, bs, KV, hd) global block pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32 physical block ids (0 = null)
    seq_lens: jax.Array,  # (B,) int32 valid kv length (incl. current token)
    *,
    softcap: float = 0.0,
    window: int = 0,
    k_scale: jax.Array | None = None,  # (N, bs, KV, 1) fp32 (int8 pools)
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Returns (B, H, hd) attention output in q.dtype."""
    B, H, hd = q.shape
    KV = k_pool.shape[2]
    qpk = H // KV
    scale = 1.0 / math.sqrt(hd)

    k = gather_blocks(k_pool, block_tables).astype(jnp.float32)  # (B, S, KV, hd)
    v = gather_blocks(v_pool, block_tables).astype(jnp.float32)
    if k_scale is not None:
        k = k * gather_blocks(k_scale, block_tables)
    if v_scale is not None:
        v = v * gather_blocks(v_scale, block_tables)
    S = k.shape[1]

    qg = q.astype(jnp.float32).reshape(B, KV, qpk, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k) * scale  # (B, KV, qpk, S)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    kv_pos = jnp.arange(S, dtype=jnp.int32)[None, :]  # logical positions
    q_pos = (seq_lens - 1)[:, None]
    ok = kv_pos < seq_lens[:, None]  # causal: everything written so far
    if window > 0:
        ok &= (q_pos - kv_pos) < window
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)

    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bkgs,bskd->bkgd", w, v)
    return ctx.reshape(B, H, hd).astype(q.dtype)


def paged_prefill_attention_ref(
    q: jax.Array,  # (B, C, H, hd) chunk queries
    k_pool: jax.Array,  # (N, bs, KV, hd) global block pool (chunk K/V written)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32 physical block ids (0 = null)
    start: jax.Array,  # (B,) int32 absolute position of the chunk's first token
    *,
    softcap: float = 0.0,
    window: int = 0,
    k_scale: jax.Array | None = None,  # (N, bs, KV, 1) fp32 (int8 pools)
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Chunked-prefill oracle: C query tokens per sequence at absolute
    positions ``start + [0, C)`` attend causally (+ window) over the gathered
    paged view — the multi-query-token twin of ``paged_attention_ref``.
    Returns (B, C, H, hd) in q.dtype."""
    B, C, H, hd = q.shape
    KV = k_pool.shape[2]
    qpk = H // KV
    scale = 1.0 / math.sqrt(hd)

    k = gather_blocks(k_pool, block_tables).astype(jnp.float32)  # (B, S, KV, hd)
    v = gather_blocks(v_pool, block_tables).astype(jnp.float32)
    if k_scale is not None:
        k = k * gather_blocks(k_scale, block_tables)
    if v_scale is not None:
        v = v * gather_blocks(v_scale, block_tables)
    S = k.shape[1]

    qg = q.astype(jnp.float32).reshape(B, C, KV, qpk, hd)
    s = jnp.einsum("bckgd,bskd->bkcgs", qg, k) * scale  # (B, KV, C, qpk, S)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (B, C)
    kv_pos = jnp.arange(S, dtype=jnp.int32)[None, None, :]  # logical positions
    ok = kv_pos <= q_pos[:, :, None]  # causal: chunk K/V is already written
    if window > 0:
        ok &= (q_pos[:, :, None] - kv_pos) < window
    s = jnp.where(ok[:, None, :, None, :], s, NEG_INF)

    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bkcgs,bskd->bckgd", w, v)
    return ctx.reshape(B, C, H, hd).astype(q.dtype)
