"""BabelStream — Pallas TPU memory-bandwidth kernels (paper Fig. 10).

The paper benchmarks GH200 HBM bandwidth with BabelStream across nine
programming models; this is the TPU-native tenth: each kernel streams
HBM->VMEM->HBM through 1-D BlockSpec tiles sized to keep several tiles in
flight (double-buffered by the Pallas pipeline).  The five classic kernels:

    copy   c = a            2 x N x sizeof  bytes
    mul    b = s * c        2 x
    add    c = a + b        3 x
    triad  a = b + s * c    3 x
    dot    s = sum(a * b)   2 x (+ partials)

``benchmarks/babelstream.py`` derives achievable-bandwidth fractions from
these byte counts against the 819 GB/s v5e HBM roofline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 65_536  # elements per tile: 256 KiB f32 -> fits VMEM 2x-buffered


def _copy_kernel(a_ref, c_ref):
    c_ref[...] = a_ref[...]


def _mul_kernel(c_ref, b_ref, *, scalar: float):
    b_ref[...] = scalar * c_ref[...]


def _add_kernel(a_ref, b_ref, c_ref):
    c_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(b_ref, c_ref, a_ref, *, scalar: float):
    a_ref[...] = b_ref[...] + scalar * c_ref[...]


def _dot_kernel(a_ref, b_ref, p_ref):
    p_ref[0] = jnp.sum(a_ref[...].astype(jnp.float32) * b_ref[...].astype(jnp.float32))


def _grid_1d(n: int, block: int):
    assert n % block == 0, (n, block)
    return (n // block,)


def _spec(block: int):
    return pl.BlockSpec((block,), lambda i: (i,))


def stream_copy(a: jax.Array, *, block: int = DEFAULT_BLOCK, interpret: bool = True) -> jax.Array:
    n = a.shape[0]
    return pl.pallas_call(
        _copy_kernel,
        grid=_grid_1d(n, block),
        in_specs=[_spec(block)],
        out_specs=_spec(block),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=interpret,
    )(a)


def stream_mul(c: jax.Array, scalar: float = 0.4, *, block: int = DEFAULT_BLOCK, interpret: bool = True) -> jax.Array:
    n = c.shape[0]
    return pl.pallas_call(
        functools.partial(_mul_kernel, scalar=scalar),
        grid=_grid_1d(n, block),
        in_specs=[_spec(block)],
        out_specs=_spec(block),
        out_shape=jax.ShapeDtypeStruct((n,), c.dtype),
        interpret=interpret,
    )(c)


def stream_add(a: jax.Array, b: jax.Array, *, block: int = DEFAULT_BLOCK, interpret: bool = True) -> jax.Array:
    n = a.shape[0]
    return pl.pallas_call(
        _add_kernel,
        grid=_grid_1d(n, block),
        in_specs=[_spec(block), _spec(block)],
        out_specs=_spec(block),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=interpret,
    )(a, b)


def stream_triad(b: jax.Array, c: jax.Array, scalar: float = 0.4, *, block: int = DEFAULT_BLOCK, interpret: bool = True) -> jax.Array:
    n = b.shape[0]
    return pl.pallas_call(
        functools.partial(_triad_kernel, scalar=scalar),
        grid=_grid_1d(n, block),
        in_specs=[_spec(block), _spec(block)],
        out_specs=_spec(block),
        out_shape=jax.ShapeDtypeStruct((n,), b.dtype),
        interpret=interpret,
    )(b, c)


def stream_dot(a: jax.Array, b: jax.Array, *, block: int = DEFAULT_BLOCK, interpret: bool = True) -> jax.Array:
    n = a.shape[0]
    partials = pl.pallas_call(
        _dot_kernel,
        grid=_grid_1d(n, block),
        in_specs=[_spec(block), _spec(block)],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // block,), jnp.float32),
        interpret=interpret,
    )(a, b)
    return jnp.sum(partials)


def stream_bytes(kernel: str, n: int, itemsize: int) -> int:
    """HBM bytes moved per kernel invocation (BabelStream convention)."""
    mult = {"copy": 2, "mul": 2, "add": 3, "triad": 3, "dot": 2}[kernel]
    return mult * n * itemsize
