"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KV, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    qpk = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, qpk, Sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) / math.sqrt(hd)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= q_pos >= kv_pos
    if window > 0:
        ok &= (q_pos - kv_pos) < window
    s = jnp.where(ok[None, None, None], s, -1e38)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", w, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
