"""Pallas TPU kernels for the perf-critical compute hot spots.

Each kernel ships as a triple: ``<name>.py`` (pl.pallas_call + BlockSpec),
``<name>_ops.py`` (jit'd public wrapper) and ``<name>_ref.py`` (pure-jnp
oracle used by the allclose test sweeps).  TPU is the TARGET; on this CPU
image everything runs through ``interpret=True``.
"""

from repro.fp8.gemm import fp8_gemm
from repro.kernels import flash_attention_ops, paged_attention_ops
from repro.kernels.babelstream import (
    stream_add,
    stream_bytes,
    stream_copy,
    stream_dot,
    stream_mul,
    stream_triad,
)
from repro.kernels.flash_attention_ops import flash_attention
from repro.kernels.paged_attention_ops import (
    paged_attention,
    paged_attention_quantized,
    paged_prefill_attention,
    paged_prefill_attention_quantized,
)
from repro.kernels.rwkv6_scan_ops import wkv6

__all__ = [
    "flash_attention",
    "flash_attention_ops",
    "fp8_gemm",
    "paged_attention",
    "paged_attention_ops",
    "paged_attention_quantized",
    "paged_prefill_attention",
    "paged_prefill_attention_quantized",
    "stream_add",
    "stream_bytes",
    "stream_copy",
    "stream_dot",
    "stream_mul",
    "stream_triad",
    "wkv6",
]
