"""Pure-jnp oracles for the BabelStream kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def copy_ref(a):
    return a


def mul_ref(c, scalar: float = 0.4):
    return scalar * c


def add_ref(a, b):
    return a + b


def triad_ref(b, c, scalar: float = 0.4):
    return b + scalar * c


def dot_ref(a, b):
    return jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32))
