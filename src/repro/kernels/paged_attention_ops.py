"""Jit'd public wrappers for the paged-attention kernels (decode + chunked
prefill).

Routes fp pools through the Pallas kernels (interpret mode off-TPU); int8
pools with per-(token, head) scales fall back to the dequantizing jnp
reference — the int8 savings are an HBM-traffic property, and on this CPU
image both paths are emulated anyway.

Dtype contract: the pool dtype selects the path, and the two must never
mix — fp entry points raise on int8 pools (scales are required:
``*_quantized``), and the quantized wrappers expect the exact
``serving.kvquant`` layout (int8 ``k``/``v`` + fp32 per-(token, head)
``k_scale``/``v_scale``).  The chunked-prefill wrappers serve both the
prefill chunks and the speculative-decoding verify pass
(``models.verify_step``) — same kernel, different caller.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import paged_attention_bhd, paged_prefill_attention_bhd
from repro.kernels.paged_attention_ref import paged_attention_ref, paged_prefill_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("softcap", "window"))
def paged_attention(
    q: jax.Array,  # (B, H, hd) current-token queries
    k_pool: jax.Array,  # (N, bs, KV, hd)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32
    seq_lens: jax.Array,  # (B,) int32, >= 1
    *,
    softcap: float = 0.0,
    window: int = 0,
) -> jax.Array:
    if k_pool.dtype == jnp.int8:
        raise ValueError("int8 pools need scales: use paged_attention_quantized")
    return paged_attention_bhd(
        q,
        k_pool,
        v_pool,
        block_tables,
        seq_lens,
        softcap=softcap,
        window=window,
        interpret=not _on_tpu(),
    )


@partial(jax.jit, static_argnames=("softcap", "window"))
def paged_prefill_attention(
    q: jax.Array,  # (B, C, H, hd) chunk queries
    k_pool: jax.Array,  # (N, bs, KV, hd)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32
    start: jax.Array,  # (B,) int32 absolute position of the chunk's first token
    *,
    softcap: float = 0.0,
    window: int = 0,
) -> jax.Array:
    if k_pool.dtype == jnp.int8:
        raise ValueError("int8 pools need scales: use paged_prefill_attention_quantized")
    return paged_prefill_attention_bhd(
        q,
        k_pool,
        v_pool,
        block_tables,
        start,
        softcap=softcap,
        window=window,
        interpret=not _on_tpu(),
    )


@partial(jax.jit, static_argnames=("softcap", "window"))
def paged_prefill_attention_quantized(
    q: jax.Array,
    k_pool: jax.Array,  # int8 (N, bs, KV, hd)
    v_pool: jax.Array,
    k_scale: jax.Array,  # fp32 (N, bs, KV, 1)
    v_scale: jax.Array,
    block_tables: jax.Array,
    start: jax.Array,
    *,
    softcap: float = 0.0,
    window: int = 0,
) -> jax.Array:
    return paged_prefill_attention_ref(
        q,
        k_pool,
        v_pool,
        block_tables,
        start,
        softcap=softcap,
        window=window,
        k_scale=k_scale,
        v_scale=v_scale,
    )


@partial(jax.jit, static_argnames=("softcap", "window"))
def paged_attention_quantized(
    q: jax.Array,
    k_pool: jax.Array,  # int8 (N, bs, KV, hd)
    v_pool: jax.Array,
    k_scale: jax.Array,  # fp32 (N, bs, KV, 1)
    v_scale: jax.Array,
    block_tables: jax.Array,
    seq_lens: jax.Array,
    *,
    softcap: float = 0.0,
    window: int = 0,
) -> jax.Array:
    return paged_attention_ref(
        q,
        k_pool,
        v_pool,
        block_tables,
        seq_lens,
        softcap=softcap,
        window=window,
        k_scale=k_scale,
        v_scale=v_scale,
    )
