"""Jit'd public wrappers for the paged-attention kernels (decode + chunked
prefill).

Routes fp pools through the Pallas kernels (interpret mode off-TPU);
quantized (int8/fp8) pools with per-(token, head) scales fall back to the
dequantizing jnp reference — the quantization savings are an HBM-traffic
property, and on this CPU image both paths are emulated anyway.

Dtype contract: the pool dtype selects the path, and the two must never
mix — fp entry points raise on quantized pools (scales are required:
``*_quantized``), and the quantized wrappers expect the exact
``serving.kvquant`` layout (int8/e4m3 ``k``/``v`` + fp32 per-(token, head)
``k_scale``/``v_scale``).  The chunked-prefill wrappers serve both the
prefill chunks and the speculative-decoding verify pass
(``models.verify_step``) — same kernel, different caller.

Layout choices (decode kernel vs C=1 prefill kernel, prefill query-row
tiling) come from the ``kernels.autotune`` cache, consulted at trace time —
shapes are static under ``jax.jit``, so each compiled graph bakes in one
tuned layout.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import autotune
from repro.kernels.paged_attention import paged_attention_bhd, paged_prefill_attention_bhd
from repro.kernels.paged_attention_ref import paged_attention_ref, paged_prefill_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _quantized_pool(dtype) -> bool:
    from repro.serving.kvquant import is_quantized_kv

    return is_quantized_kv(dtype)


def model_axis_size(mesh) -> int:
    """Size of the tensor-parallel ("model") axis; 1 when no mesh is active."""
    if mesh is None:
        return 1
    return int(mesh.shape.get("model", 1))


def kernel_shardable(mesh, num_q_heads: int, num_kv_heads: int) -> bool:
    """A Pallas call is opaque to GSPMD, so on a multi-device mesh the kernel
    must run per-shard under ``shard_map`` on its local head slice.  That
    requires BOTH head counts to divide the model axis (the contiguous
    per-device q-head slice then stays aligned with its GQA kv group).
    Callers fall back to the XLA reference path when this returns False."""
    tp = model_axis_size(mesh)
    if tp <= 1:
        return True
    return num_q_heads % tp == 0 and num_kv_heads % tp == 0


def _tp_dispatch(mesh, kernel, ref, q_spec, num_q_heads: int, num_kv_heads: int):
    """One TP dispatch rule for both paged kernels: per-shard ``shard_map``
    on the local head slice when the head counts divide, else the jnp
    reference (which GSPMD partitions freely).  ``q_spec`` is the query (and
    output) PartitionSpec — the only thing that differs between the decode
    (B, H, hd) and chunked-prefill (B, C, H, hd) entry points."""
    if not kernel_shardable(mesh, num_q_heads, num_kv_heads):
        return ref
    pool = P(None, None, "model", None)  # every block, local head slice
    return shard_map(
        kernel,
        mesh=mesh,
        in_specs=(q_spec, pool, pool, P(None, None), P(None)),
        out_specs=q_spec,
        check_rep=False,
    )


@partial(jax.jit, static_argnames=("softcap", "window", "mesh"))
def paged_attention(
    q: jax.Array,  # (B, H, hd) current-token queries
    k_pool: jax.Array,  # (N, bs, KV, hd)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32
    seq_lens: jax.Array,  # (B,) int32, >= 1
    *,
    softcap: float = 0.0,
    window: int = 0,
    mesh=None,
) -> jax.Array:
    if _quantized_pool(k_pool.dtype):
        raise ValueError("quantized pools need scales: use paged_attention_quantized")
    tuned = autotune.get_config(
        k_pool.shape[3], k_pool.shape[1], block_tables.shape[1], k_pool.dtype
    )
    if tuned["decode_kernel"] == "prefill1":
        # C=1 prefill layout: start = seq_lens - 1 makes the causal/window
        # masks degenerate to the decode masks exactly
        base = partial(
            paged_prefill_attention_bhd,
            softcap=softcap,
            window=window,
            interpret=not _on_tpu(),
            rows_per_tile=tuned["prefill_rows_per_tile"],
        )

        def kernel(qq, kk, vv, tbl, lens):
            return base(qq[:, None], kk, vv, tbl, lens - 1)[:, 0]

    else:
        kernel = partial(
            paged_attention_bhd,
            softcap=softcap,
            window=window,
            interpret=not _on_tpu(),
        )
    if model_axis_size(mesh) > 1:
        kernel = _tp_dispatch(
            mesh,
            kernel,
            partial(paged_attention_ref, softcap=softcap, window=window),
            P(None, "model", None),
            q.shape[1],
            k_pool.shape[2],
        )
    return kernel(q, k_pool, v_pool, block_tables, seq_lens)


@partial(jax.jit, static_argnames=("softcap", "window", "mesh"))
def paged_prefill_attention(
    q: jax.Array,  # (B, C, H, hd) chunk queries
    k_pool: jax.Array,  # (N, bs, KV, hd)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32
    start: jax.Array,  # (B,) int32 absolute position of the chunk's first token
    *,
    softcap: float = 0.0,
    window: int = 0,
    mesh=None,
) -> jax.Array:
    if _quantized_pool(k_pool.dtype):
        raise ValueError("quantized pools need scales: use paged_prefill_attention_quantized")
    tuned = autotune.get_config(
        k_pool.shape[3], k_pool.shape[1], block_tables.shape[1], k_pool.dtype
    )
    kernel = partial(
        paged_prefill_attention_bhd,
        softcap=softcap,
        window=window,
        interpret=not _on_tpu(),
        rows_per_tile=tuned["prefill_rows_per_tile"],
    )
    if model_axis_size(mesh) > 1:
        kernel = _tp_dispatch(
            mesh,
            kernel,
            partial(paged_prefill_attention_ref, softcap=softcap, window=window),
            P(None, None, "model", None),
            q.shape[2],
            k_pool.shape[2],
        )
    return kernel(q, k_pool, v_pool, block_tables, start)


@partial(jax.jit, static_argnames=("softcap", "window"))
def paged_prefill_attention_quantized(
    q: jax.Array,
    k_pool: jax.Array,  # int8 (N, bs, KV, hd)
    v_pool: jax.Array,
    k_scale: jax.Array,  # fp32 (N, bs, KV, 1)
    v_scale: jax.Array,
    block_tables: jax.Array,
    start: jax.Array,
    *,
    softcap: float = 0.0,
    window: int = 0,
) -> jax.Array:
    return paged_prefill_attention_ref(
        q,
        k_pool,
        v_pool,
        block_tables,
        start,
        softcap=softcap,
        window=window,
        k_scale=k_scale,
        v_scale=v_scale,
    )


@partial(jax.jit, static_argnames=("softcap", "window"))
def paged_attention_quantized(
    q: jax.Array,
    k_pool: jax.Array,  # int8 (N, bs, KV, hd)
    v_pool: jax.Array,
    k_scale: jax.Array,  # fp32 (N, bs, KV, 1)
    v_scale: jax.Array,
    block_tables: jax.Array,
    seq_lens: jax.Array,
    *,
    softcap: float = 0.0,
    window: int = 0,
) -> jax.Array:
    return paged_attention_ref(
        q,
        k_pool,
        v_pool,
        block_tables,
        seq_lens,
        softcap=softcap,
        window=window,
        k_scale=k_scale,
        v_scale=v_scale,
    )
