"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

The conv waveform frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, S, d_model); the model applies a
frame projection + learned positions + the 48-layer encoder, predicting the
504-unit masked-cluster vocabulary.
"""

from repro.config import ModelConfig
from repro.configs import register


@register("hubert-xlarge")
def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        norm="layernorm",
        activation="gelu",
        use_bias=True,
        causal=False,  # bidirectional encoder
        rotary_pct=0.0,
        learned_pos_embedding=True,
        max_position=32_768,  # covers the prefill_32k cell
        tie_embeddings=False,
        source="arXiv:2106.07447; unverified",
    )
