"""arctic-480b — 128-expert top-2 MoE + dense residual [hf:Snowflake/snowflake-arctic-base]."""

from repro.config import ModelConfig, MoEConfig
from repro.configs import register


@register("arctic-480b")
def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,  # dense-residual FFN width
        vocab_size=32000,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=10000.0,
        tie_embeddings=False,
        moe=MoEConfig(
            num_experts=128,
            top_k=2,
            expert_d_ff=4864,
            dense_residual=True,  # Arctic's dense-MoE hybrid residual
            capacity_factor=1.25,
            group_size=2048,
        ),
        source="hf:Snowflake/snowflake-arctic-base",
    )
