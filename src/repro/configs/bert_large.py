"""bert-large — the paper's own MLPerf training benchmark (Fig. 8).

Encoder-only, 24L/1024d/16H, GELU, post-LN approximated as parametric LN
(pre-LN form; the distribution/roofline shape is identical).
"""

from repro.config import ModelConfig
from repro.configs import register


@register("bert-large")
def config() -> ModelConfig:
    return ModelConfig(
        name="bert-large",
        family="dense",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=30522,
        norm="layernorm",
        activation="gelu",
        use_bias=True,
        causal=False,  # bidirectional encoder
        rotary_pct=0.0,
        learned_pos_embedding=True,
        max_position=512,
        tie_embeddings=True,
        source="arXiv:1810.04805; MLPerf v3.1 (paper Fig. 8)",
    )
