"""rwkv6-7b — Finch: attention-free, data-dependent decay [arXiv:2404.05892]."""

from repro.config import ModelConfig, RWKVConfig
from repro.configs import register


@register("rwkv6-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,  # d_model / head_size(64)
        num_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        norm="layernorm",
        activation="relu2",  # channel-mix uses squared ReLU
        rotary_pct=0.0,  # attention-free: no RoPE
        tie_embeddings=False,
        rwkv=RWKVConfig(head_size=64, lora_rank_decay=64, lora_rank_mix=32, chunk_size=64),
        subquadratic=True,  # O(1)-state decode -> long_500k runnable
        source="arXiv:2404.05892; hf",
    )
