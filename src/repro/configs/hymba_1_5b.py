"""hymba-1.5b — parallel attention + Mamba heads per block [arXiv:2411.13676].

TPU adaptations (DESIGN.md §2): SSM heads use the Mamba-2/SSD per-head-dt
formulation; sliding-window attention stands in for Hymba's SWA+meta-token
scheme (the three global-attention layers and the 128 learnable meta tokens
are omitted — they do not change the distribution/roofline shape).
"""

from repro.config import ModelConfig, SSMConfig
from repro.configs import register


@register("hymba-1.5b")
def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,  # 25*64 = 1600
        d_ff=5504,
        vocab_size=32001,
        norm="rmsnorm",
        activation="swiglu",
        sliding_window=1024,
        rope_theta=10000.0,
        tie_embeddings=True,
        ssm=SSMConfig(state_size=16, conv_width=4, expand=2, chunk_size=256),
        subquadratic=True,  # SWA + constant SSM state -> long_500k runnable
        source="arXiv:2411.13676; hf",
    )
