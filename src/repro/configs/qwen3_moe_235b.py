"""qwen3-moe-235b-a22b — 128-expert top-8 MoE, QK-norm [hf:Qwen/Qwen3-30B-A3B]."""

from repro.config import ModelConfig, MoEConfig
from repro.configs import register


@register("qwen3-moe-235b-a22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,  # (= expert width; no dense FFN in this arch)
        vocab_size=151936,
        norm="rmsnorm",
        activation="swiglu",
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        moe=MoEConfig(
            num_experts=128,
            top_k=8,
            expert_d_ff=1536,
            capacity_factor=1.25,
            group_size=2048,
        ),
        source="hf:Qwen/Qwen3-30B-A3B",
    )
