"""stablelm-12b — parallel residual, partial rotary [hf:stabilityai/stablelm-2-*]."""

from repro.config import ModelConfig
from repro.configs import register


@register("stablelm-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=13824,
        vocab_size=100352,
        norm="layernorm",
        activation="swiglu",
        rotary_pct=0.25,  # StableLM-2 partial rotary
        parallel_residual=True,  # single LN feeds attn + FFN (12b variant)
        rope_theta=10000.0,
        tie_embeddings=False,
        source="hf:stabilityai/stablelm-2-1_6b",
    )
