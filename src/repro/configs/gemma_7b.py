"""gemma-7b — GeGLU, head_dim=256, scaled embeddings [arXiv:2403.08295]."""

from repro.config import ModelConfig
from repro.configs import register


@register("gemma-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,  # 16*256 = 4096 != 3072 (Gemma decouples head_dim)
        d_ff=24576,
        vocab_size=256000,
        norm="rmsnorm",
        activation="geglu",
        scale_embedding=True,  # x *= sqrt(d_model)
        rope_theta=10000.0,
        tie_embeddings=True,
        source="arXiv:2403.08295; hf",
    )
