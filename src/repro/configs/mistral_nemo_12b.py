"""mistral-nemo-12b — 128k context [hf:mistralai/Mistral-Nemo-Base-2407]."""

from repro.config import ModelConfig
from repro.configs import register


@register("mistral-nemo-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,  # explicit: 32*128 != 5120 (Nemo decouples head_dim)
        d_ff=14336,
        vocab_size=131072,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=1_000_000.0,  # 128k-context rope base
        tie_embeddings=False,
        source="hf:mistralai/Mistral-Nemo-Base-2407",
    )
