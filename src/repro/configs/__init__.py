"""Architecture registry: the 10 assigned archs + the paper's benchmark archs.

Each ``<arch>.py`` transcribes the assignment table exactly; ``get_config``
resolves the dashed arch id (``--arch rwkv6-7b``).
"""

from __future__ import annotations

from repro.config import ModelConfig
from repro.utils.registry import Registry

ARCHS: Registry = Registry("architecture")


def register(name: str):
    def deco(fn):
        ARCHS.register(name, fn)
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    return ARCHS.get(name)()


def list_archs() -> list[str]:
    return ARCHS.names()


# import for registration side effects
from repro.configs import (  # noqa: E402,F401
    arctic_480b,
    bert_large,
    gemma_7b,
    hubert_xlarge,
    hymba_1_5b,
    llama32_vision_90b,
    mistral_nemo_12b,
    olmo_1b,
    qwen3_moe_235b,
    rwkv6_7b,
    stablelm_12b,
)

# The ten assigned architectures (dry-run set), in assignment order.
ASSIGNED = [
    "rwkv6-7b",
    "olmo-1b",
    "mistral-nemo-12b",
    "stablelm-12b",
    "gemma-7b",
    "hubert-xlarge",
    "arctic-480b",
    "qwen3-moe-235b-a22b",
    "hymba-1.5b",
    "llama-3.2-vision-90b",
]
