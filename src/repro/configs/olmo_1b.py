"""olmo-1b — non-parametric LayerNorm [arXiv:2402.00838]."""

from repro.config import ModelConfig
from repro.configs import register


@register("olmo-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,  # GQA kv=16 (i.e. MHA)
        d_ff=8192,
        vocab_size=50304,
        norm="layernorm_np",  # OLMo: non-parametric LN
        activation="swiglu",
        rope_theta=10000.0,
        tie_embeddings=True,
        source="arXiv:2402.00838; hf",
    )
