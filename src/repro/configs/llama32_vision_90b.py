"""llama-3.2-vision-90b — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

The vision tower is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, 1600, d_model) as cross-attention keys.
100 decoder layers scan as 20 groups of (4 self + 1 cross).
"""

from repro.config import ModelConfig, VisionConfig
from repro.configs import register


@register("llama-3.2-vision-90b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        norm="rmsnorm",
        activation="swiglu",
        rope_theta=500_000.0,
        tie_embeddings=False,
        vision=VisionConfig(num_image_tokens=1600, cross_attn_every=5),
        source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    )
