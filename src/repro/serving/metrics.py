"""Serving metrics: a dependency-free registry of counters, gauges and
fixed-bucket latency histograms.

The paper operates Isambard-AI like a cloud AI service — Jupyter/MLOps
front-ends under continuous load with a DCIM correlating facility power and
IT-side activity (§IV.A).  Peer systems treat service-level monitoring as
baseline infrastructure; this module is that substrate for the paged
serving engine: every latency-shaped quantity (queue wait, TTFT, TPOT,
per-step and per-chunk latency) lands in a histogram whose percentiles the
benchmarks and the async/SLO roadmap items assert against, and every
throughput-shaped quantity (tokens, admissions, prefix hits, speculative
acceptance, evictions) lands in a counter.

Design constraints, in order:

* **Dependency-free and host-only** — plain Python ints/floats, no
  prometheus_client, no numpy on the hot path.  An ``observe()`` is one
  ``bisect`` plus four scalar updates, so the engine can publish from every
  step without perturbing what it measures.
* **Injectable clock** — every engine timestamp routes through one
  ``clock()`` callable (default ``time.monotonic``).  ``ManualClock`` lets
  tests pin the clock and assert *exact* latencies instead of sleeping.
* **Two exports** — ``render_text()`` emits the Prometheus text exposition
  format (scrape-ready, ``le``-labelled cumulative buckets) and
  ``snapshot()`` emits a JSON-serializable dict with p50/p90/p99 already
  derived (what ``--metrics-json`` and the benchmark JSON consume).

Histogram percentiles interpolate linearly inside the owning bucket (the
``histogram_quantile`` rule) and clamp to the observed min/max, so the
error is bounded by one bucket's width — the default buckets are a
factor-of-2 geometric ladder over 10 µs … ~84 s, tested against a numpy
oracle in ``tests/test_metrics.py``.

``EnergyBridge`` reconnects the paper's DCIM accounting to serving: each
engine step charges ``chips x seconds`` at an occupancy-derived (or
caller-supplied roofline) utilization into the seed
``core.telemetry.EnergyLedger``, giving joules/token per request — the
service-side view of the facility-side tables in ``core/telemetry.py``.
"""

from __future__ import annotations

import json
import math
import time
from bisect import bisect_left
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

from repro.core.telemetry import EnergyLedger


class ManualClock:
    """Deterministic monotonic clock for tests.

    ``tick`` > 0 advances the clock by that much on every read (strictly
    increasing timestamps without wall time); ``advance`` jumps it
    explicitly.  Passing an instance as the engine's ``clock=`` makes every
    recorded latency an exact, assertable number.
    """

    def __init__(self, start: float = 0.0, tick: float = 0.0):
        self._t = float(start)
        self.tick = float(tick)

    def __call__(self) -> float:
        t = self._t
        self._t += self.tick
        return t

    @property
    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock must be monotonic: advance({dt})")
        self._t += dt


def exponential_buckets(start: float, factor: float, count: int) -> list[float]:
    """``count`` geometric bucket upper bounds: start, start*factor, ..."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError(f"exponential_buckets({start}, {factor}, {count})")
    return [start * factor**i for i in range(count)]


# 10 us .. ~84 s at x2 resolution: covers a single jitted dispatch on real
# hardware up to a CPU-smoke drained run, with <= 2x percentile error
DEFAULT_TIME_BUCKETS = exponential_buckets(1e-5, 2.0, 24)


def _fmt(v: float) -> str:
    return format(float(v), ".10g")


class Counter:
    """Monotonically increasing float counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} can only increase (inc({v}))")
        self.value += v

    def render(self) -> list[str]:
        return [f"{self.name} {_fmt(self.value)}"]

    def snapshot(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Instantaneous value (set, not accumulated)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def render(self) -> list[str]:
        return [f"{self.name} {_fmt(self.value)}"]

    def snapshot(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Fixed-bucket histogram with Prometheus semantics.

    ``bounds`` are ascending finite upper bounds; an implicit +Inf bucket
    catches overflow.  ``percentile`` interpolates linearly inside the
    owning bucket and clamps to the observed [min, max], so the returned
    value is within one bucket width of the true order statistic.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.help = help
        self.bounds = [float(b) for b in (buckets if buckets is not None else DEFAULT_TIME_BUCKETS)]
        if self.bounds != sorted(self.bounds) or len(set(self.bounds)) != len(self.bounds):
            raise ValueError(f"histogram {name}: buckets must be strictly ascending")
        self.counts = [0] * (len(self.bounds) + 1)  # [-1] = overflow (+Inf)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def percentile(self, pct: float) -> Optional[float]:
        """The pct-th percentile (0 < pct <= 100), or None when empty."""
        if self.count == 0:
            return None
        if not 0 < pct <= 100:
            raise ValueError(f"percentile({pct})")
        rank = pct / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= rank and c > 0:
                if i == len(self.bounds):  # overflow bucket: no upper edge
                    return self.max
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                val = lo + (rank - cum) / c * (hi - lo)
                return min(max(val, self.min), self.max)
            cum += c
        return self.max  # unreachable: cum == count by the last bucket

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def render(self) -> list[str]:
        lines = []
        cum = 0
        for b, c in zip(self.bounds, self.counts):
            cum += c
            lines.append(f'{self.name}_bucket{{le="{_fmt(b)}"}} {cum}')
        lines.append(f'{self.name}_bucket{{le="+Inf"}} {self.count}')
        lines.append(f"{self.name}_sum {_fmt(self.sum)}")
        lines.append(f"{self.name}_count {self.count}")
        return lines

    def snapshot(self) -> dict:
        cum, buckets = 0, []
        for b, c in zip(self.bounds, self.counts):
            cum += c
            buckets.append({"le": b, "count": cum})
        buckets.append({"le": "+Inf", "count": self.count})
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
            "buckets": buckets,
        }


class MetricsRegistry:
    """Named instruments, get-or-create; one registry per engine.

    Registration is idempotent — asking for an existing name returns the
    existing instrument (help text of the first registration wins), so the
    engine, allocator, prefix index and drafters can all publish into one
    registry without coordination.  Asking for an existing name as a
    *different* kind raises.
    """

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get_or_create(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} is a {m.kind}, not a {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(
        self, name: str, help: str = "", buckets: Optional[Sequence[float]] = None
    ) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str):
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return list(self._metrics)

    def percentiles(self, name: str, pcts: Iterable[float] = (50, 90, 99)) -> dict:
        """p-th percentiles of a histogram; all-None when absent/empty."""
        h = self._metrics.get(name)
        if not isinstance(h, Histogram):
            return {p: None for p in pcts}
        return {p: h.percentile(p) for p in pcts}

    def render_text(self, prefix: str = "") -> str:
        """Prometheus text exposition (scrape-ready).

        ``prefix`` prepends every metric name — the multi-replica router
        renders each replica engine's registry as ``replica<N>_...`` so one
        ``/metrics`` scrape carries the whole fleet without name collisions.
        """
        lines = []
        for name, m in self._metrics.items():
            pname = prefix + name
            if m.help:
                lines.append(f"# HELP {pname} {m.help}")
            lines.append(f"# TYPE {pname} {m.kind}")
            lines.extend(
                prefix + ln if prefix else ln for ln in m.render()
            )
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> dict:
        """JSON-serializable view grouped by kind, percentiles derived."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in self._metrics.items():
            out[m.kind + "s"][name] = m.snapshot()
        return out

    def write_json(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)


@dataclass
class EnergyBridge:
    """Charge engine activity into the seed DCIM ``EnergyLedger``.

    Each engine step records ``chips x seconds`` at a utilization — by
    default the step's slot occupancy (an activity proxy for the roofline
    compute share: an idle slot leaves its sweep's FLOPs on the floor), or
    a fixed ``utilization`` override when the caller has a roofline-derived
    number (``core.telemetry.train_step_utilization``).  The engine then
    attributes the step's IT-side joules to the requests that did work that
    step, proportional to tokens computed, which yields joules/token per
    request — the per-request view of the paper's facility accounting.
    """

    chips: int = 1
    job_id: str = "serving"
    utilization: Optional[float] = None  # fixed override; None = occupancy proxy
    ledger: EnergyLedger = field(default_factory=EnergyLedger)
    joules: float = 0.0  # IT-side joules charged so far

    def record_step(self, seconds: float, *, occupancy: float) -> float:
        """Integrate one engine step; returns the IT-side joules charged."""
        if seconds <= 0:
            return 0.0
        util = occupancy if self.utilization is None else self.utilization
        j = self.ledger.record(self.job_id, chips=self.chips, seconds=seconds, utilization=util)
        self.joules += j
        return j

    def report(self) -> dict:
        return self.ledger.report()
