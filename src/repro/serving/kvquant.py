"""Int8 KV-cache quantization (KIVI-style, arXiv:2402.02750).

The §Perf analysis shows decode cells are HBM-bound on KV-cache reads after
the stationary-weights fix (arctic decode: 13.65 ms memory term).  Int8 KV
with per-(token, head) scales halves that traffic vs bf16 (4× vs fp32):

    k_q[b, s, h, :] = round(k[b, s, h, :] / scale),  scale = amax / 127

Keys are quantized per-channel-group post-RoPE (the simple KIVI variant);
values per-token.  Dequantization happens at attention time — on TPU it
fuses into the score matmul's operand load.

This module is the opt-in serving feature: ``quantize_cache`` converts a
decode cache in place; ``attend_quantized`` is the reference consumption
path validated against fp attention in tests/test_kvquant.py.

Paged pools (``InferenceEngine(quantize_kv=...)``) use ``quantize`` at
every write site — prefill graft, chunk scatter, decode, speculative
verify — storing quantized ``k``/``v`` blocks with fp32 per-(token, head)
scales in sibling ``k_scale``/``v_scale`` pool leaves; the block-table ops
in ``serving.kvcache`` move scale rows together with their data rows.

Two block dtypes share the layout and the dequantizing read path
(``pool.astype(f32) * scale`` in ``kernels.paged_attention_ref``):

* ``"int8"`` — symmetric round-to-nearest, scale = amax / 127 (KIVI).
* ``"fp8"`` — e4m3 saturating cast (the PR-1 ``repro.fp8`` recipe applied
  per-(token, head)), scale = amax / 448.  Same byte footprint as int8 but
  a nonuniform grid: more resolution near zero, coarser at the amax edge.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

E4M3 = jnp.float8_e4m3fn
E4M3_MAX = 448.0

KV_QUANT_MODES = ("int8", "fp8")
_STORAGE_DTYPES = {"int8": jnp.int8, "fp8": E4M3}


def normalize_kv_quant(mode) -> str | None:
    """Engine knob -> canonical mode string (``True`` keeps meaning int8)."""
    if not mode:
        return None
    if mode is True:
        return "int8"
    if mode not in KV_QUANT_MODES:
        raise ValueError(f"quantize_kv must be one of {KV_QUANT_MODES}, got {mode!r}")
    return mode


def kv_storage_dtype(mode: str):
    return _STORAGE_DTYPES[normalize_kv_quant(mode)]


def kv_quant_mode_of(dtype) -> str | None:
    """Mode implied by a pool's storage dtype (None for unquantized pools)."""
    for mode, dt in _STORAGE_DTYPES.items():
        if dtype == dt:
            return mode
    return None


def is_quantized_kv(dtype) -> bool:
    """True when a pool dtype carries sibling scale leaves (int8 or fp8)."""
    return kv_quant_mode_of(dtype) is not None


class QuantizedKV(NamedTuple):
    k_q: jax.Array  # int8/e4m3, same shape as k
    k_scale: jax.Array  # fp32 (..., seq, heads, 1)
    v_q: jax.Array
    v_scale: jax.Array


def quantize(x: jax.Array, mode: str = "int8") -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric quantize. x: (..., seq, heads, head_dim).

    Both modes return ``(q, scale)`` with dequant = ``q.astype(f32) * scale``,
    so every consumer (ref kernels, spill tier, COW copies) is mode-agnostic.
    """
    xf = x.astype(jnp.float32)
    if mode == "fp8":
        scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / E4M3_MAX
        scale = jnp.maximum(scale, 1e-8)
        # saturating cast: astype(e4m3) maps out-of-range to NaN, so clip first
        q = jnp.clip(xf / scale, -E4M3_MAX, E4M3_MAX).astype(E4M3)
        return q, scale
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_cache(k: jax.Array, v: jax.Array) -> QuantizedKV:
    k_q, k_s = quantize(k)
    v_q, v_s = quantize(v)
    return QuantizedKV(k_q, k_s, v_q, v_s)


def cache_bytes(kv: QuantizedKV) -> int:
    tot = 0
    for a in kv:
        tot += a.size * a.dtype.itemsize
    return tot


def attend_quantized(cfg, q: jax.Array, kv: QuantizedKV, mask: jax.Array) -> jax.Array:
    """Reference decode attention over a quantized cache.

    q: (B, 1, H, hd); kv arrays: (B, W, KV, hd); mask: (B, 1, 1, 1, W).
    Returns (B, 1, H, hd).
    """
    from repro.models.attention import _attend_block

    k = dequantize(kv.k_q, kv.k_scale, q.dtype)
    v = dequantize(kv.v_q, kv.v_scale, q.dtype)
    return _attend_block(cfg, q, k, v, mask, cfg.q_per_kv)


def memory_saving(seq: int, kv_heads: int, head_dim: int, layers: int, batch: int, from_dtype_bytes: int = 2) -> dict:
    """Roofline arithmetic for the decode memory term (per step, global)."""
    base = 2 * layers * batch * seq * kv_heads * head_dim * from_dtype_bytes
    quant = 2 * layers * batch * seq * kv_heads * (head_dim * 1 + 4)  # int8 + fp32 scale
    return {"bf16_bytes": base, "int8_bytes": quant, "ratio": base / quant}
