"""Continuous-batching inference engine with a prefix-cached paged KV cache.

The paper's inference QoS class served as a real engine: a fixed-size decode
batch whose slots are continuously refilled as requests finish (Orca-style
iteration-level scheduling).  Every ``step()`` advances ALL active slots one
token through the jitted ``decode_step``; prompt processing is **incremental
and shared** for the paged attention families:

* **Prefix caching** (``serving.prefix``): every full token-aligned block of
  a prefilled prompt is indexed by a content chain hash.  Admission matches
  the longest cached prefix, bumps refcounts on the shared blocks (a partial
  tail hit is copied-on-write into a private block) and schedules only the
  *suffix* for prefill — a fleet of requests sharing a system prompt
  computes it once.  Finished requests park their indexed blocks in an LRU
  pool that is evicted on demand, not freed eagerly.
* **Chunked prefill**: instead of a blocking batch=1 prefill at admission,
  prompts are processed in per-``step()`` budgeted chunks
  (``prefill_budget`` tokens per step, binary-decomposed into power-of-two
  chunk sizes for a bounded trace count) interleaved with decode — one long
  prompt no longer stalls every decoding request.  Suffix chunks attend over
  the request's already-grafted paged history via the multi-query-token
  ``kernels.paged_prefill_attention`` path; a mid-prefill slot keeps a null
  row in the engine block table so interleaved decode steps can't touch its
  blocks.

Two cache layouts:

* ``cache_kind="paged"`` (default for dense/moe/hybrid) — a global block
  pool + per-request block tables (``serving.paged.BlockAllocator``).
  Admission is gated on **free blocks** (cached refcount-0 blocks count:
  they are evictable on demand): a request reserves
  ``ceil((prompt + max_new_tokens) / block_size)`` blocks minus whatever the
  prefix cache already holds, so concurrency is bounded by actual cache
  *bytes in use* and shared prefixes admit for the price of their suffix.
* ``cache_kind="dense"`` — the original slot-granular ring-buffer cache
  (still used by ssm/vlm families, and as the A/B baseline in benchmarks).

**Tiered KV cache** (``spill_bytes=``, ``spill_dtype=``): the paged pool is
backed by a host-RAM spill tier (``serving.spill.SpillPool``).  An LRU
eviction of a prefix-indexed block demotes its K/V rows to host memory
(optionally int8/fp8-compressed at rest) instead of destroying them; the
index entry stays matchable under a spill handle, and a later prefix hit
admits as a cheap *re-prefill*: fresh device blocks are allocated, the
entry promotes onto them, and the row swap-ins run through the scheduler's
per-step ``restore_budget`` — double-buffered against decode, never
blocking admission.  Greedy outputs are token-identical to both the
drop-on-evict baseline and the dense-cache oracle
(``tests/test_tiered_kv.py``).

Hybrid (attention+SSM) archs page their K/V but their recurrent states
absorb the whole prompt in one pass, so they keep the blocking
prefill+graft admission (no prefix sharing / chunking); dense/moe take the
incremental path.  Window archs reclaim blocks that slide out of the window
mid-decode (shared blocks just drop a reference).  ``quantize_kv="int8"``
(or ``True``) stores paged pools int8, ``"fp8"`` stores e4m3 — both with
per-(token, head) scales (``serving.kvquant``).

**Speculative decoding** (``spec_decode="ngram"|"draft"``, dense/moe paged
only): each step drafts up to ``spec_k`` candidate tokens per slot
(``serving.spec_decode`` — n-gram prompt lookup, or a reduced-depth draft
model) and scores the whole window in ONE multi-query-token verify pass
through the chunked-prefill machinery (``models.verify_step``).
``sampler.spec_accept`` keeps the longest prefix the target distribution
agrees with plus a correction/bonus token — exactly target-distributed,
greedy-mode token-identical to plain decode — so a slot advances by 1 to
``spec_k + 1`` tokens per step while paying one cache sweep.  Rejected
tail writes are rolled back (rows zeroed, position reset); admission
reserves ``spec_k`` positions of headroom per request so speculative writes
always land inside the request's own blocks.

Per-step sampling is one jitted whole-batch dispatch
(``sampler.sample_tokens``) with per-slot temperature/top-k carried as data.
The allocator's free list is auto-defragmented when ``fragmentation()``
exceeds ``defrag_threshold`` after frees (``defrag_triggers`` in stats).

**Fused one-dispatch step** (``fused=True``, chunked families only): the
scheduler emits a typed ``StepPlan`` instead of walking phases, and each
tick lowers to ONE jitted dispatch over a unified (rows, width) batch —
decode rows, prefill chunks and spec-verify windows together through
``models.unified_step``, with sampling (``sampler.fused_sample_accept``)
and the speculative rollback (``kvcache.truncate_block_rows``) folded into
the same graph.  The host sees one sync of (new_tokens, accept_counts,
cut, done_flags) per step; ``stats()`` reports ``dispatches_per_step`` /
``host_syncs_per_step``.  Greedy outputs are token-identical to the legacy
walk (``tests/test_fused_step.py``).

Scheduling (``serving.scheduler.SchedulerCore``): queue ordering, admission,
chunked-prefill budgeting, spec-decode windows and SLO-aware **preemption**
live in an extracted scheduler core that drives this engine through a narrow
ops surface (``try_admit`` / ``run_chunk`` / ``finish_prefill`` /
``preempt`` / ...).  The default ``policy="slo"`` orders by (priority desc,
online first, earliest deadline, FCFS) — with default knobs exactly the
paper §IV.F online-ahead-of-offline-backfill order — and under pool/slot
pressure evicts a strictly-lower-priority running request (its blocks are
registered into the prefix index and parked in the LRU pool, so the resumed
request recovers its committed context as a prefix hit instead of
recomputing it).  ``policy="fcfs"`` ignores SLO knobs and never preempts.

**Tensor parallelism** (``mesh=``, ``parallel=``): one engine instance can
span the devices of a ``(data=1, model=tp)`` mesh (the paper's 4-way
Grace-Hopper node).  Params shard with the standard
``ShardingRules.param_shardings`` rule table (heads / FFN hidden / experts /
vocab over "model"); the paged K/V pools partition along the **kv-head**
axis (``ShardingRules.paged_cache_shardings``) so each device holds its head
slice of EVERY physical block — block ids are device-invariant, which keeps
the ``BlockAllocator``, ``PrefixIndex``, block tables and the scheduler
plain replicated host-side logic.  Decode / chunked-prefill / verify run as
one SPMD program with explicit ``NamedSharding`` out-specs (Pallas paged
kernels execute per-shard under ``shard_map`` on their local head slice;
head counts that don't divide the mesh fall back to the XLA reference
path), and the sampler/spec-accept dispatches consume the vocab-sharded
logits directly.  TP=n greedy decode is token-identical to TP=1 (asserted
in ``tests/test_sharded_serving.py``).

**Observability** (``serving.metrics`` + ``serving.trace``, see
docs/observability.md): every timestamp routes through one injectable
``clock``; latencies (queue wait, TTFT, TPOT, step, prefill chunk) land in
fixed-bucket histograms and throughputs in counters on ``self.metrics``;
request-lifecycle events (submit/admit/chunk/first-token/spec/finish/evict)
record into ``self.tracer``'s bounded ring buffer, exportable as
Chrome-trace JSON with one track per slot plus a scheduler track.
``profile=True`` opts into ``block_until_ready``-bracketed per-phase
dispatch timing (off by default: the hot path takes no extra host syncs),
and an ``EnergyBridge`` charges each step's chip-seconds into the seed
``core.telemetry.EnergyLedger``, attributed per request as joules/token.
"""

from __future__ import annotations

import itertools
import time
import warnings
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    decode_step,
    init_paged_cache,
    init_params,
    prefill,
    prefill_step,
    supports_chunked_prefill,
    supports_paged,
    unified_step,
    verify_step,
)
from repro.serving.kvcache import (
    clear_block_row,
    clear_slot,
    copy_block_rows,
    decode_cache_from_prefill,
    gather_block_rows,
    graft_prefill_into_blocks,
    make_engine_cache,
    make_table_row,
    restore_block_rows,
    truncate_block_rows,
    write_request_into_slot,
)
from repro.serving.metrics import EnergyBridge, MetricsRegistry
from repro.serving.paged import BlockAllocator, blocks_needed, truncate_blocks
from repro.serving.prefix import PrefixIndex, is_spilled
from repro.serving.spill import SPILL_MODES, SpillPool, warn_if_fp8_over_int8
from repro.serving.sampler import (
    fused_sample_accept,
    sample_token,
    sample_tokens,
    spec_accept,
)
from repro.serving.scheduler import (  # re-exported for back-compat
    Request,
    RequestState,
    SchedulerCore,
    binary_chunks,
)
from repro.serving.spec_decode import DraftModel, make_draft_config, ngram_draft
from repro.serving.trace import SCHEDULER_TRACK, Tracer, slot_track

# patchable seam for the opt-in profiler: tests monkeypatch this to assert
# the default path never introduces a host sync (profile=False must not
# call it at all)
_block_until_ready = jax.block_until_ready

# families whose prefill is exact under right-padding (causal attention:
# pad positions can never influence earlier K/V or the last-real-token
# logits).  ssm/hybrid recurrent states WOULD absorb pad tokens, so those
# families prefill at exact prompt length (one trace per length).
BUCKETED_FAMILIES = ("dense", "moe", "vlm")
MIN_PREFILL_BUCKET = 8


@dataclass
class _RestoreTask:
    """One pending spill swap-in: ``payload`` rows destined for device block
    ``dst``.  ``cow`` marks a partial-tail restore whose canonical entry
    stays in the pool (cancel must not demote the private copy back)."""

    dst: int
    payload: dict
    cow: bool
    t0: float


class InferenceEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        max_batch: int = 4,
        max_seq: int = 512,
        eos_token: int = 1,
        seed: int = 0,
        cache_kind: str = "paged",
        block_size: int = 32,
        num_blocks: Optional[int] = None,
        cache_dtype=jnp.bfloat16,
        quantize_kv: bool | str = False,
        attn_impl: str = "xla",
        fused: bool = False,
        prefix_cache: Optional[bool] = None,
        prefill_budget: int = 0,
        policy: str = "slo",
        defrag_threshold: float = 0.5,
        spill_bytes: int = 0,
        spill_dtype: str = "cache",
        restore_budget: int = 4,
        spec_decode: str = "off",
        spec_k: int = 4,
        draft_cfg=None,
        draft_params=None,
        mesh=None,
        parallel=None,
        clock=None,
        metrics: Optional[MetricsRegistry] = None,
        tracer: Optional[Tracer] = None,
        trace_capacity: int = 4096,
        profile: bool = False,
        energy: Optional[EnergyBridge] = None,
    ):
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only; no decode serving")
        if cache_kind not in ("paged", "dense"):
            raise ValueError(f"cache_kind={cache_kind!r}")
        if cache_kind == "paged" and not supports_paged(cfg):
            # ssm states are O(1) per slot (nothing to page); vlm keeps the
            # grouped dense layout
            cache_kind = "dense"
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos = eos_token
        self.cache_kind = cache_kind
        self.cache_dtype = cache_dtype
        from repro.serving.kvquant import normalize_kv_quant

        quantize_kv = normalize_kv_quant(quantize_kv)  # "int8" | "fp8" | None
        if quantize_kv and cache_kind != "paged":
            warnings.warn(
                f"quantize_kv only applies to paged block pools; ignored for "
                f"cache_kind={cache_kind!r} ({cfg.name})",
                RuntimeWarning,
                stacklevel=2,
            )
        self.quantize_kv = quantize_kv if cache_kind == "paged" else None
        if self.quantize_kv and attn_impl == "pallas":
            warnings.warn(
                f"{self.quantize_kv} block pools have no Pallas kernel yet; decode "
                "runs the dequantizing jnp reference path despite attn_impl='pallas'",
                RuntimeWarning,
                stacklevel=2,
            )
        self.attn_impl = attn_impl

        # ---- observability: one injectable clock feeds every timestamp
        # (request lifecycle, tracer, profiler), one registry collects every
        # counter/gauge/histogram, one bounded ring buffer records the
        # request-lifecycle events.  All host-side scalar work — the default
        # path adds no device syncs (profile=True opts into
        # block_until_ready-bracketed per-phase timing).
        self._clock = clock if clock is not None else time.monotonic
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(self._clock, trace_capacity)
        self._profile = profile
        self._phase_acc: dict[str, float] = {}
        M = self.metrics
        self._c_submitted = M.counter("engine_requests_submitted_total", "requests accepted by submit()")
        self._c_admitted = M.counter("engine_requests_admitted_total", "requests admitted into a batch slot")
        self._c_finished = M.counter("engine_requests_finished_total", "requests finished (EOS or max_new_tokens)")
        self._c_tokens = M.counter("engine_tokens_out_total", "generated tokens emitted")
        self._c_prefill_tokens = M.counter("engine_prefill_tokens_total", "prompt tokens computed (prefix hits excluded)")
        self._c_prefix_hit = M.counter("engine_prefix_hit_tokens_total", "prompt tokens served from the prefix cache")
        self._c_drafted = M.counter("engine_spec_drafted_total", "speculative candidate tokens proposed")
        self._c_accepted = M.counter("engine_spec_accepted_total", "speculative candidate tokens committed")
        self._c_energy = M.counter("engine_energy_joules_total", "IT-side joules charged to serving steps")
        self._c_preempted = M.counter("engine_preemptions_total", "scheduler evictions of running requests")
        self._c_deadline_miss = M.counter("engine_deadline_violations_total", "finished requests whose TTFT missed deadline_s")
        self._c_aborted = M.counter("engine_requests_aborted_total", "requests aborted (client cancel, deadline, migration)")
        self._c_spill_hit = M.counter("engine_spill_hit_tokens_total", "prompt tokens served from the host spill tier")
        self._c_restored = M.counter("engine_restores_total", "spilled blocks swapped back into device blocks")
        self._c_restore_cancel = M.counter("engine_restores_cancelled_total", "queued swap-ins cancelled by preempt/abort")
        self._h_queue_wait = M.histogram("engine_queue_wait_seconds", "submit to admission")
        self._h_restore_wait = M.histogram("engine_restore_wait_seconds", "swap-in queued to rows scattered on device")
        self._h_ttft = M.histogram("engine_ttft_seconds", "submit to first generated token")
        self._h_admit_first = M.histogram("engine_admit_to_first_token_seconds", "admission to first generated token")
        self._h_tpot = M.histogram("engine_tpot_seconds", "mean inter-token time per finished request")
        self._h_step = M.histogram("engine_step_seconds", "wall time of one engine step()")
        self._h_prefill_chunk = M.histogram("engine_prefill_chunk_seconds", "one chunked-prefill dispatch")
        self._g_queue = M.gauge("engine_queue_depth", "requests waiting for admission")
        self._g_active = M.gauge("engine_active_slots", "slots decoding")
        self._g_prefilling = M.gauge("engine_prefilling_slots", "slots mid chunked prefill")

        # ---- tensor parallelism: shard params over the mesh's model axis;
        # cache shardings are attached after the cache is built below.  The
        # rule tables come from parallel/sharding.py — serving defaults to
        # TP-only (no FSDP: decode wants weights stationary and replicated
        # over the size-1 data axis).
        self.mesh = mesh
        self._rules = None
        self._cache_shardings = None
        if mesh is not None:
            from repro.config import MeshConfig, ParallelConfig
            from repro.parallel import make_rules

            missing = {"data", "model"} - set(mesh.axis_names)
            if missing:
                raise ValueError(
                    f"serving mesh needs ('data', 'model') axes "
                    f"(launch.mesh.make_serving_mesh); got {mesh.axis_names}"
                )
            self._rules = make_rules(
                MeshConfig(), parallel or ParallelConfig(fsdp=False, tensor_parallel=True)
            )
            self.params = params = jax.device_put(
                params, self._rules.param_shardings(cfg, mesh, params)
            )
            from repro.kernels.paged_attention_ops import kernel_shardable, model_axis_size

            if (
                attn_impl == "pallas"
                and model_axis_size(mesh) > 1
                and not kernel_shardable(mesh, cfg.num_heads, cfg.num_kv_heads)
            ):
                warnings.warn(
                    f"{cfg.name}: head counts ({cfg.num_heads}/{cfg.num_kv_heads}) "
                    f"don't divide the model axis ({model_axis_size(mesh)}); Pallas "
                    f"paged kernels can't take a local head slice, decode runs the "
                    f"XLA reference path",
                    RuntimeWarning,
                    stacklevel=2,
                )

        # DCIM bridge (paper §IV.A): each step charges chip-seconds at an
        # occupancy-derived utilization into the seed EnergyLedger; the
        # engine then attributes the joules to the requests that did work
        self.energy = (
            energy if energy is not None else EnergyBridge(chips=mesh.size if mesh is not None else 1)
        )

        # chunked prefill (and with it prefix caching) needs a paged cache
        # and a family whose chunk state is fully captured by written K/V
        self._chunked = cache_kind == "paged" and supports_chunked_prefill(cfg)
        if prefix_cache and not self._chunked:
            warnings.warn(
                f"prefix_cache needs a paged cache and a chunk-resumable "
                f"family (dense/moe); disabled for {cfg.name} "
                f"({cache_kind}/{cfg.family})",
                RuntimeWarning,
                stacklevel=2,
            )
        if prefill_budget > 0 and not self._chunked:
            warnings.warn(
                f"prefill_budget requires chunked prefill (paged cache + "
                f"dense/moe family); {cfg.name} ({cache_kind}/{cfg.family}) "
                f"keeps the blocking admission prefill",
                RuntimeWarning,
                stacklevel=2,
            )
        # fused one-dispatch step: the scheduler emits a StepPlan of typed
        # rows and the engine lowers the whole tick (decode + prefill chunks
        # + spec verify + sampling/accept + rollback) into one jitted call.
        # It rides the chunked machinery, so it has the same family gate.
        if fused and not self._chunked:
            raise ValueError(
                f"fused=True needs a paged cache and a chunk-resumable family "
                f"(dense/moe); got {cfg.name} ({cache_kind}/{cfg.family})"
            )
        self.fused = bool(fused)
        # scheduling brain: queue ordering (SLO/FCFS), admission, preemption
        # decisions and the chunked-prefill budget live in the extracted
        # SchedulerCore; the engine provides the execution primitives
        # (try_admit / run_chunk / finish_prefill / preempt / ...) below
        if restore_budget < 1:
            raise ValueError(f"restore_budget={restore_budget} (need >= 1)")
        self.scheduler = SchedulerCore(
            self, policy=policy, prefill_budget=prefill_budget, restore_budget=restore_budget
        )
        self.defrag_threshold = defrag_threshold

        # speculative decoding rides on the chunked verify path: the k drafted
        # tokens are scored in one multi-query-token pass through the paged
        # prefill-attention machinery, so it needs a paged cache + a
        # chunk-resumable family (recurrent states can't be rolled back)
        if spec_decode not in ("off", "ngram", "draft"):
            raise ValueError(f"spec_decode={spec_decode!r}")
        if spec_decode != "off" and not self._chunked:
            warnings.warn(
                f"spec_decode needs a paged cache and a chunk-resumable "
                f"family (dense/moe); disabled for {cfg.name} "
                f"({cache_kind}/{cfg.family})",
                RuntimeWarning,
                stacklevel=2,
            )
            spec_decode = "off"
        if spec_k < 1:
            raise ValueError(f"spec_k={spec_k} (need >= 1)")
        self.spec_mode = spec_decode
        self.spec_k = spec_k
        # a verify pass writes up to spec_k positions past the committed
        # sequence; admission reserves that headroom so speculative writes
        # always land in the request's own blocks, never past its table row
        self._spec_extra = spec_k if spec_decode != "off" else 0
        self._draft: Optional[DraftModel] = None
        if self.spec_mode == "draft":
            dcfg = draft_cfg if draft_cfg is not None else make_draft_config(cfg)
            if dcfg.padded_vocab != cfg.padded_vocab:
                raise ValueError(
                    f"draft model vocab {dcfg.padded_vocab} != target {cfg.padded_vocab}"
                )
            if draft_params is None:
                draft_params = init_params(dcfg, jax.random.PRNGKey(seed + 1), jnp.float32)
            self._draft = DraftModel(
                dcfg,
                draft_params,
                max_batch=max_batch,
                max_seq=max_seq,
                seed=seed,
                metrics=self.metrics,
            )

        if cache_kind == "paged":
            self.block_size = block_size
            self.max_blocks_per_seq = -(-max_seq // block_size)
            if num_blocks is None:
                # default: same position capacity as the dense layout (+ null)
                num_blocks = max_batch * self.max_blocks_per_seq + 1
            self.num_blocks = num_blocks
            self.allocator = BlockAllocator(num_blocks)
            self.prefix = (
                PrefixIndex(self.allocator, block_size)
                if (self._chunked if prefix_cache is None else prefix_cache and self._chunked)
                else None
            )
            # allocator publishes pool occupancy into the shared registry;
            # the engine wraps the eviction callback (the prefix index set
            # its unmap hook in __post_init__) so LRU reclaims surface as
            # trace events too
            self.allocator.attach_metrics(self.metrics)
            if self.prefix is not None:
                self.prefix.attach_metrics(self.metrics)
            self._g_frag = self.metrics.gauge(
                "pool_fragmentation", "allocator free-list fragmentation"
            )
            inner_evict = self.allocator.on_evict
            def _evict_hook(block, _inner=inner_evict):
                # propagate the tier tag: the prefix index returns "spilled"
                # when the block's content was demoted to the host pool, and
                # the allocator accounts the two outcomes separately
                tier = _inner(block) if _inner is not None else None
                self.tracer.instant(
                    "spill" if tier == "spilled" else "evict",
                    track=SCHEDULER_TRACK,
                    block=block,
                )
                return tier
            self.allocator.on_evict = _evict_hook
            # host spill tier: evicted prefix blocks park in host RAM and
            # swap back in on a later hit instead of re-prefilling
            if spill_dtype not in SPILL_MODES:
                raise ValueError(f"spill_dtype={spill_dtype!r} (choose from {SPILL_MODES})")
            self.spill = None
            if spill_bytes > 0:
                if self.prefix is None:
                    warnings.warn(
                        f"spill_bytes needs the prefix cache (paged cache + "
                        f"dense/moe family); disabled for {cfg.name}",
                        RuntimeWarning,
                        stacklevel=2,
                    )
                else:
                    spill_dtype = warn_if_fp8_over_int8(self.quantize_kv, spill_dtype)
                    self.spill = SpillPool(spill_bytes, mode=spill_dtype)
                    self.prefix.attach_spill(self.spill, self._fetch_block_rows)
                    self.spill.attach_metrics(self.metrics)
            self.tbl = np.zeros((max_batch, self.max_blocks_per_seq), np.int32)
            self._tbl_dirty = True
            self.cache = init_paged_cache(
                cfg,
                num_blocks,
                block_size,
                max_batch,
                self.max_blocks_per_seq,
                cache_dtype,
                quantized=self.quantize_kv,
            )
        else:
            self.allocator = None
            self.prefix = None
            self.spill = None
            if spill_bytes > 0:
                warnings.warn(
                    f"spill_bytes only applies to paged caches; ignored for "
                    f"cache_kind={cache_kind!r} ({cfg.name})",
                    RuntimeWarning,
                    stacklevel=2,
                )
            self.cache = make_engine_cache(cfg, max_batch, max_seq, cache_dtype)

        if mesh is not None:
            # pools: head-sharded; tables / recurrent states: replicated.
            # Placing the cache up front (instead of letting the first jit
            # decide) pins every later dispatch to the same layout.
            if cache_kind == "paged":
                self._cache_shardings = self._rules.paged_cache_shardings(cfg, mesh, self.cache)
            else:
                self._cache_shardings = self._rules.cache_shardings(cfg, mesh, self.cache)
            self.cache = jax.device_put(self.cache, self._cache_shardings)

        self.pos = np.full((max_batch,), 0, np.int32)  # next position per slot
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.done: list[Request] = []
        self._preempted_ids: set[int] = set()  # distinct requests ever evicted
        self.deadline_violations = 0  # finished with ttft > deadline_s
        self.aborts = 0  # requests aborted (cancel / deadline / migration)
        # streaming hooks (serving.async_engine): called synchronously on the
        # stepping thread — on_token(req, new_tokens) per emission batch,
        # on_finish(req) when a request completes
        self.on_token = None
        self.on_finish = None
        self._ids = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        # explicit NamedSharding out-specs under a mesh: the cache tree keeps
        # its pinned layout across every dispatch (head-sharded pools,
        # replicated tables) and logits come back vocab-sharded, which the
        # jitted sampler / spec-accept consume without a gather
        if mesh is not None:
            logits2 = self._rules.logits_sharding(cfg, mesh, 2)
            logits3 = self._rules.logits_sharding(cfg, mesh, 3)
            lc_out = dict(out_shardings=(logits2, self._cache_shardings))
            lc3_out = dict(out_shardings=(logits3, self._cache_shardings))
            c_out = dict(out_shardings=self._cache_shardings)
        else:
            lc_out = lc3_out = c_out = {}
        self._decode = jax.jit(
            lambda p, c, t, q: decode_step(cfg, p, c, t, q, attn_impl=attn_impl, mesh=mesh),
            **lc_out,
        )
        self._prefill = jax.jit(lambda p, b: prefill(cfg, p, b))
        # donate the pool so admission/chunk updates touch only the request's
        # blocks in place instead of copying the whole pool per call (donation
        # is honored on TPU; CPU falls back to a copy)
        self._graft = jax.jit(
            lambda c, raw, blocks, n, slot: graft_prefill_into_blocks(cfg, c, raw, blocks, n, slot),
            donate_argnums=(0,),
            **(c_out if cache_kind == "paged" else {}),
        )
        if self._chunked:
            self._chunk_step = jax.jit(
                lambda p, c, t, s, row: prefill_step(
                    cfg, p, c, t, s, row, attn_impl=attn_impl, mesh=mesh
                ),
                donate_argnums=(1,),
                **lc_out,
            )
            self._copy_block = jax.jit(copy_block_rows, donate_argnums=(0,), **c_out)
            # spill tier data movement: the gather is dispatched at evict
            # time (the immutable result pins the rows while the pool block
            # is reused); the scatter batches every task of one restore pass
            self._gather_rows = jax.jit(gather_block_rows)
            self._restore_rows = jax.jit(restore_block_rows, donate_argnums=(0,), **c_out)
        if self.spec_mode != "off":
            self._verify = jax.jit(
                lambda p, c, t, s, row: verify_step(
                    cfg, p, c, t, s, row, attn_impl=attn_impl, mesh=mesh
                ),
                donate_argnums=(1,),
                **lc3_out,
            )
            self._trunc_rows = jax.jit(
                lambda c, tbl, s, e: truncate_block_rows(c, tbl, s, e, span=spec_k + 1),
                donate_argnums=(0,),
                **c_out,
            )
        if self.fused:
            # one-dispatch step graphs.  The host sees only the per-row
            # (new_tokens, accept_counts, cut, done_flags) once per tick;
            # sampling, speculative accept and the rejected-tail rollback all
            # live inside the compiled graph.  Shapes (R, W) vary per tick
            # but are drawn from bounded bucketed sets, so jax.jit's shape
            # cache holds one compiled program per (row-bucket, width).
            eos = self.eos

            def _fused_decode_fn(p, c, tokens, pos, temps, top_ks, room, key):
                # pure-decode ticks keep decode_step's exact graph (bit-
                # identical logits to the unfused engine), sampling folded in
                logits, c = decode_step(cfg, p, c, tokens, pos, attn_impl=attn_impl, mesh=mesh)
                toks = sample_tokens(logits, temps, top_ks, key)
                done = (toks == eos) | (room <= 1)
                return toks, done, c

            def _make_fused_mixed(spec: bool):
                def fn(p, c, tokens, start, widths, tbl, drafts, valid, temps,
                       top_ks, sample_lane, room, roll_end, key, qprobs):
                    logits, c = unified_step(
                        cfg, p, c, tokens, start, widths, tbl, attn_impl=attn_impl, mesh=mesh
                    )
                    n_acc, final = fused_sample_accept(
                        logits, drafts, qprobs, valid, temps, top_ks, sample_lane, key
                    )
                    # committed emission length: first EOS inside the window,
                    # clamped by the remaining generation budget (``room``)
                    W = tokens.shape[1]
                    lanes = jnp.arange(W, dtype=jnp.int32)
                    emitted = jnp.where(
                        lanes[None, :] == n_acc[:, None],
                        final[:, None],
                        jnp.pad(drafts, ((0, 0), (0, 1))),
                    )
                    is_eos = (lanes[None, :] <= n_acc[:, None]) & (emitted == eos)
                    eos_cut = jnp.where(
                        is_eos.any(axis=1),
                        jnp.argmax(is_eos, axis=1).astype(jnp.int32) + 1,
                        jnp.int32(W + 1),
                    )
                    cut = jnp.minimum(jnp.minimum(eos_cut, room), n_acc + 1).astype(jnp.int32)
                    done = (eos_cut <= cut) | (cut >= room)
                    if spec:
                        # in-graph rollback: zero verify rows' rejected tail
                        # lanes [start+cut, roll_end) — roll_end <= start+cut
                        # makes a row a no-op (decode/chunk rows)
                        c = truncate_block_rows(c, tbl, start + cut, roll_end, span=W)
                    return final, n_acc, cut, done, c

                return fn

            if mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec

                repl = NamedSharding(mesh, PartitionSpec())
                fd_out = dict(out_shardings=(repl, repl, self._cache_shardings))
                fm_out = dict(out_shardings=(repl, repl, repl, repl, self._cache_shardings))
            else:
                fd_out = fm_out = {}
            self._fused_decode = jax.jit(_fused_decode_fn, donate_argnums=(1,), **fd_out)
            self._fused_plain = jax.jit(_make_fused_mixed(False), donate_argnums=(1,), **fm_out)
            self._fused_spec = jax.jit(_make_fused_mixed(True), donate_argnums=(1,), **fm_out)
        self._bucketed = cfg.family in BUCKETED_FAMILIES
        self.steps = 0
        self.tokens_out = 0
        self.peak_active = 0
        self.prefill_chunks = 0
        self.prefill_tokens = 0  # prompt tokens actually run through the model
        # verify-window tokens are counted SEPARATELY: the speculative verify
        # pass rides the chunked-prefill machinery but its fed tokens are
        # decode work, not prompt work — folding them into prefill_tokens
        # would deflate prefix_hit_rate whenever spec_decode is on
        self.verify_tokens = 0
        self.prefix_hits = 0
        self.prefix_partial_hits = 0
        self.prefix_hit_tokens = 0  # prompt tokens served from cached blocks
        self.defrag_triggers = 0
        self._frees_seen = 0  # auto-defrag: only re-check after new frees
        self.spill_hits = 0  # admissions that matched >= 1 spilled block
        self.spill_hit_tokens = 0  # prompt tokens served from the host tier
        self.restores = 0  # spilled blocks swapped back onto the device
        self.restores_cancelled = 0  # queued swap-ins cancelled (preempt/abort)
        self._restore_q: list[_RestoreTask] = []  # FIFO, drained per step
        self._restoring: set[int] = set()  # dst blocks with a queued task
        self.spec_steps = 0  # verify dispatches
        self.spec_slot_steps = 0  # per-slot verify passes (spec stats denominator)
        self.spec_drafted = 0  # candidate tokens proposed (valid lanes only)
        self.spec_accepted = 0  # drafted tokens committed
        self.spec_emitted = 0  # tokens emitted via the speculative path
        # dispatch/sync accounting (the fused step's raison d'être): every
        # jitted call through the _dispatch seam and every device->host sync
        # (_host_fetch / profiled block_until_ready) increments these, so
        # stats() can report dispatches/syncs per step for A/B comparison
        self.dispatches_total = 0
        self.host_syncs_total = 0
        self._g_dispatches = M.gauge(
            "engine_dispatches_per_step", "jitted dispatches per engine step"
        )
        self._g_host_syncs = M.gauge(
            "engine_host_syncs_per_step", "device->host syncs per engine step"
        )

    # ------------------------------------------------------------------
    @property
    def queue(self) -> list[Request]:
        """Waiting requests in policy order — owned by the scheduler core."""
        return self.scheduler.queue

    @property
    def _prefilling(self) -> list[Request]:
        return self.scheduler.prefilling

    @property
    def prefill_budget(self) -> int:
        return self.scheduler.prefill_budget

    @property
    def has_work(self) -> bool:
        """True while any request is waiting, prefilling or decoding."""
        return bool(self.scheduler.queue) or any(s is not None for s in self.slots)

    def submit(
        self,
        prompt: list[int],
        *,
        max_new_tokens: int = 32,
        online: bool = True,
        temperature: float = 0.0,
        top_k: int = 0,
        priority: int = 0,
        deadline_s: Optional[float] = None,
    ) -> Request:
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens={max_new_tokens} (need >= 1)")
        if priority < 0:
            raise ValueError(f"priority={priority} (need >= 0)")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s={deadline_s} (need > 0, or None)")
        total = len(prompt) + max_new_tokens
        if self.cache_kind == "paged":
            span = total + self._spec_extra  # worst case + speculative headroom
            if span > self.max_seq:
                headroom = f" (+{self._spec_extra} spec_k headroom)" if self._spec_extra else ""
                raise ValueError(
                    f"prompt+max_new_tokens={total}{headroom} exceeds max_seq={self.max_seq}"
                )
            if blocks_needed(span, self.block_size) > self.allocator.capacity:
                raise ValueError(
                    f"request needs {blocks_needed(span, self.block_size)} blocks, "
                    f"pool capacity is {self.allocator.capacity}"
                )
        elif self.cfg.has_attention and self.cfg.sliding_window == 0 and total > self.max_seq:
            # full-attention dense cache: positions past max_seq would wrap the
            # ring buffer and silently corrupt the oldest entries
            raise ValueError(f"prompt+max_new_tokens={total} exceeds max_seq={self.max_seq}")
        req = Request(
            req_id=next(self._ids),
            prompt=list(prompt),
            max_new_tokens=max_new_tokens,
            online=online,
            priority=priority,
            deadline_s=deadline_s,
            temperature=temperature,
            top_k=top_k,
            submit_t=self._clock(),
        )
        self.scheduler.enqueue(req)
        self._c_submitted.inc()
        self._g_queue.set(len(self.queue))
        self.tracer.instant(
            "submit",
            track=SCHEDULER_TRACK,
            req_id=req.req_id,
            prompt_len=len(req.prompt),
            online=online,
            priority=priority,
        )
        return req

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    # ---- scheduler ops surface (see SchedulerCore's table) -----------
    def free_slots(self) -> list[int]:
        return self._free_slots()

    def running(self) -> list[Request]:
        """Requests holding a slot (decoding or mid-prefill)."""
        return [r for r in self.slots if r is not None]

    def chunked(self) -> bool:
        return self._chunked

    def can_preempt(self) -> bool:
        # eviction+resume rides the chunk-resumable paged path: the resumed
        # context re-prefills in chunks (recurrent states can't)
        return self._chunked

    def try_admit(self, req: Request, slot: int) -> bool:
        admit = self._admit_chunked if self._chunked else self._admit_blocking
        return admit(req, slot)

    def preempt(self, req: Request) -> None:
        """Evict a running request: park its committed K/V in the prefix
        cache (LRU pool), free everything else, clear its slot and mark it
        WAITING so the scheduler can requeue it.

        The cache holds K/V for positions ``[0, written)`` — for a decoding
        request ``written = len(ctx) - 1`` (the trailing generated token is
        not yet fed), for a mid-prefill one ``written = prefill_pos``.  Full
        blocks of that span are registered into the prefix index before
        release, so re-admission recovers them as a prefix hit; the partial
        tail block and unused reserve free eagerly and are recomputed on
        resume.
        """
        slot = req.slot
        # cancel in-flight spill swap-ins FIRST: cancelled entries demote
        # back to the pool (re-keyed off the device blocks), so the
        # register call below skips their chain positions and the release
        # plain-frees the never-written destination blocks
        self._cancel_restores(req)
        written = int(req.prefill_pos if req.prefilling else self.pos[slot])
        if self.prefix is not None and req.freed_blocks == 0:
            # index the committed context (prompt + generated) up to the
            # written position — sliding-window requests skip this: their
            # leading blocks are gone, the chain can't start at the root
            req.reg_block, req.reg_parent = self.prefix.register(
                req.context(),
                req.blocks,
                written,
                start_block=req.reg_block,
                parent=req.reg_parent,
            )
        kept, tail = truncate_blocks(req.blocks, written, self.block_size)
        if tail:
            self.allocator.free(tail)
        self._release_blocks(kept[req.freed_blocks :])
        req.blocks = []
        req.freed_blocks = 0
        req.prefill_pos = 0
        req.prefilling = False
        req.reg_block = 0
        req.reg_parent = 0
        req.state = RequestState.WAITING
        req.slot = None
        req.preemptions += 1
        self._preempted_ids.add(req.req_id)
        self._c_preempted.inc()
        self.slots[slot] = None
        self.pos[slot] = 0
        self.tbl[slot] = 0  # null block
        self._tbl_dirty = True
        self.cache = clear_block_row(self.cfg, self.cache, slot)
        if self._draft is not None:
            self._draft.reset(slot)
        self.tracer.instant(
            "preempt",
            track=slot_track(slot),
            req_id=req.req_id,
            committed_tokens=written,
            generated=len(req.generated),
            priority=req.priority,
        )

    # ------------------------------------------------------------------
    def find_request(self, req_id: int) -> Optional[Request]:
        """A live (waiting or active) request by id, or None."""
        for r in self.queue:
            if r.req_id == req_id:
                return r
        for r in self.slots:
            if r is not None and r.req_id == req_id:
                return r
        return None

    def abort(self, req, reason: str = "aborted") -> bool:
        """Abort a queued, prefilling or decoding request.

        Every resource the request holds is released: its slot and draft
        state clear, its tail blocks free eagerly, and the committed span
        routes through the prefix index (indexed blocks park in the LRU
        cached pool, still matchable — an aborted request's prefix work is
        not thrown away).  The request finishes with
        ``finish_reason=reason`` and ``on_finish`` fires so streams
        unblock.  Accepts a ``Request`` or a request id; returns False when
        the request is unknown or already finished (abort/finish races are
        benign).
        """
        if isinstance(req, int):
            req = self.find_request(req)
        if req is None or req.state == RequestState.DONE:
            return False
        slot = req.slot
        if req.state == RequestState.WAITING:
            if not self.scheduler.dequeue(req):
                return False
        else:  # ACTIVE: mid-prefill or decoding, holds a slot
            self.scheduler.drop_prefilling(req)
            if self.cache_kind == "paged":
                self._cancel_restores(req)
                written = int(req.prefill_pos if req.prefilling else self.pos[slot])
                kept, tail = truncate_blocks(req.blocks, written, self.block_size)
                if tail:
                    self.allocator.free(tail)
                self._release_blocks(kept[req.freed_blocks :])
                req.blocks = []
                req.freed_blocks = 0
                self.tbl[slot] = 0  # null block
                self._tbl_dirty = True
                self.cache = clear_block_row(self.cfg, self.cache, slot)
            else:
                self.cache = clear_slot(self.cfg, self.cache, slot)
            self.pos[slot] = 0
            self.slots[slot] = None
            req.prefilling = False
            req.slot = None
            if self._draft is not None:
                self._draft.reset(slot)
        req.state = RequestState.DONE
        req.finish_reason = reason
        req.done_t = self._clock()
        self.aborts += 1
        self._c_aborted.inc()
        if reason == "deadline_exceeded":
            self.deadline_violations += 1
            self._c_deadline_miss.inc()
        self.tracer.instant(
            "abort",
            track=SCHEDULER_TRACK if slot is None else slot_track(slot),
            req_id=req.req_id,
            reason=reason,
            generated=len(req.generated),
        )
        self._g_queue.set(len(self.queue))
        self.done.append(req)
        if self.on_finish is not None:
            self.on_finish(req)
        return True

    def _enforce_deadlines(self) -> None:
        """Abort requests whose TTFT deadline passed with no first token.

        ``deadline_s`` is a time-to-first-token SLO: a request that missed
        it is worthless to its (interactive) caller, so burning pool blocks
        and batch slots to finish it anyway only delays everyone else.
        Runs at the top of every ``step()``; requests that got their first
        token in time run to completion (a post-first-token overrun still
        counts into ``deadline_violations`` at finish, but never aborts).
        """
        now = self._clock()
        at_risk = [
            r
            for r in list(self.queue) + [s for s in self.slots if s is not None]
            if r.first_token_t is None and now > r.deadline_t
        ]
        for r in at_risk:
            self.abort(r, reason="deadline_exceeded")

    # ------------------------------------------------------------------
    def _bucket_len(self, n: int) -> int:
        """Power-of-two prefill length bucket (bounded trace count)."""
        if not self._bucketed:
            return n
        p = MIN_PREFILL_BUCKET
        while p < n:
            p *= 2
        return min(p, self.max_seq)

    def _run_prefill(self, req: Request):
        n = len(req.prompt)
        P = self._bucket_len(n)
        toks = req.prompt + [0] * (P - n)
        batch = {
            "tokens": jnp.asarray(toks, jnp.int32)[None, :],
            "last_index": jnp.asarray([n - 1], jnp.int32),
        }
        if self.cfg.family == "vlm":
            batch["vision_tokens"] = jnp.zeros(
                (1, self.cfg.vision.num_image_tokens, self.cfg.d_model), jnp.float32
            )
        return self._prefill(self.params, batch)

    # ------------------------------------------------------------------
    def _dispatch(self, phase: str, fn, *args):
        """Run one jitted dispatch, optionally profiled.

        ``profile=False`` (default) is a plain call — no timing, no
        ``block_until_ready``, zero extra host syncs on the hot path.
        ``profile=True`` brackets the dispatch with the injectable clock and
        a device sync so step latency decomposes by phase
        (``engine_profile_<phase>_seconds`` histograms, and a per-step
        breakdown in the tracer's ``step`` span args).

        Every call counts one jitted dispatch (the fused-step A/B metric);
        the profiled branch's ``block_until_ready`` additionally counts as a
        host sync."""
        self.dispatches_total += 1
        if not self._profile:
            return fn(*args)
        t0 = self._clock()
        out = fn(*args)
        _block_until_ready(out)
        self.host_syncs_total += 1
        dt = self._clock() - t0
        self.metrics.histogram(
            f"engine_profile_{phase}_seconds", f"synced {phase} dispatch time"
        ).observe(dt)
        self._phase_acc[phase] = self._phase_acc.get(phase, 0.0) + dt
        return out

    def _host_fetch(self, *arrays):
        """Bring device results to the host as ONE counted sync event (the
        arrays are fetched together at the jit-call seam; per-step stats
        report the count as ``host_syncs_per_step``)."""
        self.host_syncs_total += 1
        return tuple(np.asarray(a) for a in arrays)

    def _note_admit(self, req: Request, slot: int) -> None:
        req.admit_t = self._clock()
        self._c_admitted.inc()
        self._h_queue_wait.observe(req.admit_t - req.submit_t)
        self.tracer.instant(
            "admit",
            track=slot_track(slot),
            req_id=req.req_id,
            prompt_len=len(req.prompt),
            prefix_hit_tokens=req.prefix_hit_tokens,
            blocks=len(req.blocks),
        )
        if req.preemptions:
            # re-admission of a previously evicted request: its committed
            # context streams back in (mostly from the prefix cache) and
            # decode continues without re-emitting the first token
            self.tracer.instant(
                "resume",
                track=slot_track(slot),
                req_id=req.req_id,
                preemptions=req.preemptions,
                generated=len(req.generated),
                recovered_tokens=req.prefill_pos,
            )

    def _release_blocks(self, blocks: list[int]) -> None:
        """Drop this request's references; the prefix index parks indexed
        blocks in the LRU cached pool, everything else frees eagerly."""
        if not blocks:
            return
        if self.prefix is not None:
            self.prefix.release(blocks)
        else:
            self.allocator.free(blocks)

    # ---- spill tier: gather / swap-in machinery ----------------------
    def _fetch_block_rows(self, block: int) -> dict:
        """One block's K/V rows off the device pool (the prefix index calls
        this at evict time, before the allocator reuses the block).  The
        jitted gather returns fresh immutable arrays, so the value stays
        pinned in the ``SpillPool`` staging ring even after the pool block
        is overwritten."""
        return self._gather_rows(self.cache, jnp.asarray(block, jnp.int32))

    def _queue_restore(self, dst: int, payload: dict, *, cow: bool, req: Request) -> None:
        self._restore_q.append(_RestoreTask(dst, payload, cow, self._clock()))
        self._restoring.add(dst)
        req.pending_restores.add(dst)

    def restoring(self, req: Request) -> bool:
        """Scheduler gate: the request's block table points at rows the
        restore pass has not scattered yet — no prefill chunk (or table
        publish) may run until the swap-ins land."""
        return bool(req.pending_restores)

    def run_restores(self, budget: int) -> int:
        """Execute up to ``budget`` queued swap-ins as ONE jitted scatter
        (rows stacked along a new block axis), then unblock every admitted
        request that was waiting on them.  Called by the scheduler between
        admission and the prefill budget each step, so restores overlap
        with the decode work of other slots instead of serializing admission."""
        if budget <= 0 or not self._restore_q:
            return 0
        tasks = self._restore_q[:budget]
        del self._restore_q[: len(tasks)]
        t0 = self._clock()
        rows = {
            name: jnp.stack([jnp.asarray(t.payload[name]) for t in tasks], axis=1)
            for name in tasks[0].payload
        }
        blocks = jnp.asarray([t.dst for t in tasks], jnp.int32)
        self.cache = self._dispatch("restore", self._restore_rows, self.cache, blocks, rows)
        now = self._clock()
        done = {t.dst for t in tasks}
        self._restoring -= done
        for r in self.slots:
            if r is not None and r.pending_restores:
                r.pending_restores -= done
        for t in tasks:
            self._h_restore_wait.observe(max(now - t.t0, 0.0))
        n = len(tasks)
        self.restores += n
        self._c_restored.inc(n)
        if self.spill is not None:
            self.spill.restores += n
        self.tracer.span(
            "restore", t0, track=SCHEDULER_TRACK, blocks=n, queued=len(self._restore_q)
        )
        return n

    def _cancel_restores(self, req: Request) -> None:
        """Drop the request's pending swap-ins (preempt/abort mid-restore).
        A task another admitted request also waits on stays queued; an
        exclusive full-block task is removed and its entry *demoted* back to
        the spill pool — the destination block was never written, so the
        rows only exist in the un-copied payload.  COW tasks just drop (the
        canonical entry never left the pool)."""
        if not req.pending_restores:
            return
        for b in sorted(req.pending_restores):
            req.pending_restores.discard(b)
            if any(
                r is not None and r is not req and b in r.pending_restores
                for r in self.slots
            ):
                continue
            task = next((t for t in self._restore_q if t.dst == b), None)
            if task is None:
                continue  # already scattered this step
            self._restore_q.remove(task)
            self._restoring.discard(b)
            self.restores_cancelled += 1
            self._c_restore_cancel.inc()
            if not task.cow and self.prefix is not None:
                self.prefix.demote(b, task.payload)
            self.tracer.instant(
                "restore_cancel", track=SCHEDULER_TRACK, block=b, req_id=req.req_id
            )

    def _admit_chunked(self, req: Request, slot: int) -> bool:
        """Prefix-matched, block-budgeted admission (no model call: context
        chunks run inside subsequent ``step()`` prefill budgets).  Returns
        False when the pool can't cover the request's unshared blocks.

        A resumed (previously preempted) request admits through the same
        path with its committed context ``prompt + generated`` in place of
        the prompt: the blocks its eviction parked in the prefix LRU match
        here, so the preempted work is mostly recovered rather than
        recomputed.

        Matched blocks may live on either tier: device entries pin by
        refcount as before; **spilled** entries (negative handles) admit as
        a cheap re-prefill — their payloads are popped from the host pool
        *before* ``alloc`` (eviction churn inside alloc can spill new
        entries and must never LRU-drop rows about to swap back in), the
        entries are ``promote``d onto freshly-allocated device blocks, and
        the actual row scatter is queued for the scheduler's budgeted
        restore pass.  A spilled partial tail copies-on-write from the
        pool's decompressed rows while the canonical entry stays put."""
        needed = blocks_needed(
            len(req.prompt) + req.max_new_tokens + self._spec_extra, self.block_size
        )
        ctx = req.context()
        full, partial = self.prefix.match(ctx) if self.prefix else ([], None)
        dev_full = [b for b in full if not is_spilled(b)]
        spilled = [b for b in full if is_spilled(b)]
        partial_spilled = partial is not None and is_spilled(partial.block)
        # spilled hits need a fresh device block each; device hits are shared
        need_new = needed - len(dev_full)
        if self.prefix is not None:
            # pin matched device blocks first so the free-count check below
            # can't hand them out as eviction victims
            self.prefix.acquire(dev_full)
            if partial is not None and not partial_spilled:
                self.prefix.acquire([partial.block])
        if need_new > self.allocator.num_free:
            if self.prefix is not None:
                self.prefix.release(dev_full)
                if partial is not None and not partial_spilled:
                    self.prefix.release([partial.block])
            return False  # out of blocks: backpressure until frees
        payloads = {h: self.spill.pop(h) for h in spilled}
        cow_payload = self.spill.get(partial.block) if partial_spilled else None
        # chain state must be read while the handles are still in the index
        # (promote re-keys them)
        reg_parent = self.prefix.parent_hash(full) if self.prefix is not None else 0
        new_blocks = self.allocator.alloc(need_new)
        ni = 0
        blocks: list[int] = []
        for b in full:
            if not is_spilled(b):
                if b in self._restoring:
                    # promoted by an earlier admission, rows still in
                    # flight: this sharer waits on the same task
                    req.pending_restores.add(b)
                blocks.append(b)
                continue
            nb = new_blocks[ni]
            ni += 1
            self.prefix.promote(b, nb)
            self._queue_restore(nb, payloads[b], cow=False, req=req)
            blocks.append(nb)
        req.blocks = blocks + new_blocks[ni:]
        matched = len(full) * self.block_size
        if partial is not None:
            # copy-on-write: the partially-shared block's rows move into the
            # request's first private block; its suffix is overwritten by the
            # first prefill chunk while the cached original stays immutable
            # (device tier) or parked in the spill pool (host tier)
            dst = new_blocks[ni]
            if partial_spilled:
                self._queue_restore(dst, cow_payload, cow=True, req=req)
            else:
                self.cache = self._copy_block(
                    self.cache,
                    jnp.asarray(partial.block, jnp.int32),
                    jnp.asarray(dst, jnp.int32),
                )
                self.prefix.release([partial.block])
            matched += partial.tokens
            self.prefix_partial_hits += 1
        if matched:
            self.prefix_hits += 1
            self.prefix_hit_tokens += matched
            req.prefix_hit_tokens += matched  # accumulates across resumes
            self._c_prefix_hit.inc(matched)
        spill_matched = len(spilled) * self.block_size + (
            partial.tokens if partial_spilled else 0
        )
        if spill_matched:
            self.spill_hits += 1
            self.spill_hit_tokens += spill_matched
            self._c_spill_hit.inc(spill_matched)
            self.tracer.instant(
                "spill_hit",
                track=slot_track(slot),
                req_id=req.req_id,
                tokens=spill_matched,
                blocks=len(spilled) + int(partial_spilled),
            )
        if self.prefix is not None:
            # registration resumes after the matched (already indexed) blocks
            req.reg_block = len(full)
            req.reg_parent = reg_parent
        req.prefill_pos = matched
        req.prefilling = True
        req.state = RequestState.ACTIVE
        req.slot = slot
        self.slots[slot] = req
        self.peak_active = max(self.peak_active, sum(r is not None for r in self.slots))
        self.pos[slot] = matched
        if self._draft is not None:
            self._draft.reset(slot)
        self._note_admit(req, slot)
        # the engine table row stays null until the prompt completes, so
        # interleaved decode steps write into the scratch null block, never
        # into a half-prefilled request's memory
        self._prefilling.append(req)
        return True

    def _admit_blocking(self, req: Request, slot: int) -> bool:
        """Legacy one-shot admission: full prefill + cache graft (hybrid's
        recurrent states, and every dense-cache family)."""
        if self.cache_kind == "paged":
            needed = blocks_needed(len(req.prompt) + req.max_new_tokens, self.block_size)
            if needed > self.allocator.num_free:
                return False  # out of blocks: backpressure until frees
        self._note_admit(req, slot)
        t0 = self._clock()
        logits, raw = self._dispatch("prefill", self._run_prefill, req)
        n = len(req.prompt)
        self.prefill_chunks += 1
        self.prefill_tokens += n
        self._c_prefill_tokens.inc(n)
        req.step_work += n
        self._h_prefill_chunk.observe(self._clock() - t0)
        self.tracer.span(
            "prefill", t0, track=slot_track(slot), req_id=req.req_id, tokens=n
        )
        if self.cache_kind == "paged":
            req.blocks = self.allocator.alloc(needed)
            self.cache = self._dispatch(
                "graft", self._graft, self.cache, raw, jnp.asarray(req.blocks, jnp.int32), n, slot
            )
            self.tbl[slot] = make_table_row(req.blocks, self.max_blocks_per_seq)
            self._tbl_dirty = True
        else:
            req_cache = decode_cache_from_prefill(
                self.cfg, raw, seq_filled=n, decode_len=self.max_seq
            )
            self.cache = write_request_into_slot(self.cfg, self.cache, req_cache, slot)
        self.pos[slot] = n
        req.state = RequestState.ACTIVE
        req.slot = slot
        self.slots[slot] = req
        self.peak_active = max(self.peak_active, sum(r is not None for r in self.slots))
        # first generated token comes from the prefill logits
        self._emit_first_token(req, logits[0])
        return True

    def _emit_first_token(self, req: Request, logits) -> None:
        self._key, sub = jax.random.split(self._key)
        self.dispatches_total += 1
        self.host_syncs_total += 1
        tok = int(sample_token(logits, req.temperature, sub, top_k=req.top_k))
        self._note_first_token(req, tok)

    def _note_first_token(self, req: Request, tok: int) -> None:
        """First-token bookkeeping shared by the legacy path (which samples
        host-side from the final chunk's logits) and the fused path (whose
        token comes out of the one-dispatch graph)."""
        req.generated.append(tok)
        req.first_token_t = self._clock()
        self.tokens_out += 1
        self._c_tokens.inc()
        self._h_ttft.observe(req.first_token_t - req.submit_t)
        if req.admit_t is not None:
            self._h_admit_first.observe(req.first_token_t - req.admit_t)
        self.tracer.instant("first_token", track=slot_track(req.slot), req_id=req.req_id)
        if self.on_token is not None:
            self.on_token(req, [tok])
        self._finish_if_done(req)

    # ------------------------------------------------------------------
    def run_chunk(self, req: Request, c: int):
        """Run one c-token context chunk; returns the chunk's last logits."""
        ctx = req.context()
        start = req.prefill_pos
        toks = jnp.asarray(ctx[start : start + c], jnp.int32)[None]
        row = jnp.asarray(
            make_table_row(req.blocks, self.max_blocks_per_seq), jnp.int32
        )[None]
        t0 = self._clock()
        logits, self.cache = self._dispatch(
            "prefill_chunk",
            self._chunk_step,
            self.params,
            self.cache,
            toks,
            jnp.asarray([start], jnp.int32),
            row,
        )
        self._h_prefill_chunk.observe(self._clock() - t0)
        self.tracer.span(
            "prefill_chunk", t0, track=slot_track(req.slot), req_id=req.req_id,
            pos=start, tokens=c,
        )
        req.prefill_pos += c
        req.step_work += c
        self.pos[req.slot] = req.prefill_pos
        self.prefill_chunks += 1
        self.prefill_tokens += c
        self._c_prefill_tokens.inc(c)
        if self.prefix is not None:
            # index the newly-completed full context blocks (written above)
            req.reg_block, req.reg_parent = self.prefix.register(
                ctx,
                req.blocks,
                req.prefill_pos,
                start_block=req.reg_block,
                parent=req.reg_parent,
            )
        return logits

    def finish_prefill(self, req: Request, logits) -> None:
        """Context complete: publish the block table to the decode path.
        A fresh request samples its first token from the last chunk's
        logits; a resumed one already holds its first token — its trailing
        generated token is simply re-fed by the next decode step."""
        self.tbl[req.slot] = make_table_row(req.blocks, self.max_blocks_per_seq)
        self._tbl_dirty = True
        self.pos[req.slot] = req.prefill_target
        req.prefilling = False
        if not req.generated:
            self._emit_first_token(req, logits[0])

    # ------------------------------------------------------------------
    def _spec_step(self, active: list[Request]) -> int:
        """One speculative engine iteration over the decoding slots.

        Per slot: draft up to ``spec_k`` candidates (``ngram`` prompt lookup
        or the draft model), score every candidate in ONE verify pass
        (``models.verify_step`` — the chunked-prefill machinery with
        all-position logits), accept the longest target-agreeing prefix via
        ``sampler.spec_accept``, commit the accepted tokens' already-written
        K/V, and roll back the rejected tail (zero the stale rows, reset the
        position).  Slots with no draftable candidates (no n-gram match,
        one-token budget) degrade to a plain single-token step through the
        same pass.
        """
        K = self.spec_k
        V = self.cfg.padded_vocab
        tokens = np.zeros((self.max_batch, K + 1), np.int32)
        drafts = np.zeros((self.max_batch, K), np.int32)
        # draft mode carries the true proposal distributions; the ngram
        # drafter's q is a one-hot of ``drafts`` and is built on-device
        # below instead of materializing a dense (B, K, V) host array
        qprobs = np.zeros((self.max_batch, K, V), np.float32) if self._draft else None
        valid = np.zeros((self.max_batch, K), bool)
        start = np.zeros((self.max_batch,), np.int32)
        temps = np.zeros((self.max_batch,), np.float32)
        top_ks = np.zeros((self.max_batch,), np.int32)
        for r in active:
            s = r.slot
            ctx = r.prompt + r.generated
            kmax = self.scheduler.spec_window(r, K)
            if self.spec_mode == "ngram":
                d = ngram_draft(ctx, kmax)
            else:
                d, q = self._draft.draft(
                    s, ctx, kmax, temperature=r.temperature, top_k=r.top_k
                )
                if d:
                    qprobs[s, : len(d)] = q
            tokens[s, 0] = r.generated[-1]
            if d:
                tokens[s, 1 : 1 + len(d)] = d
                drafts[s, : len(d)] = d
                valid[s, : len(d)] = True
            start[s] = self.pos[s]
            temps[s] = r.temperature
            top_ks[s] = r.top_k
            self.spec_slot_steps += 1
            self.spec_drafted += len(d)
            self._c_drafted.inc(len(d))
            r.step_work += K + 1  # verify feeds the whole window per slot
            self.verify_tokens += K + 1  # fed window: last committed + K lanes
        logits, self.cache = self._dispatch(
            "verify",
            self._verify,
            self.params,
            self.cache,
            jnp.asarray(tokens),
            jnp.asarray(start),
            jnp.asarray(self.tbl),
        )
        self.steps += 1
        self.spec_steps += 1
        self._key, sub = jax.random.split(self._key)
        t_sample = self._clock() if self._profile else 0.0
        drafts_j = jnp.asarray(drafts)
        q_j = (
            jnp.asarray(qprobs)
            if qprobs is not None
            else jax.nn.one_hot(drafts_j, V, dtype=jnp.float32)
        )
        n_acc, final = spec_accept(
            logits,
            drafts_j,
            q_j,
            jnp.asarray(valid),
            jnp.asarray(temps),
            jnp.asarray(top_ks),
            sub,
        )
        # np.asarray forces the host sync, so the sample phase needs no
        # extra block_until_ready
        self.dispatches_total += 1  # the jitted spec_accept call above
        n_acc, final = self._host_fetch(n_acc, final)
        if self._profile:
            dt = self._clock() - t_sample
            self.metrics.histogram(
                "engine_profile_sample_seconds", "synced sample dispatch time"
            ).observe(dt)
            self._phase_acc["sample"] = self._phase_acc.get("sample", 0.0) + dt
        produced = 0
        t_start = np.zeros((self.max_batch,), np.int32)
        t_end = np.zeros((self.max_batch,), np.int32)  # end <= start: no-op slot
        for r in active:
            s = r.slot
            na = int(n_acc[s])
            emitted = [int(drafts[s, i]) for i in range(na)] + [int(final[s])]
            # stop at the first EOS inside the accepted window
            cut = next((j + 1 for j, t in enumerate(emitted) if t == self.eos), len(emitted))
            cut = min(cut, r.max_new_tokens - len(r.generated))
            emitted = emitted[:cut]
            base = int(start[s])
            clen = len(r.prompt) + len(r.generated)  # committed ctx before this step
            r.generated.extend(emitted)
            self.pos[s] = base + cut
            produced += cut
            self.tokens_out += cut
            self._c_tokens.inc(cut)
            self.spec_accepted += min(na, cut)
            self._c_accepted.inc(min(na, cut))
            self.spec_emitted += cut
            self.tracer.instant(
                "spec_accept",
                track=slot_track(s),
                req_id=r.req_id,
                drafted=int(valid[s].sum()),
                accepted=na,
                emitted=cut,
            )
            if self.on_token is not None and emitted:
                self.on_token(r, emitted)
            if self._draft is not None:
                # the drafter absorbed its own provisional tokens; truncate
                # its view to the committed prefix (divergent feeds are
                # re-fed by the next draft call's catch-up)
                self._draft.rollback(s, clen + min(na, cut))
            self._finish_if_done(r)
            if r.state != RequestState.ACTIVE:
                continue  # blocks already truncated + released at final length
            if cut < K + 1:
                # mark the rejected tail for rollback: its K/V rows are
                # zeroed so the pool never carries live-looking rows past
                # the committed length
                t_start[s], t_end[s] = base + cut, base + K + 1
                self.tracer.instant(
                    "rollback", track=slot_track(s), req_id=r.req_id,
                    tokens=int(K + 1 - cut),
                )
            self._reclaim_window_blocks(r)
        if np.any(t_end > t_start):
            # one whole-batch dispatch rolls back every slot's tail
            self.cache = self._dispatch(
                "rollback",
                self._trunc_rows,
                self.cache,
                jnp.asarray(self.tbl),
                jnp.asarray(t_start),
                jnp.asarray(t_end),
            )
        return produced

    # ------------------------------------------------------------------
    def _finish_if_done(self, req: Request) -> None:
        if req.state != RequestState.ACTIVE:
            return
        if len(req.generated) >= req.max_new_tokens or (req.generated and req.generated[-1] == self.eos):
            req.state = RequestState.DONE
            req.finish_reason = (
                "eos" if req.generated and req.generated[-1] == self.eos else "length"
            )
            req.done_t = self._clock()
            slot = req.slot
            self._c_finished.inc()
            if req.tpot is not None:
                self._h_tpot.observe(req.tpot)
            if req.deadline_s is not None and req.ttft is not None and req.ttft > req.deadline_s:
                self.deadline_violations += 1
                self._c_deadline_miss.inc()
            self.tracer.instant(
                "finish",
                track=slot_track(slot),
                req_id=req.req_id,
                reason=req.finish_reason,
                tokens=len(req.generated),
            )
            if req.admit_t is not None:
                # one span covering the request's whole residency in its
                # slot — the per-request lane in chrome://tracing
                self.tracer.span(
                    f"req {req.req_id}",
                    req.admit_t,
                    end=req.done_t,
                    track=slot_track(slot),
                    req_id=req.req_id,
                    tokens=len(req.generated),
                    prefix_hit_tokens=req.prefix_hit_tokens,
                )
            self.slots[slot] = None
            if self.cache_kind == "paged":
                # token-level truncate at the final committed length: tail
                # blocks hold only rejected speculative writes or unused
                # reserve (dead content) — plain-freed, never parked in the
                # prefix LRU; the kept span routes through the prefix index
                final_len = len(req.prompt) + len(req.generated)
                kept, tail = truncate_blocks(req.blocks, final_len, self.block_size)
                if tail:
                    self.allocator.free(tail)
                self._release_blocks(kept[req.freed_blocks :])
                req.blocks = []
                req.freed_blocks = 0
                self.tbl[slot] = 0  # null block
                self._tbl_dirty = True
                self.cache = clear_block_row(self.cfg, self.cache, slot)
            else:
                self.cache = clear_slot(self.cfg, self.cache, slot)
            self.pos[slot] = 0
            self.done.append(req)
            if self.on_finish is not None:
                self.on_finish(req)

    # ------------------------------------------------------------------
    def _reclaim_window_blocks(self, req: Request) -> None:
        """Sliding-window archs: free blocks that have slid out of the window.

        The dense layout ring-buffers W positions; the paged layout instead
        writes every position, so without reclamation a window arch would
        hold O(total) blocks where the ring holds O(window).  A block
        covering positions [i*bs, (i+1)*bs) is dead once its last position
        can no longer be attended by any future query (positions only grow):
        (i+1)*bs - 1 <= next_pos - W.  Dead blocks drop this request's
        reference (shared prefix blocks stay alive for their other holders)
        and the table entries point back at the null block (the window mask
        already excludes those positions in both decode impls).
        """
        W = self.cfg.sliding_window
        if W <= 0:
            return
        nxt = int(self.pos[req.slot])
        d = min((nxt - W + 1) // self.block_size, len(req.blocks))
        if d <= req.freed_blocks:
            return
        self._release_blocks(req.blocks[req.freed_blocks : d])
        self.tbl[req.slot, req.freed_blocks : d] = 0
        req.freed_blocks = d
        self._tbl_dirty = True

    def _maybe_defrag(self) -> None:
        """Auto-defrag: sort the free list when scatter exceeds the
        threshold, re-checked only after new frees."""
        if self.allocator is None or self.defrag_threshold >= 1.0:
            return
        if self.allocator.total_frees == self._frees_seen:
            return
        self._frees_seen = self.allocator.total_frees
        if self.allocator.fragmentation() > self.defrag_threshold:
            self.allocator.defrag()
            self.defrag_triggers += 1

    def _sync_tables(self) -> None:
        if self.cache_kind != "paged" or not self._tbl_dirty:
            return
        L = self.cache["tbl"].shape[0]
        tbl = np.broadcast_to(self.tbl[None], (L,) + self.tbl.shape)
        if self.mesh is not None:
            # commit the replicated layout up front so the host-side update
            # never changes the compiled dispatch's input sharding signature
            self.cache["tbl"] = jax.device_put(tbl, self._cache_shardings["tbl"])
        else:
            self.cache["tbl"] = jnp.asarray(tbl)
        self._tbl_dirty = False

    # ---- fused one-dispatch step -------------------------------------
    def _fused_step(self) -> int:
        """One fused engine tick: the scheduler emits a ``StepPlan`` of
        typed rows (decode / prefill-chunk / spec-verify) and the whole
        tick's model work — including sampling, speculative accept and the
        rejected-tail rollback — runs as ONE jitted dispatch, after which
        the host reads (new_tokens, accept_counts, cut, done_flags) in one
        sync.  Pure-decode ticks route through ``decode_step``'s exact graph
        (bit-identical logits to the unfused engine); mixed ticks run every
        row through the unified chunk path (greedy token-identical).

        One scheduling difference vs the legacy walk: a request whose
        prompt completes this tick gets its first token from the fused
        graph but joins decode only NEXT tick (the legacy path runs prefill
        before collecting the decode batch, so it decodes in the same
        step).  Token sequences are unchanged; per-request step counts can
        shift by one."""
        spec = self.spec_mode != "off"
        plan = self.scheduler.plan(spec=spec)
        self.peak_active = max(self.peak_active, sum(r is not None for r in self.slots))
        if not plan.rows:
            return 0
        if not plan.chunk_rows and not spec:
            return self._fused_decode_tick([pr.req for pr in plan.rows])
        return self._fused_mixed_tick(plan)

    def _fused_decode_tick(self, active: list[Request]) -> int:
        """All rows are single-token decodes: one dispatch through the
        fused decode graph (``decode_step`` + in-graph ``sample_tokens``)."""
        self._sync_tables()
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        temps = np.zeros((B,), np.float32)
        top_ks = np.zeros((B,), np.int32)
        room = np.ones((B,), np.int32)
        for r in active:
            tokens[r.slot, 0] = r.generated[-1]
            temps[r.slot] = r.temperature
            top_ks[r.slot] = r.top_k
            room[r.slot] = r.max_new_tokens - len(r.generated)
            r.step_work += 1
        self._key, sub = jax.random.split(self._key)
        toks, done, self.cache = self._dispatch(
            "fused_decode",
            self._fused_decode,
            self.params,
            self.cache,
            jnp.asarray(tokens),
            jnp.asarray(self.pos, jnp.int32),
            jnp.asarray(temps),
            jnp.asarray(top_ks),
            jnp.asarray(room),
            sub,
        )
        toks_h, _done_h = self._host_fetch(toks, done)
        self.steps += 1
        produced = 0
        for r in active:
            tok = int(toks_h[r.slot])
            r.generated.append(tok)
            self.pos[r.slot] += 1
            produced += 1
            self.tokens_out += 1
            self._c_tokens.inc()
            if self.on_token is not None:
                self.on_token(r, [tok])
            self._reclaim_window_blocks(r)
            self._finish_if_done(r)
        return produced

    def _fused_mixed_tick(self, plan) -> int:
        """Lower a mixed ``StepPlan`` into one unified (R, W) row batch and
        dispatch it once.  R buckets to a power of two (pad rows carry
        width 0 and an all-null table, so their lanes scatter into the null
        scratch block); W is the widest row — chunk widths are the
        power-of-two binary decomposition and the verify window is
        ``spec_k + 1``, so the (R, W) compile cache stays small."""
        K = self.spec_k
        rows = plan.rows
        R0 = len(rows)
        R = 1 << max(R0 - 1, 0).bit_length()
        has_verify = any(pr.kind == "verify" for pr in rows)
        row_width = {
            id(pr): (1 if pr.kind == "decode" else K + 1 if pr.kind == "verify" else pr.take)
            for pr in rows
        }
        W = max(max(row_width.values()), 1)
        nb = self.max_blocks_per_seq
        V = self.cfg.padded_vocab
        tokens = np.zeros((R, W), np.int32)
        start = np.zeros((R,), np.int32)
        widths = np.zeros((R,), np.int32)
        tbl = np.zeros((R, nb), np.int32)  # null-block rows for pad lanes
        drafts = np.zeros((R, W - 1), np.int32)
        valid = np.zeros((R, W - 1), bool)
        qprobs = (
            np.zeros((R, W - 1, V), np.float32)
            if (self._draft is not None and has_verify)
            else None
        )
        temps = np.zeros((R,), np.float32)
        top_ks = np.zeros((R,), np.int32)
        sample_lane = np.zeros((R,), np.int32)
        room = np.full((R,), W + 1, np.int32)  # pad rows: cut never clamps
        roll_end = np.zeros((R,), np.int32)  # 0 = no rollback for this row
        for i, pr in enumerate(rows):
            r = pr.req
            temps[i] = r.temperature
            top_ks[i] = r.top_k
            widths[i] = row_width[id(pr)]
            if pr.kind == "decode":
                s = r.slot
                tokens[i, 0] = r.generated[-1]
                start[i] = self.pos[s]
                tbl[i] = self.tbl[s]
                room[i] = r.max_new_tokens - len(r.generated)
                r.step_work += 1
            elif pr.kind == "verify":
                s = r.slot
                ctx = r.prompt + r.generated
                kmax = self.scheduler.spec_window(r, K)
                if self.spec_mode == "ngram":
                    d = ngram_draft(ctx, kmax)
                else:
                    d, q = self._draft.draft(
                        s, ctx, kmax, temperature=r.temperature, top_k=r.top_k
                    )
                    if d:
                        qprobs[i, : len(d)] = q
                tokens[i, 0] = r.generated[-1]
                if d:
                    tokens[i, 1 : 1 + len(d)] = d
                    drafts[i, : len(d)] = d
                    valid[i, : len(d)] = True
                start[i] = self.pos[s]
                tbl[i] = self.tbl[s]
                room[i] = r.max_new_tokens - len(r.generated)
                roll_end[i] = int(start[i]) + K + 1
                self.spec_slot_steps += 1
                self.spec_drafted += len(d)
                self._c_drafted.inc(len(d))
                r.step_work += K + 1
                self.verify_tokens += K + 1
            else:  # prefill chunk
                c = pr.take
                if c:
                    ctx = r.prompt + r.generated
                    tokens[i, :c] = ctx[pr.start : pr.start + c]
                start[i] = pr.start
                tbl[i, : len(r.blocks)] = r.blocks
                sample_lane[i] = max(c - 1, 0)
                if pr.final:
                    room[i] = max(r.max_new_tokens - len(r.generated), 1)
        self._key, sub = jax.random.split(self._key)
        fn = self._fused_spec if has_verify else self._fused_plain
        final, n_acc, cut, done, self.cache = self._dispatch(
            "fused_step",
            fn,
            self.params,
            self.cache,
            jnp.asarray(tokens),
            jnp.asarray(start),
            jnp.asarray(widths),
            jnp.asarray(tbl),
            jnp.asarray(drafts),
            jnp.asarray(valid),
            jnp.asarray(temps),
            jnp.asarray(top_ks),
            jnp.asarray(sample_lane),
            jnp.asarray(room),
            jnp.asarray(roll_end),
            sub,
            jnp.asarray(qprobs) if qprobs is not None else None,
        )
        final_h, n_acc_h, cut_h, _done_h = self._host_fetch(final, n_acc, cut, done)
        if any(pr.kind != "chunk" for pr in rows):
            self.steps += 1
        if has_verify:
            self.spec_steps += 1
        produced = 0
        for i, pr in enumerate(rows):
            r = pr.req
            if pr.kind == "chunk":
                c = pr.take
                r.prefill_pos = pr.start + c
                r.step_work += c
                self.pos[r.slot] = r.prefill_pos
                if c:
                    self.prefill_chunks += 1
                    self.prefill_tokens += c
                    self._c_prefill_tokens.inc(c)
                    self.tracer.instant(
                        "prefill_chunk", track=slot_track(r.slot), req_id=r.req_id,
                        pos=pr.start, tokens=c,
                    )
                    if self.prefix is not None:
                        ctx = r.prompt + r.generated
                        r.reg_block, r.reg_parent = self.prefix.register(
                            ctx, r.blocks, r.prefill_pos,
                            start_block=r.reg_block, parent=r.reg_parent,
                        )
                if pr.final:
                    self.scheduler.drop_prefilling(r)
                    self.tbl[r.slot] = make_table_row(r.blocks, self.max_blocks_per_seq)
                    self._tbl_dirty = True
                    self.pos[r.slot] = r.prefill_target
                    r.prefilling = False
                    if not r.generated:
                        self._note_first_token(r, int(final_h[i]))
            elif pr.kind == "decode":
                tok = int(final_h[i])
                r.generated.append(tok)
                self.pos[r.slot] += 1
                produced += 1
                self.tokens_out += 1
                self._c_tokens.inc()
                if self.on_token is not None:
                    self.on_token(r, [tok])
                self._reclaim_window_blocks(r)
                self._finish_if_done(r)
            else:  # verify
                s = r.slot
                na = int(n_acc_h[i])
                cut_i = int(cut_h[i])
                emitted = [int(drafts[i, j]) for j in range(na)] + [int(final_h[i])]
                emitted = emitted[:cut_i]
                base = int(start[i])
                clen = len(r.prompt) + len(r.generated)
                r.generated.extend(emitted)
                self.pos[s] = base + cut_i
                produced += cut_i
                self.tokens_out += cut_i
                self._c_tokens.inc(cut_i)
                self.spec_accepted += min(na, cut_i)
                self._c_accepted.inc(min(na, cut_i))
                self.spec_emitted += cut_i
                self.tracer.instant(
                    "spec_accept", track=slot_track(s), req_id=r.req_id,
                    drafted=int(valid[i].sum()), accepted=na, emitted=cut_i,
                )
                if self.on_token is not None and emitted:
                    self.on_token(r, emitted)
                if self._draft is not None:
                    self._draft.rollback(s, clen + min(na, cut_i))
                self._finish_if_done(r)
                if r.state == RequestState.ACTIVE:
                    self._reclaim_window_blocks(r)
        return produced

    def step(self) -> int:
        """One engine iteration: one scheduling pass (admission with SLO
        preemption, then the chunked-prefill budget — see
        ``scheduler.SchedulerCore``), then advance all decoding slots."""
        t0 = self._clock()
        done0 = len(self.done)
        if self._profile:
            self._phase_acc = {}
        self._enforce_deadlines()
        if self.fused:
            produced = self._fused_step()
            self._maybe_defrag()
            self._note_step(t0, done0, produced)
            return produced
        self.scheduler.schedule()
        self.peak_active = max(self.peak_active, sum(r is not None for r in self.slots))
        active = [r for r in self.slots if r is not None and not r.prefilling]
        produced = 0
        if active and self.spec_mode != "off":
            self._sync_tables()
            produced = self._spec_step(active)
        elif active:
            self._sync_tables()
            tokens = np.zeros((self.max_batch, 1), np.int32)
            temps = np.zeros((self.max_batch,), np.float32)
            top_ks = np.zeros((self.max_batch,), np.int32)
            for r in active:
                tokens[r.slot, 0] = r.generated[-1]
                temps[r.slot] = r.temperature
                top_ks[r.slot] = r.top_k
            pos = jnp.asarray(self.pos, jnp.int32)
            logits, self.cache = self._dispatch(
                "decode", self._decode, self.params, self.cache, jnp.asarray(tokens), pos
            )
            self.steps += 1
            # one whole-batch sampling dispatch; the all-greedy batch (the
            # common serving default) skips the sort/categorical work.
            # np.asarray is the host sync, so profiling adds no extra one
            t_sample = self._clock() if self._profile else 0.0
            self.dispatches_total += 1  # the sampling dispatch below
            if all(r.temperature <= 0.0 for r in active):
                (sampled,) = self._host_fetch(jnp.argmax(logits, axis=-1))
            else:
                self._key, sub = jax.random.split(self._key)
                (sampled,) = self._host_fetch(
                    sample_tokens(logits, jnp.asarray(temps), jnp.asarray(top_ks), sub)
                )
            if self._profile:
                dt = self._clock() - t_sample
                self.metrics.histogram(
                    "engine_profile_sample_seconds", "synced sample dispatch time"
                ).observe(dt)
                self._phase_acc["sample"] = self._phase_acc.get("sample", 0.0) + dt
            for r in active:
                tok = int(sampled[r.slot])
                r.generated.append(tok)
                self.pos[r.slot] += 1
                produced += 1
                self.tokens_out += 1
                self._c_tokens.inc()
                r.step_work += 1
                if self.on_token is not None:
                    self.on_token(r, [tok])
                if self.cache_kind == "paged":
                    self._reclaim_window_blocks(r)
                self._finish_if_done(r)
        self._maybe_defrag()
        self._note_step(t0, done0, produced)
        return produced

    def _note_step(self, t0: float, done0: int, produced: int) -> None:
        """Per-step observability tail: step latency + span, gauges, and
        energy attribution to the requests that did work this step."""
        dt = max(self._clock() - t0, 0.0)
        self._h_step.observe(dt)
        span_args = {"produced": produced}
        if self._profile and self._phase_acc:
            span_args["phases"] = {k: round(v, 6) for k, v in self._phase_acc.items()}
        self.tracer.span("step", t0, end=t0 + dt, track=SCHEDULER_TRACK, **span_args)
        self._g_queue.set(len(self.queue))
        self._g_active.set(sum(r is not None and not r.prefilling for r in self.slots))
        self._g_prefilling.set(len(self._prefilling))
        if self.steps:
            self._g_dispatches.set(self.dispatches_total / self.steps)
            self._g_host_syncs.set(self.host_syncs_total / self.steps)
        if self.allocator is not None:
            self._g_frag.set(self.allocator.fragmentation())
        if self.energy is None:
            return
        # requests that computed tokens this step: still in a slot, or
        # finished during the step.  The step's IT-side joules split
        # proportional to tokens computed (prefill chunks, decode tokens,
        # verify windows)
        workers = [r for r in self.slots if r is not None and r.step_work > 0]
        workers += [r for r in self.done[done0:] if r.step_work > 0]
        occupancy = min(len(workers) / self.max_batch, 1.0)
        joules = self.energy.record_step(dt, occupancy=occupancy)
        self._c_energy.inc(joules)
        total_work = sum(r.step_work for r in workers)
        for r in workers:
            r.energy_j += joules * r.step_work / total_work
            r.step_work = 0

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        """Closed-loop drain: step the scheduler core until no request is
        waiting, prefilling or decoding.  A thin wrapper over the same
        ``step()`` the always-on ``serving.async_engine`` loop drives —
        batch drains and streaming service exercise one code path."""
        for _ in range(max_steps):
            if not self.has_work:
                break
            self.step()
        else:
            n_queued = len(self.queue)
            n_active = sum(r is not None for r in self.slots)
            if n_queued or n_active:
                spec = ""
                if self.spec_mode != "off":
                    # surface acceptance so a drafting regression (fewer
                    # tokens/step -> more steps to drain) is visible in logs
                    rate = self.spec_accepted / self.spec_drafted if self.spec_drafted else 0.0
                    per = self.spec_emitted / self.spec_slot_steps if self.spec_slot_steps else 0.0
                    spec = (
                        f" (spec_decode={self.spec_mode}: acceptance_rate={rate:.2f}, "
                        f"accepted_per_step={per:.2f})"
                    )
                warnings.warn(
                    f"run_until_drained exhausted max_steps={max_steps} with "
                    f"{n_queued} queued and {n_active} active requests unfinished{spec}",
                    RuntimeWarning,
                    stacklevel=2,
                )
        return self.done

    # ------------------------------------------------------------------
    def cache_bytes(self, *, per_device: bool = False) -> int:
        """Device bytes held by the engine's KV cache (pools + tables).

        Global (logical) bytes by default — mesh-size invariant, so capacity
        planning reads the same number under TP=1 and TP=n.  ``per_device``
        instead sums each leaf's addressable shard: head-sharded pools count
        ``global / tp``, replicated tables count in full."""
        total = 0
        for l in jax.tree.leaves(self.cache):
            shape = l.sharding.shard_shape(l.shape) if per_device else l.shape
            total += int(np.prod(shape, dtype=np.int64)) * l.dtype.itemsize
        return total

    def stats(self) -> dict:
        """Engine counters (see docs/serving.md for the glossary and
        docs/observability.md for the histogram/trace layer).

        Returns a **defensive snapshot**: every value is a scalar or a
        freshly-built dict — mutating the result can never corrupt engine
        state, and every derived rate is division-by-zero-guarded so an
        empty or truncated drain still snapshots cleanly.

        ``mean_ttft_s`` is computed over FINISHED requests only and
        ``requests_queued`` / ``requests_active`` / ``requests_prefilling``
        report the population still in flight — a drained-with-truncation run
        (``run_until_drained`` hit ``max_steps``) is distinguishable from a
        finished one without parsing warnings.  The four populations
        PARTITION the submitted requests (``requests_active`` counts
        decoding slots only; a mid-prefill slot counts under
        ``requests_prefilling``), so ``done + queued + active + prefilling``
        equals every request ever submitted.
        """
        ttfts = [r.ttft for r in self.done if r.ttft is not None]
        s = {
            "cache_kind": self.cache_kind,
            "scheduler_policy": self.scheduler.policy,
            "preemptions": self.scheduler.preemptions,
            "requests_preempted": len(self._preempted_ids),
            "deadline_violations": self.deadline_violations,
            "requests_aborted": self.aborts,
            "requests_done": len(self.done),
            "requests_queued": len(self.queue),
            "requests_active": sum(r is not None and not r.prefilling for r in self.slots),
            "requests_prefilling": len(self._prefilling),
            "decode_steps": self.steps,
            "tokens_out": self.tokens_out,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
            "ttft_p50_s": self._h_ttft.percentile(50),
            "ttft_p99_s": self._h_ttft.percentile(99),
            "tpot_p50_s": self._h_tpot.percentile(50),
            "tpot_p99_s": self._h_tpot.percentile(99),
            "slot_utilization": (
                1.0 - len(self._free_slots()) / self.max_batch if self.max_batch else 0.0
            ),
            "peak_active": self.peak_active,
            "cache_bytes": self.cache_bytes(),
            "prefill_chunks": self.prefill_chunks,
            "prefill_tokens": self.prefill_tokens,
            "fused": self.fused,
            "dispatches_total": self.dispatches_total,
            "host_syncs_total": self.host_syncs_total,
            "dispatches_per_step": (
                self.dispatches_total / self.steps if self.steps else 0.0
            ),
            "host_syncs_per_step": (
                self.host_syncs_total / self.steps if self.steps else 0.0
            ),
        }
        if self.energy is not None:
            s["energy_joules"] = self.energy.joules
            s["joules_per_token"] = (
                self.energy.joules / self.tokens_out if self.tokens_out else 0.0
            )
        if self.mesh is not None:
            s["tp"] = int(self.mesh.shape.get("model", 1))
            s["cache_bytes_per_device"] = self.cache_bytes(per_device=True)
        if self.spec_mode != "off":
            s["spec_decode"] = self.spec_mode
            s["spec_k"] = self.spec_k
            s["spec_steps"] = self.spec_steps
            s["verify_tokens"] = self.verify_tokens
            s["drafted_tokens"] = self.spec_drafted
            s["accepted_tokens"] = self.spec_accepted
            s["acceptance_rate"] = (
                self.spec_accepted / self.spec_drafted if self.spec_drafted else 0.0
            )
            s["accepted_per_step"] = (
                self.spec_emitted / self.spec_slot_steps if self.spec_slot_steps else 0.0
            )
        if self.cache_kind == "paged":
            s["block_size"] = self.block_size
            s["defrag_triggers"] = self.defrag_triggers
            s["evictions"] = self.allocator.evictions
            s.update({f"alloc_{k}": v for k, v in self.allocator.stats().items()})
            if self.prefix is not None:
                # denominator = prompt tokens only: `prefill_tokens` is
                # incremented solely by prompt chunks / blocking prefills,
                # never by spec-decode verify windows (those accrue to
                # `verify_tokens`), so the hit rate is invariant to
                # spec_decode — regression-tested in tests/test_spec_decode.py
                served = self.prefix_hit_tokens + self.prefill_tokens
                s["prefix_hits"] = self.prefix_hits
                s["prefix_partial_hits"] = self.prefix_partial_hits
                s["prefix_hit_tokens"] = self.prefix_hit_tokens
                s["prefix_hit_rate"] = self.prefix_hit_tokens / served if served else 0.0
                s.update({f"prefix_{k}": v for k, v in self.prefix.stats().items()})
            if self.spill is not None:
                s["spill_hits"] = self.spill_hits
                s["spill_hit_tokens"] = self.spill_hit_tokens
                s["restores"] = self.restores
                s["restores_cancelled"] = self.restores_cancelled
                s["restores_pending"] = len(self._restore_q)
                s.update({f"spill_{k}": v for k, v in self.spill.stats().items()})
        return s
