"""Continuous-batching inference engine.

The paper's inference QoS class served as a real engine: a fixed-size decode
batch whose slots are continuously refilled as requests finish (Orca-style
iteration-level scheduling).  Admission runs a (batch=1) prefill and grafts
the resulting cache into a free slot; every ``step()`` advances ALL active
slots one token through the jitted ``decode_step``.

Online vs offline QoS (paper §IV.F): online requests preempt the admission
queue; offline requests backfill free slots.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, prefill
from repro.serving.kvcache import (
    clear_slot,
    decode_cache_from_prefill,
    make_engine_cache,
    write_request_into_slot,
)
from repro.serving.sampler import sample_token


class RequestState(Enum):
    WAITING = "waiting"
    ACTIVE = "active"
    DONE = "done"


@dataclass
class Request:
    req_id: int
    prompt: list[int]
    max_new_tokens: int = 32
    online: bool = True  # online requests admit before offline ones
    temperature: float = 0.0
    state: RequestState = RequestState.WAITING
    generated: list[int] = field(default_factory=list)
    slot: Optional[int] = None
    submit_t: float = field(default_factory=time.monotonic)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        return None if self.first_token_t is None else self.first_token_t - self.submit_t


class InferenceEngine:
    def __init__(self, cfg, params, *, max_batch: int = 4, max_seq: int = 512, eos_token: int = 1, seed: int = 0):
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only; no decode serving")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos = eos_token
        self.cache = make_engine_cache(cfg, max_batch, max_seq, jnp.float32)
        self.pos = np.full((max_batch,), 0, np.int32)  # next position per slot
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self._ids = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q))
        self._prefill = jax.jit(lambda p, b: prefill(cfg, p, b))
        self.steps = 0
        self.tokens_out = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: list[int], *, max_new_tokens: int = 32, online: bool = True, temperature: float = 0.0) -> Request:
        req = Request(
            req_id=next(self._ids),
            prompt=list(prompt),
            max_new_tokens=max_new_tokens,
            online=online,
            temperature=temperature,
        )
        self.queue.append(req)
        return req

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Prefill waiting requests into free slots (online first)."""
        free = self._free_slots()
        if not free:
            return
        self.queue.sort(key=lambda r: (not r.online, r.submit_t))
        while free and self.queue:
            req = self.queue.pop(0)
            slot = free.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            batch = {"tokens": prompt}
            if self.cfg.family == "vlm":
                batch["vision_tokens"] = jnp.zeros(
                    (1, self.cfg.vision.num_image_tokens, self.cfg.d_model), jnp.float32
                )
            logits, raw = self._prefill(self.params, batch)
            req_cache = decode_cache_from_prefill(
                self.cfg, raw, seq_filled=len(req.prompt), decode_len=self.max_seq
            )
            self.cache = write_request_into_slot(self.cfg, self.cache, req_cache, slot)
            self.pos[slot] = len(req.prompt)
            # first generated token comes from the prefill logits
            self._key, sub = jax.random.split(self._key)
            tok = int(sample_token(logits[0], req.temperature, sub))
            req.generated.append(tok)
            req.first_token_t = time.monotonic()
            req.state = RequestState.ACTIVE
            req.slot = slot
            self.slots[slot] = req
            self.tokens_out += 1
            self._finish_if_done(req)

    def _finish_if_done(self, req: Request) -> None:
        if req.state != RequestState.ACTIVE:
            return
        if len(req.generated) >= req.max_new_tokens or (req.generated and req.generated[-1] == self.eos):
            req.state = RequestState.DONE
            req.done_t = time.monotonic()
            slot = req.slot
            self.slots[slot] = None
            self.cache = clear_slot(self.cfg, self.cache, slot)
            self.pos[slot] = 0
            self.done.append(req)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit, then advance all active slots."""
        self._admit()
        active = [r for r in self.slots if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for r in active:
            tokens[r.slot, 0] = r.generated[-1]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, jnp.asarray(tokens), pos)
        self.steps += 1
        produced = 0
        for r in active:
            self._key, sub = jax.random.split(self._key)
            tok = int(sample_token(logits[r.slot], r.temperature, sub))
            r.generated.append(tok)
            self.pos[r.slot] += 1
            produced += 1
            self.tokens_out += 1
            self._finish_if_done(r)
        return produced

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        return self.done

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        ttfts = [r.ttft for r in self.done if r.ttft is not None]
        return {
            "requests_done": len(self.done),
            "decode_steps": self.steps,
            "tokens_out": self.tokens_out,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
            "slot_utilization": 1.0 - len(self._free_slots()) / self.max_batch,
        }
