"""Continuous-batching inference engine with a paged KV cache.

The paper's inference QoS class served as a real engine: a fixed-size decode
batch whose slots are continuously refilled as requests finish (Orca-style
iteration-level scheduling).  Admission runs a (batch=1) prefill and grafts
the resulting cache into the engine's persistent cache; every ``step()``
advances ALL active slots one token through the jitted ``decode_step``.

Two cache layouts:

* ``cache_kind="paged"`` (default for dense/moe/hybrid) — a global block
  pool + per-request block tables (``serving.paged.BlockAllocator``).
  Admission is gated on **free blocks**, not free slots: a request reserves
  ``ceil((prompt + max_new_tokens) / block_size)`` blocks, so short requests
  are cheap and concurrency is bounded by actual cache *bytes in use*
  instead of ``max_batch x max_seq`` worst-case lines.  This is the
  decode-HBM fix: the same byte budget admits strictly more concurrent
  requests whenever requests are shorter than ``max_seq``.
* ``cache_kind="dense"`` — the original slot-granular ring-buffer cache
  (still used by ssm/vlm families, and as the A/B baseline in benchmarks).

Paged requests are bounded by ``max_seq`` (the block-table width); the dense
ring additionally serves sliding-window archs past ``max_seq`` by wrapping.
Window archs on the paged path write every position but *reclaim* blocks as
they slide out of the window (``_reclaim_window_blocks``), so steady-state
usage is O(window) blocks per request, matching the ring's footprint.

Prefill recompilation fix: prompts are right-padded to power-of-two length
buckets (attention-only families, where causality makes padding exact), so
the jitted prefill compiles O(log max_seq) traces instead of one per
distinct prompt length.  ``quantize_kv=True`` stores paged pools int8 with
per-(token, head) scales (``serving.kvquant``), halving KV bytes vs bf16.

Online vs offline QoS (paper §IV.F): online requests preempt the admission
queue; offline requests backfill free capacity.
"""

from __future__ import annotations

import itertools
import time
import warnings
from dataclasses import dataclass, field
from enum import Enum
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_paged_cache, prefill, supports_paged
from repro.serving.kvcache import (
    clear_block_row,
    clear_slot,
    decode_cache_from_prefill,
    graft_prefill_into_blocks,
    make_engine_cache,
    make_table_row,
    write_request_into_slot,
)
from repro.serving.paged import BlockAllocator, blocks_needed
from repro.serving.sampler import sample_token

# families whose prefill is exact under right-padding (causal attention:
# pad positions can never influence earlier K/V or the last-real-token
# logits).  ssm/hybrid recurrent states WOULD absorb pad tokens, so those
# families prefill at exact prompt length (one trace per length).
BUCKETED_FAMILIES = ("dense", "moe", "vlm")
MIN_PREFILL_BUCKET = 8


class RequestState(Enum):
    WAITING = "waiting"
    ACTIVE = "active"
    DONE = "done"


@dataclass
class Request:
    req_id: int
    prompt: list[int]
    max_new_tokens: int = 32
    online: bool = True  # online requests admit before offline ones
    temperature: float = 0.0
    top_k: int = 0  # 0 = full softmax (only applies when temperature > 0)
    state: RequestState = RequestState.WAITING
    generated: list[int] = field(default_factory=list)
    slot: Optional[int] = None
    blocks: list[int] = field(default_factory=list)  # paged: owned physical blocks
    freed_blocks: int = 0  # paged: leading blocks already reclaimed (sliding window)
    submit_t: float = field(default_factory=time.monotonic)
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        return None if self.first_token_t is None else self.first_token_t - self.submit_t


class InferenceEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        max_batch: int = 4,
        max_seq: int = 512,
        eos_token: int = 1,
        seed: int = 0,
        cache_kind: str = "paged",
        block_size: int = 32,
        num_blocks: Optional[int] = None,
        cache_dtype=jnp.bfloat16,
        quantize_kv: bool = False,
        attn_impl: str = "xla",
    ):
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only; no decode serving")
        if cache_kind not in ("paged", "dense"):
            raise ValueError(f"cache_kind={cache_kind!r}")
        if cache_kind == "paged" and not supports_paged(cfg):
            # ssm states are O(1) per slot (nothing to page); vlm keeps the
            # grouped dense layout
            cache_kind = "dense"
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos = eos_token
        self.cache_kind = cache_kind
        self.cache_dtype = cache_dtype
        if quantize_kv and cache_kind != "paged":
            warnings.warn(
                f"quantize_kv only applies to paged block pools; ignored for "
                f"cache_kind={cache_kind!r} ({cfg.name})",
                RuntimeWarning,
                stacklevel=2,
            )
        self.quantize_kv = quantize_kv and cache_kind == "paged"
        if self.quantize_kv and attn_impl == "pallas":
            warnings.warn(
                "int8 block pools have no Pallas kernel yet; decode runs the "
                "dequantizing jnp reference path despite attn_impl='pallas'",
                RuntimeWarning,
                stacklevel=2,
            )
        self.attn_impl = attn_impl

        if cache_kind == "paged":
            self.block_size = block_size
            self.max_blocks_per_seq = -(-max_seq // block_size)
            if num_blocks is None:
                # default: same position capacity as the dense layout (+ null)
                num_blocks = max_batch * self.max_blocks_per_seq + 1
            self.num_blocks = num_blocks
            self.allocator = BlockAllocator(num_blocks)
            self.tbl = np.zeros((max_batch, self.max_blocks_per_seq), np.int32)
            self._tbl_dirty = True
            self.cache = init_paged_cache(
                cfg,
                num_blocks,
                block_size,
                max_batch,
                self.max_blocks_per_seq,
                cache_dtype,
                quantized=self.quantize_kv,
            )
        else:
            self.allocator = None
            self.cache = make_engine_cache(cfg, max_batch, max_seq, cache_dtype)

        self.pos = np.full((max_batch,), 0, np.int32)  # next position per slot
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self._ids = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q, attn_impl=attn_impl))
        self._prefill = jax.jit(lambda p, b: prefill(cfg, p, b))
        # donate the pool so admission updates only the request's blocks
        # in place instead of copying the whole pool per graft (donation is
        # honored on TPU; CPU falls back to a copy)
        self._graft = jax.jit(
            lambda c, raw, blocks, n, slot: graft_prefill_into_blocks(cfg, c, raw, blocks, n, slot),
            donate_argnums=(0,),
        )
        self._bucketed = cfg.family in BUCKETED_FAMILIES
        self.steps = 0
        self.tokens_out = 0
        self.peak_active = 0

    # ------------------------------------------------------------------
    def submit(
        self,
        prompt: list[int],
        *,
        max_new_tokens: int = 32,
        online: bool = True,
        temperature: float = 0.0,
        top_k: int = 0,
    ) -> Request:
        if not prompt:
            raise ValueError("empty prompt")
        total = len(prompt) + max_new_tokens
        if self.cache_kind == "paged":
            if total > self.max_seq:
                raise ValueError(
                    f"prompt+max_new_tokens={total} exceeds max_seq={self.max_seq}"
                )
            if blocks_needed(total, self.block_size) > self.allocator.capacity:
                raise ValueError(
                    f"request needs {blocks_needed(total, self.block_size)} blocks, "
                    f"pool capacity is {self.allocator.capacity}"
                )
        elif self.cfg.has_attention and self.cfg.sliding_window == 0 and total > self.max_seq:
            # full-attention dense cache: positions past max_seq would wrap the
            # ring buffer and silently corrupt the oldest entries
            raise ValueError(f"prompt+max_new_tokens={total} exceeds max_seq={self.max_seq}")
        req = Request(
            req_id=next(self._ids),
            prompt=list(prompt),
            max_new_tokens=max_new_tokens,
            online=online,
            temperature=temperature,
            top_k=top_k,
        )
        self.queue.append(req)
        return req

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    # ------------------------------------------------------------------
    def _bucket_len(self, n: int) -> int:
        """Power-of-two prefill length bucket (bounded trace count)."""
        if not self._bucketed:
            return n
        p = MIN_PREFILL_BUCKET
        while p < n:
            p *= 2
        return min(p, self.max_seq)

    def _run_prefill(self, req: Request):
        n = len(req.prompt)
        P = self._bucket_len(n)
        toks = req.prompt + [0] * (P - n)
        batch = {
            "tokens": jnp.asarray(toks, jnp.int32)[None, :],
            "last_index": jnp.asarray([n - 1], jnp.int32),
        }
        if self.cfg.family == "vlm":
            batch["vision_tokens"] = jnp.zeros(
                (1, self.cfg.vision.num_image_tokens, self.cfg.d_model), jnp.float32
            )
        return self._prefill(self.params, batch)

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Prefill waiting requests into free capacity (online first).

        Paged: admission requires a free slot AND enough free blocks for the
        request's worst case (prompt + max_new_tokens); when the pool is
        exhausted admission backpressures (FCFS head-of-line) until finished
        requests free their blocks.
        """
        free = self._free_slots()
        if not free:
            return
        self.queue.sort(key=lambda r: (not r.online, r.submit_t))
        while free and self.queue:
            req = self.queue[0]
            if self.cache_kind == "paged":
                needed = blocks_needed(len(req.prompt) + req.max_new_tokens, self.block_size)
                if needed > self.allocator.num_free:
                    break  # out of blocks: backpressure until frees
            self.queue.pop(0)
            slot = free.pop(0)
            logits, raw = self._run_prefill(req)
            n = len(req.prompt)
            if self.cache_kind == "paged":
                req.blocks = self.allocator.alloc(needed)
                self.cache = self._graft(
                    self.cache, raw, jnp.asarray(req.blocks, jnp.int32), n, slot
                )
                self.tbl[slot] = make_table_row(req.blocks, self.max_blocks_per_seq)
                self._tbl_dirty = True
            else:
                req_cache = decode_cache_from_prefill(
                    self.cfg, raw, seq_filled=n, decode_len=self.max_seq
                )
                self.cache = write_request_into_slot(self.cfg, self.cache, req_cache, slot)
            self.pos[slot] = n
            # first generated token comes from the prefill logits
            self._key, sub = jax.random.split(self._key)
            tok = int(sample_token(logits[0], req.temperature, sub, top_k=req.top_k))
            req.generated.append(tok)
            req.first_token_t = time.monotonic()
            req.state = RequestState.ACTIVE
            req.slot = slot
            self.slots[slot] = req
            self.tokens_out += 1
            self._finish_if_done(req)
        self.peak_active = max(self.peak_active, sum(r is not None for r in self.slots))

    def _finish_if_done(self, req: Request) -> None:
        if req.state != RequestState.ACTIVE:
            return
        if len(req.generated) >= req.max_new_tokens or (req.generated and req.generated[-1] == self.eos):
            req.state = RequestState.DONE
            req.done_t = time.monotonic()
            slot = req.slot
            self.slots[slot] = None
            if self.cache_kind == "paged":
                self.allocator.free(req.blocks[req.freed_blocks :])
                req.blocks = []
                req.freed_blocks = 0
                self.tbl[slot] = 0  # null block
                self._tbl_dirty = True
                self.cache = clear_block_row(self.cfg, self.cache, slot)
            else:
                self.cache = clear_slot(self.cfg, self.cache, slot)
            self.pos[slot] = 0
            self.done.append(req)

    # ------------------------------------------------------------------
    def _reclaim_window_blocks(self, req: Request) -> None:
        """Sliding-window archs: free blocks that have slid out of the window.

        The dense layout ring-buffers W positions; the paged layout instead
        writes every position, so without reclamation a window arch would
        hold O(total) blocks where the ring holds O(window).  A block
        covering positions [i*bs, (i+1)*bs) is dead once its last position
        can no longer be attended by any future query (positions only grow):
        (i+1)*bs - 1 <= next_pos - W.  Dead blocks return to the pool
        mid-decode and their table entries point back at the null block (the
        window mask already excludes those positions in both decode impls).
        """
        W = self.cfg.sliding_window
        if W <= 0:
            return
        nxt = int(self.pos[req.slot])
        d = min((nxt - W + 1) // self.block_size, len(req.blocks))
        if d <= req.freed_blocks:
            return
        self.allocator.free(req.blocks[req.freed_blocks : d])
        self.tbl[req.slot, req.freed_blocks : d] = 0
        req.freed_blocks = d
        self._tbl_dirty = True

    def _sync_tables(self) -> None:
        if self.cache_kind != "paged" or not self._tbl_dirty:
            return
        L = self.cache["tbl"].shape[0]
        self.cache["tbl"] = jnp.broadcast_to(jnp.asarray(self.tbl)[None], (L,) + self.tbl.shape)
        self._tbl_dirty = False

    def step(self) -> int:
        """One engine iteration: admit, then advance all active slots."""
        self._admit()
        active = [r for r in self.slots if r is not None]
        if not active:
            return 0
        self._sync_tables()
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for r in active:
            tokens[r.slot, 0] = r.generated[-1]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, jnp.asarray(tokens), pos)
        self.steps += 1
        produced = 0
        for r in active:
            self._key, sub = jax.random.split(self._key)
            tok = int(sample_token(logits[r.slot], r.temperature, sub, top_k=r.top_k))
            r.generated.append(tok)
            self.pos[r.slot] += 1
            produced += 1
            self.tokens_out += 1
            if self.cache_kind == "paged":
                self._reclaim_window_blocks(r)
            self._finish_if_done(r)
        return produced

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        else:
            n_queued = len(self.queue)
            n_active = sum(r is not None for r in self.slots)
            if n_queued or n_active:
                warnings.warn(
                    f"run_until_drained exhausted max_steps={max_steps} with "
                    f"{n_queued} queued and {n_active} active requests unfinished",
                    RuntimeWarning,
                    stacklevel=2,
                )
        return self.done

    # ------------------------------------------------------------------
    def cache_bytes(self) -> int:
        """Device bytes held by the engine's KV cache (pools + tables)."""
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(self.cache))

    def stats(self) -> dict:
        ttfts = [r.ttft for r in self.done if r.ttft is not None]
        s = {
            "cache_kind": self.cache_kind,
            "requests_done": len(self.done),
            "decode_steps": self.steps,
            "tokens_out": self.tokens_out,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
            "slot_utilization": 1.0 - len(self._free_slots()) / self.max_batch,
            "peak_active": self.peak_active,
            "cache_bytes": self.cache_bytes(),
        }
        if self.cache_kind == "paged":
            s["block_size"] = self.block_size
            s.update({f"alloc_{k}": v for k, v in self.allocator.stats().items()})
        return s
