"""Speculative decoding: drafters for the paged serving engine.

Decode is the HBM-bound hot path — every generated token re-reads the whole
KV cache for one token of output.  Speculative decoding spends the node's
spare FLOPs to amortise that traffic: a cheap *drafter* proposes ``k``
candidate tokens per slot, the target model scores all of them in ONE
multi-query-token pass through the chunked-prefill machinery
(``models.verify_step`` -> ``kernels.paged_prefill_attention``), and
``sampler.spec_accept`` keeps the longest prefix the target agrees with —
plus one correction/bonus token, so a slot always advances by at least one
token and by up to ``k + 1``.  The accept/reject rule is exact: the emitted
token stream is distributed (greedy: bit-identical) as if the target model
had decoded one token at a time.

Under tensor-parallel serving the verify pass runs as one SPMD dispatch
(sharded pools, vocab-sharded logits into ``sampler.spec_accept``) while
both drafters stay host-side/replicated: ``ngram_draft`` is pure Python
over token lists, and the ``DraftModel``'s per-slot batch=1 caches are
small enough that sharding them would cost more in collectives than it
saves — drafting is device-invariant, so acceptance statistics match TP=1
exactly.

Two drafters, selected by the engine's ``spec_decode`` knob:

* ``ngram_draft`` — self-speculative **prompt lookup** (no second model):
  the longest recent n-gram suffix of the context is searched for an earlier
  occurrence and the tokens that followed it are proposed.  Free to run and
  strong on repetitive traffic (code, templated prose, long shared prompts);
  proposes nothing when the context never repeats, which gracefully degrades
  to plain decode.  Its draft "distribution" is a one-hot at the proposed
  token, so the residual-sampling correction reduces to sampling from the
  target with the draft token's mass removed.
* ``DraftModel`` — a small same-family model (``make_draft_config``: the
  target config at reduced depth, same tokenizer-free synthetic-token
  vocabulary) decoded autoregressively ``k`` times per engine step.  Each
  slot keeps a private batch=1 dense decode cache; after the target's
  accept/reject, ``rollback`` truncates the drafter's committed length and
  the next ``draft`` call re-feeds the divergent tokens (stale ring entries
  hold *future* positions, so the causal mask hides them until they are
  overwritten — the same invariant the engine's paged rollback relies on).

The drafters run host-side on Python token lists (the engine's request
state); only the draft model's decode steps are jitted device work.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache
from repro.serving.sampler import _target_probs


def make_draft_config(cfg, *, num_layers: Optional[int] = None):
    """A draft config from the same family: the target config at reduced
    depth (default: half, floor 1).  Width, heads and — critically — the
    vocabulary are inherited, so drafted token ids are target token ids."""
    if num_layers is None:
        num_layers = max(cfg.num_layers // 2, 1)
    return cfg.replace(name=f"{cfg.name}-draft{num_layers}l", num_layers=num_layers)


def ngram_draft(
    context: list[int],
    k: int,
    *,
    max_ngram: int = 3,
    min_ngram: int = 1,
) -> list[int]:
    """Prompt-lookup drafting: propose the tokens that followed the most
    recent earlier occurrence of the longest matching suffix n-gram.

    Tries suffix lengths ``max_ngram`` down to ``min_ngram``; the most
    recent earlier occurrence of the suffix wins.  A match at position ``s``
    witnesses period ``p = L - n - s``, and the proposal extrapolates that
    period forward: token ``L + j`` is predicted as token ``L + j - p`` —
    for a non-overlapping match this is exactly "the k tokens that followed
    last time", and a run/cycle near the end proposes the whole window
    instead of stalling at the context boundary.  Returns ``[]`` when the
    context never repeats — the engine then takes a plain decode step.
    """
    L = len(context)
    if k <= 0 or L < min_ngram + 1:
        return []
    for n in range(min(max_ngram, L - 1), min_ngram - 1, -1):
        pat = context[L - n :]
        for s in range(L - n - 1, -1, -1):
            if context[s : s + n] == pat:
                p = L - n - s
                pred = list(context)
                for _ in range(k):
                    pred.append(pred[-p])
                return pred[L:]
    return []


class DraftModel:
    """Per-slot autoregressive drafter over private dense decode caches.

    Each engine slot owns a batch=1 ring cache for the draft model (tiny —
    the draft is a reduced-depth config).  ``draft`` first *catches up* on
    committed context tokens the cache hasn't absorbed (at most the prompt
    on a fresh slot, and <= 2 tokens per steady-state step: the corrected
    final token plus possibly the never-fed last draft), then rolls the
    draft forward ``k`` tokens, recording the distribution each one was
    drawn from — ``sampler.spec_accept`` needs the true proposal law ``q``
    for exact rejection sampling.

    Known tradeoff: drafting is O(active_slots * k) batch=1 decode
    dispatches per engine step (fine at smoke scale, where the draft is a
    2-layer micro-model).  A whole-batch draft cache with per-slot
    positions would cut that to k dispatches; it needs per-slot catch-up
    lengths to be equalised first, so it's left for a perf pass.
    """

    def __init__(self, cfg, params, *, max_batch: int, max_seq: int, seed: int = 0, metrics=None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.caches = [init_cache(cfg, 1, max_seq, jnp.float32) for _ in range(max_batch)]
        self.lens = np.zeros((max_batch,), np.int32)  # committed tokens absorbed
        self._decode = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q))
        self._key = jax.random.PRNGKey(seed ^ 0x5BEC)
        self._m_calls = self._m_feeds = None
        if metrics is not None:
            self._m_calls = metrics.counter("spec_draft_calls_total", "draft() invocations")
            self._m_feeds = metrics.counter(
                "spec_draft_feeds_total", "draft-model decode dispatches (catch-up + window)"
            )

    def reset(self, slot: int) -> None:
        """New request in ``slot``: restart from position 0.  The stale cache
        entries hold positions >= every future query position until they are
        overwritten in feed order, so the causal mask hides them."""
        self.lens[slot] = 0

    def rollback(self, slot: int, committed: int) -> None:
        """Truncate the drafter's view to ``committed`` context tokens after
        the target's accept/reject; rejected feeds get re-fed (overwritten)
        by the next ``draft`` call's catch-up."""
        self.lens[slot] = min(int(self.lens[slot]), committed)

    def _feed(self, slot: int, token: int, pos: int):
        if self._m_feeds is not None:
            self._m_feeds.inc()
        logits, self.caches[slot] = self._decode(
            self.params,
            self.caches[slot],
            jnp.asarray([[token]], jnp.int32),
            jnp.asarray([pos], jnp.int32),
        )
        return logits[0]

    def draft(
        self,
        slot: int,
        context: list[int],
        k: int,
        *,
        temperature: float = 0.0,
        top_k: int = 0,
    ) -> tuple[list[int], np.ndarray]:
        """Propose up to ``k`` tokens for ``slot`` given the committed
        ``context``.  Returns ``(tokens, probs)`` with ``probs[i]`` the (V,)
        distribution token ``i`` was drawn from (one-hot under greedy)."""
        if k <= 0:
            return [], np.zeros((0, 1), np.float32)
        if self._m_calls is not None:
            self._m_calls.inc()
        start = int(self.lens[slot])
        logits = None
        for i, t in enumerate(context[start:]):  # catch-up on committed tokens
            logits = self._feed(slot, int(t), start + i)
        pos = len(context)
        self.lens[slot] = pos
        drafts: list[int] = []
        probs: list[np.ndarray] = []
        temp = jnp.asarray([temperature], jnp.float32)
        tk = jnp.asarray([top_k], jnp.int32)
        for i in range(k):
            if pos + i >= self.max_seq:  # draft cache is full
                break
            # exact rejection sampling needs q and the target's p to share
            # one tempered/top-k rule — reuse the sampler's, don't copy it
            q = np.asarray(_target_probs(logits[None, None], temp, tk)[0, 0], np.float32)
            if temperature <= 0.0:
                d = int(np.argmax(q))  # one-hot row
            else:
                self._key, sub = jax.random.split(self._key)
                d = int(jax.random.categorical(sub, jnp.log(jnp.maximum(jnp.asarray(q), 1e-38))))
            drafts.append(d)
            probs.append(q)
            if i < k - 1:
                logits = self._feed(slot, d, pos + i)
        if len(drafts) > 1:
            # the provisional feeds past the context are rolled back by the
            # engine after accept/reject; record only what was actually fed
            self.lens[slot] = pos + len(drafts) - 1
        return drafts, np.stack(probs) if probs else np.zeros((0, 1), np.float32)
