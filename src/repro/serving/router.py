"""Fault-tolerant multi-replica router with prefix affinity.

One tensor-parallel engine is a single Grace-Hopper node; Isambard-AI
fields 1,362 of them and treats node failure as the baseline operating
condition.  ``Router`` turns N independent ``InferenceEngine`` replicas
(``serving.replica.Replica``, one per ``launch.mesh.make_replica_meshes``
slice) into one service with the seed cluster's health model on the
serving path:

* **Prefix-affinity routing** — a request is scored against every
  admittable replica's ``PrefixIndex`` (``match_tokens``, a pure peek);
  the replica already holding the most of its prompt wins.  When nothing
  is cached yet (a cold burst of requests sharing a brand-new system
  prompt), a **sticky map** keyed on ``prefix.routing_key`` — the chain
  hash of the prompt's first block — pins the whole burst to one replica
  so the first request's prefill serves the rest.  Everything else
  balances by load (queued + slotted requests).  ``policy="random"`` and
  ``"round_robin"`` exist as the A/B baselines the benchmark degrades to.
* **Health monitoring** — each ``step()`` sweeps heartbeat ages exactly
  like the seed ``Cluster.sweep_heartbeats``: older than ``suspect_after``
  → SUSPECT (routed around, still admittable as a last resort), older
  than ``fail_after`` → UNHEALTHY + failover.  A ``ReplicaCrashed`` raise
  (real or injected via ``serving.faults.FaultPlan``) fails the replica
  immediately.
* **Failover** — in-flight requests of a failed replica resubmit to a
  healthy one with exponential backoff (``backoff_base_s * 2**attempt``)
  and at most ``max_retries`` moves.  The already-delivered tokens are
  seeded into the fresh engine request, whose chunked admission re-prefills
  ``prompt + generated[:-1]`` — the same committed-context resume contract
  as SLO preemption, so greedy output is token-identical to a no-failure
  run.  Delivery is idempotent: the router forwards only tokens beyond
  what the client already received, so a replay can never duplicate a
  token.  (Non-chunked engines resubmit from scratch; greedy output is
  still identical, the prefix work is just recomputed.)
* **Graceful drain** — ``drain(replica_id)`` stops admission and lets the
  replica finish its work (``migrate=True`` moves it immediately via the
  failover path, without the failure accounting); a drained-clean replica
  RETIREs out of rotation.
* **Degraded mode** — with no admittable replica, ``submit`` raises
  ``ServiceUnavailable`` (HTTP 503) and pending failovers wait under
  backpressure instead of growing a queue nobody will serve; if every
  replica is actually gone they fail fast with ``finish_reason
  ="unavailable"`` so no stream hangs forever.

The router duck-types the engine surface ``AsyncEngine`` drives —
``submit`` / ``step`` / ``abort`` / ``has_work`` / ``eos`` / ``stats`` /
``metrics`` / ``on_token`` / ``on_finish`` — so the asyncio loop and the
HTTP front-end serve a fleet exactly as they serve one engine.
"""

from __future__ import annotations

import itertools
import random
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.serving.faults import ReplicaCrashed, ServiceUnavailable
from repro.serving.metrics import MetricsRegistry
from repro.serving.prefix import routing_key
from repro.serving.replica import Replica, ReplicaState
from repro.serving.scheduler import Request
from repro.serving.trace import Tracer, replica_track

ROUTER_TRACK = 0
ROUTING_POLICIES = ("affinity", "random", "round_robin")


def _router_track_label(track: int) -> str:
    return "router" if track == ROUTER_TRACK else f"replica {track - 1}"


@dataclass
class RouterRequest:
    """The router's client-facing request handle.

    ``generated`` holds the tokens actually **delivered** to the client —
    across failovers it is the request's single source of truth (engine-side
    replays are deduplicated against it).  Field names mirror
    ``scheduler.Request`` where the semantics match, so ``AsyncEngine``
    streams router requests unchanged.
    """

    req_id: int
    prompt: list[int]
    kwargs: dict  # submit() knobs, replayed verbatim on failover
    affinity_key: int
    submit_t: float
    generated: list[int] = field(default_factory=list)
    state: str = "active"  # active | done | failed
    finish_reason: Optional[str] = None
    replica_id: Optional[int] = None
    engine_req: Optional[Request] = None
    attempts: int = 1  # submissions tried (first placement included)
    failovers: int = 0  # moves off a failed replica
    retry_at: float = 0.0  # backoff gate while awaiting resubmission
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        return None if self.first_token_t is None else self.first_token_t - self.submit_t

    @property
    def preemptions(self) -> int:
        """Failovers, surfaced under the StreamEvent field of that name."""
        return self.failovers


class Router:
    """Prefix-affinity router over a set of engine replicas."""

    def __init__(
        self,
        replicas: list[Replica],
        *,
        policy: str = "affinity",
        clock: Optional[Callable[[], float]] = None,
        suspect_after: float = 1.0,
        fail_after: float = 5.0,
        max_retries: int = 3,
        backoff_base_s: float = 0.05,
        seed: int = 0,
        metrics: Optional[MetricsRegistry] = None,
        tracer: Optional[Tracer] = None,
        trace_capacity: int = 4096,
    ):
        if not replicas:
            raise ValueError("need at least one replica")
        ids = [r.id for r in replicas]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate replica ids: {ids}")
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"policy={policy!r} (choose from {ROUTING_POLICIES})")
        if not 0 < suspect_after <= fail_after:
            raise ValueError(f"need 0 < suspect_after <= fail_after, got {suspect_after}/{fail_after}")
        if max_retries < 0:
            raise ValueError(f"max_retries={max_retries}")
        self.replicas = list(replicas)
        self.policy = policy
        self._clock = clock if clock is not None else time.monotonic
        self.suspect_after = suspect_after
        self.fail_after = fail_after
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self._rng = random.Random(seed)
        self._rr = 0
        self._ids = itertools.count()
        # the first engine's block size keys the sticky map; replicas are
        # homogeneous by construction (make_replica_meshes slices one fleet)
        self._bs = getattr(replicas[0].engine, "block_size", 16) or 16
        self._sticky: dict[int, int] = {}  # affinity key -> replica id
        self._by_engine: dict[tuple[int, int], RouterRequest] = {}
        self._pending: list[RouterRequest] = []  # awaiting (re)submission
        self.done: list[RouterRequest] = []
        self.submitted = 0
        # streaming hooks, same contract as the engine's: on_token(req,
        # fresh_tokens) per delivery, on_finish(req) once per request
        self.on_token = None
        self.on_finish = None

        self.metrics = metrics if metrics is not None else MetricsRegistry()
        M = self.metrics
        self._c_requests = M.counter("router_requests_total", "requests accepted by the router")
        self._c_affinity = M.counter("router_affinity_routed_total", "requests routed by prefix affinity (peek or sticky key)")
        self._c_failovers = M.counter("router_failovers_total", "in-flight requests moved off a failed replica")
        self._c_retries = M.counter("router_retries_total", "failover resubmissions actually placed")
        self._c_migrations = M.counter("router_migrations_total", "requests migrated off a draining replica")
        self._c_failed = M.counter("router_requests_failed_total", "requests failed after exhausting retries")
        self._c_unavailable = M.counter("router_unavailable_total", "submissions rejected: no admittable replica")
        self._g_unhealthy = M.gauge("replica_unhealthy", "replicas failed out of rotation (unhealthy or dead)")
        self._g_inflight = M.gauge("router_inflight", "requests placed or awaiting resubmission")
        self.tracer = (
            tracer
            if tracer is not None
            else Tracer(self._clock, trace_capacity, track_label=_router_track_label)
        )
        for rep in self.replicas:
            self._hook(rep)

    # -- engine-hook plumbing ------------------------------------------
    def _hook(self, rep: Replica) -> None:
        rid = rep.id

        def on_token(ereq: Request, toks: list[int]) -> None:
            rreq = self._by_engine.get((rid, ereq.req_id))
            if rreq is None:
                return
            # idempotent delivery: a failed-over request replays its seeded
            # committed tokens through the engine's resume path — forward
            # only what the client has not seen yet
            start = len(ereq.generated) - len(toks)
            fresh = toks[max(len(rreq.generated) - start, 0) :]
            if not fresh:
                return
            if rreq.first_token_t is None:
                rreq.first_token_t = self._clock()
            rreq.generated.extend(fresh)
            if self.on_token is not None:
                self.on_token(rreq, fresh)

        def on_finish(ereq: Request) -> None:
            rreq = self._by_engine.pop((rid, ereq.req_id), None)
            if rreq is None:
                return
            self._finish(rreq, ereq.finish_reason or "length")

        rep.engine.on_token = on_token
        rep.engine.on_finish = on_finish

    def _finish(self, rreq: RouterRequest, reason: str) -> None:
        rreq.state = "failed" if reason in ("failed", "unavailable") else "done"
        rreq.finish_reason = reason
        rreq.done_t = self._clock()
        rreq.engine_req = None
        self.done.append(rreq)
        if self.on_finish is not None:
            self.on_finish(rreq)

    # -- routing --------------------------------------------------------
    @property
    def eos(self) -> int:
        return self.replicas[0].engine.eos

    def _rep(self, replica_id: int) -> Replica:
        for r in self.replicas:
            if r.id == replica_id:
                return r
        raise KeyError(f"no replica {replica_id}")

    def _route(self, prompt: list[int]) -> Replica:
        """Pick a target replica, or raise ``ServiceUnavailable``."""
        cands = [r for r in self.replicas if r.admittable]
        if not cands:
            self._c_unavailable.inc()
            self.tracer.instant("degraded", track=ROUTER_TRACK, replicas=len(self.replicas))
            raise ServiceUnavailable("no admittable replica (degraded mode)")
        # prefer healthy replicas; suspects only when nothing else is left
        healthy = [r for r in cands if r.state == ReplicaState.HEALTHY] or cands
        if self.policy == "random":
            return self._rng.choice(healthy)
        if self.policy == "round_robin":
            rep = healthy[self._rr % len(healthy)]
            self._rr += 1
            return rep
        # affinity: longest cached prefix wins; ties (incl. the all-cold
        # case) fall to the sticky key, then to least load
        key = routing_key(prompt, self._bs)
        scored = [
            (r.engine.prefix.match_tokens(prompt) if r.engine.prefix is not None else 0, r)
            for r in healthy
        ]
        best = max(s for s, _ in scored)
        if best > 0:
            rep = min((r for s, r in scored if s == best), key=lambda r: (r.load, r.id))
            self._c_affinity.inc()
        else:
            sticky = self._sticky.get(key)
            rep = next((r for r in healthy if r.id == sticky), None)
            if rep is not None:
                self._c_affinity.inc()
            else:
                rep = min(healthy, key=lambda r: (r.load, r.id))
        self._sticky[key] = rep.id
        return rep

    def submit(
        self,
        prompt: list[int],
        *,
        max_new_tokens: int = 32,
        online: bool = True,
        temperature: float = 0.0,
        top_k: int = 0,
        priority: int = 0,
        deadline_s: Optional[float] = None,
    ) -> RouterRequest:
        """Route and place one request.  Raises ``ServiceUnavailable`` in
        degraded mode; engine validation errors propagate unchanged."""
        prompt = list(prompt)
        rep = self._route(prompt)
        rreq = RouterRequest(
            req_id=next(self._ids),
            prompt=prompt,
            kwargs=dict(
                max_new_tokens=max_new_tokens,
                online=online,
                temperature=temperature,
                top_k=top_k,
                priority=priority,
                deadline_s=deadline_s,
            ),
            affinity_key=routing_key(prompt, self._bs),
            submit_t=self._clock(),
        )
        self._place(rreq, rep)
        self.submitted += 1
        self._c_requests.inc()
        return rreq

    def _place(self, rreq: RouterRequest, rep: Replica) -> None:
        ereq = rep.engine.submit(rreq.prompt, **rreq.kwargs)
        if rreq.generated and rep.engine.chunked():
            # failover resume: seed the delivered tokens so chunked
            # admission re-prefills prompt + generated[:-1] and decode
            # re-feeds the trailing token — the preemption-resume contract,
            # token-identical under greedy sampling
            ereq.generated = list(rreq.generated)
        rreq.engine_req = ereq
        rreq.replica_id = rep.id
        self._by_engine[(rep.id, ereq.req_id)] = rreq
        self.tracer.instant(
            "route",
            track=replica_track(rep.id),
            req_id=rreq.req_id,
            engine_req_id=ereq.req_id,
            resumed_tokens=len(rreq.generated),
        )

    def abort(self, req, reason: str = "aborted") -> bool:
        """Abort a router request (by handle or router req_id) wherever it
        currently lives — on a replica, or parked awaiting resubmission."""
        if isinstance(req, int):
            req = next(
                (
                    r
                    for r in list(self._by_engine.values()) + self._pending
                    if r.req_id == req
                ),
                None,
            )
        if req is None or req.state != "active":
            return False
        if req in self._pending:
            self._pending.remove(req)
            self._finish(req, reason)
            return True
        if req.engine_req is None or req.replica_id is None:
            return False
        rep = self._rep(req.replica_id)
        # the engine's on_finish hook routes back into _finish with the
        # abort reason, completing the router-side bookkeeping
        return rep.engine.abort(req.engine_req, reason)

    # -- failure handling ----------------------------------------------
    def _fail_replica(self, rep: Replica, cause: str) -> None:
        rep.state = ReplicaState.DEAD if cause == "crash" else ReplicaState.UNHEALTHY
        orphan_keys = [k for k in self._by_engine if k[0] == rep.id]
        orphans = [self._by_engine.pop(k) for k in orphan_keys]
        self.tracer.instant(
            "replica_down",
            track=replica_track(rep.id),
            cause=cause,
            inflight=len(orphans),
        )
        now = self._clock()
        for rreq in orphans:
            self._schedule_failover(rreq, now)

    def _schedule_failover(self, rreq: RouterRequest, now: float) -> None:
        rreq.engine_req = None
        rreq.replica_id = None
        if rreq.attempts > self.max_retries:
            self._c_failed.inc()
            self._finish(rreq, "failed")
            return
        rreq.failovers += 1
        rreq.retry_at = now + self.backoff_base_s * (2 ** (rreq.attempts - 1))
        rreq.attempts += 1
        self._pending.append(rreq)
        self._c_failovers.inc()
        self.tracer.instant(
            "failover",
            track=ROUTER_TRACK,
            req_id=rreq.req_id,
            attempt=rreq.attempts,
            delivered=len(rreq.generated),
            retry_at=rreq.retry_at,
        )

    def _resubmit_ready(self, now: float) -> None:
        if not self._pending:
            return
        if not any(r.alive for r in self.replicas):
            # every replica is gone: nothing will ever serve these — fail
            # fast so streams terminate instead of hanging on backpressure
            for rreq in self._pending:
                self._c_failed.inc()
                self._finish(rreq, "unavailable")
            self._pending = []
            return
        still: list[RouterRequest] = []
        for rreq in self._pending:
            if rreq.retry_at > now:
                still.append(rreq)
                continue
            try:
                rep = self._route(rreq.prompt)
            except ServiceUnavailable:
                still.append(rreq)  # degraded: hold under backpressure
                continue
            self._place(rreq, rep)
            rep.failovers_in += 1
            self._c_retries.inc()
        self._pending = still

    def _sweep_health(self, now: float) -> None:
        for rep in self.replicas:
            if not rep.alive or rep.state == ReplicaState.DRAINING:
                continue
            age = rep.heartbeat_age(now)
            if age >= self.fail_after:
                self._fail_replica(rep, "missed_heartbeats")
            elif age >= self.suspect_after:
                if rep.state == ReplicaState.HEALTHY:
                    rep.state = ReplicaState.SUSPECT
                    self.tracer.instant(
                        "replica_suspect", track=replica_track(rep.id), age=age
                    )
            elif rep.state == ReplicaState.SUSPECT:
                rep.state = ReplicaState.HEALTHY
                self.tracer.instant(
                    "replica_recovered", track=replica_track(rep.id), age=age
                )

    # -- drain ----------------------------------------------------------
    def drain(self, replica_id: int, *, migrate: bool = False) -> None:
        """Stop admission to a replica.  ``migrate=False`` lets it finish
        its in-flight work (it keeps stepping, then retires);
        ``migrate=True`` moves the work to peers immediately through the
        failover path, minus the failure accounting."""
        rep = self._rep(replica_id)
        if not rep.alive:
            raise ValueError(f"replica {replica_id} is {rep.state.value}; cannot drain")
        rep.state = ReplicaState.DRAINING
        self.tracer.instant(
            "drain", track=replica_track(rep.id), migrate=migrate, inflight=rep.load
        )
        if not migrate:
            return
        now = self._clock()
        for key in [k for k in self._by_engine if k[0] == rep.id]:
            rreq = self._by_engine.pop(key)
            # engine-side teardown (frees blocks, fires on_finish — which
            # finds no mapping and no-ops); router-side the request goes
            # straight back to the resubmission queue, no backoff
            rep.engine.abort(rreq.engine_req, "migrated")
            rreq.engine_req = None
            rreq.replica_id = None
            rreq.retry_at = now
            self._pending.append(rreq)
            self._c_migrations.inc()

    def _retire(self, rep: Replica) -> None:
        rep.state = ReplicaState.RETIRED
        self.tracer.instant("drain_complete", track=replica_track(rep.id), steps=rep.steps)

    # -- stepping --------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self._pending) or any(
            r.alive and r.engine.has_work for r in self.replicas
        )

    def step(self) -> int:
        """One fleet iteration: place due resubmissions, step every live
        replica (catching crashes), then sweep heartbeat health."""
        self._resubmit_ready(self._clock())
        produced = 0
        for rep in self.replicas:
            if not rep.alive:
                continue
            if rep.state == ReplicaState.DRAINING and not rep.engine.has_work:
                self._retire(rep)
                continue
            try:
                produced += rep.step()
            except ReplicaCrashed:
                self._fail_replica(rep, "crash")
        self._sweep_health(self._clock())
        self._g_unhealthy.set(
            sum(r.state in (ReplicaState.UNHEALTHY, ReplicaState.DEAD) for r in self.replicas)
        )
        self._g_inflight.set(len(self._by_engine) + len(self._pending))
        return produced

    def run_until_drained(self, max_steps: int = 10_000) -> list[RouterRequest]:
        """Closed-loop drain, the fleet analogue of the engine's."""
        for _ in range(max_steps):
            if not self.has_work:
                break
            self.step()
        return self.done

    # -- introspection ---------------------------------------------------
    def stats(self) -> dict:
        """Fleet-level aggregates plus per-replica engine stats."""
        engines = [r.engine for r in self.replicas]
        hit = sum(getattr(e, "prefix_hit_tokens", 0) for e in engines)
        prefill = sum(e.prefill_tokens for e in engines)
        served = hit + prefill
        return {
            "routing_policy": self.policy,
            "replicas": len(self.replicas),
            "replicas_admittable": sum(r.admittable for r in self.replicas),
            "requests_submitted": self.submitted,
            "requests_done": sum(r.state == "done" for r in self.done),
            "requests_failed": sum(r.state == "failed" for r in self.done),
            "requests_inflight": len(self._by_engine) + len(self._pending),
            "failovers": self._c_failovers.value,
            "retries": self._c_retries.value,
            "migrations": self._c_migrations.value,
            "tokens_out": sum(e.tokens_out for e in engines),
            "prefill_tokens": prefill,
            "prefix_hit_tokens": hit,
            "prefix_hit_rate": hit / served if served else 0.0,
            "replica_states": {r.id: r.state.value for r in self.replicas},
            "per_replica": {r.id: r.engine.stats() for r in self.replicas},
        }
