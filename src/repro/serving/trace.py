"""Request-lifecycle event tracer for the paged serving engine.

Answers the question the flat counters can't: *where did this request's
latency go?*  The engine records structured events — submit, admit (with
prefix-hit detail), every prefill chunk, first token, speculative
accept/reject, rollback, eviction, SLO preempt/resume, finish — into a
bounded ring buffer with
an injectable monotonic clock (the same clock as ``serving.metrics``), so a
drained run replays as a per-request timeline.

Two consumption paths:

* **In-process** — ``events`` / ``events_for(req_id)`` return the raw
  ``TraceEvent`` records; tests assert per-request ordering
  (submit < admit < chunk* < first_token < finish) on exact ManualClock
  timestamps.
* **Chrome trace / Perfetto** — ``to_chrome()`` emits the Trace Event
  Format (one JSON object with a ``traceEvents`` list): instants as
  ``ph="i"``, spans as complete ``ph="X"`` events with microsecond
  ``ts``/``dur``, plus ``thread_name`` metadata so the viewer shows **one
  track per batch slot and one for the scheduler**.  ``write(path)`` then
  opens directly in ``chrome://tracing`` or https://ui.perfetto.dev.

The ring buffer (``capacity`` events, oldest dropped first, drops counted)
bounds memory for always-on tracing; recording an event is one dataclass
construction and a deque append — cheap enough to stay on by default, and
entirely host-side (no device syncs: span durations on the default path
measure *dispatch* time; enable the engine's ``profile=True`` to bracket
dispatches with ``block_until_ready`` for device-inclusive phase times).
"""

from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

SCHEDULER_TRACK = 0


def slot_track(slot: int) -> int:
    """Track id for a batch slot (track 0 is the scheduler)."""
    return slot + 1


def replica_track(replica_id: int) -> int:
    """Track id for a replica on a router tracer (track 0 is the router)."""
    return replica_id + 1


@dataclass
class TraceEvent:
    name: str
    ts: float  # clock seconds (monotonic, engine clock)
    track: int = SCHEDULER_TRACK
    dur: Optional[float] = None  # None = instant, else span length in seconds
    req_id: Optional[int] = None
    args: dict = field(default_factory=dict)


class Tracer:
    def __init__(
        self,
        clock: Callable[[], float] = time.monotonic,
        capacity: int = 4096,
        track_label: Optional[Callable[[int], str]] = None,
    ):
        if capacity < 1:
            raise ValueError(f"capacity={capacity}")
        self._clock = clock
        self.capacity = capacity
        # maps a track id to its viewer lane name; default: engine layout
        # (track 0 = scheduler, track N = slot N-1).  The router passes its
        # own labeler (track 0 = router, track N = replica N-1).
        self.track_label = track_label
        self._events: deque[TraceEvent] = deque(maxlen=capacity)
        self.recorded = 0  # total events ever recorded (>= len(events))

    # -- recording -----------------------------------------------------
    def now(self) -> float:
        return self._clock()

    def instant(
        self,
        name: str,
        *,
        track: int = SCHEDULER_TRACK,
        req_id: Optional[int] = None,
        **args,
    ) -> None:
        self._events.append(TraceEvent(name, self._clock(), track, None, req_id, args))
        self.recorded += 1

    def span(
        self,
        name: str,
        start: float,
        *,
        end: Optional[float] = None,
        track: int = SCHEDULER_TRACK,
        req_id: Optional[int] = None,
        **args,
    ) -> None:
        """A complete span from ``start`` to ``end`` (default: now)."""
        if end is None:
            end = self._clock()
        self._events.append(
            TraceEvent(name, start, track, max(end - start, 0.0), req_id, args)
        )
        self.recorded += 1

    # -- consumption ---------------------------------------------------
    @property
    def events(self) -> list[TraceEvent]:
        return list(self._events)

    @property
    def dropped(self) -> int:
        """Events pushed out of the ring buffer."""
        return self.recorded - len(self._events)

    def events_for(self, req_id: int) -> list[TraceEvent]:
        return [e for e in self._events if e.req_id == req_id]

    def clear(self) -> None:
        self._events.clear()
        self.recorded = 0

    # -- export --------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome Trace Event Format (JSON object flavour).

        Timestamps rebase to the earliest buffered event and convert to
        microseconds; one ``thread_name`` metadata row per used track keeps
        the per-slot / scheduler lanes labelled in the viewer.
        """
        evs = sorted(self._events, key=lambda e: (e.ts, e.track))
        t0 = evs[0].ts if evs else 0.0
        out: list[dict] = [
            {"ph": "M", "pid": 0, "tid": 0, "ts": 0, "name": "process_name",
             "args": {"name": "paged-engine"}},
        ]
        for t in sorted({e.track for e in evs} | {SCHEDULER_TRACK}):
            if self.track_label is not None:
                label = self.track_label(t)
            else:
                label = "scheduler" if t == SCHEDULER_TRACK else f"slot {t - 1}"
            out.append(
                {"ph": "M", "pid": 0, "tid": t, "ts": 0, "name": "thread_name",
                 "args": {"name": label}}
            )
        for e in evs:
            args = dict(e.args)
            if e.req_id is not None:
                args["req_id"] = e.req_id
            rec = {
                "name": e.name,
                "pid": 0,
                "tid": e.track,
                "ts": round((e.ts - t0) * 1e6, 3),
                "args": args,
            }
            if e.dur is None:
                rec["ph"] = "i"
                rec["s"] = "t"  # instant scoped to its thread/track
            else:
                rec["ph"] = "X"
                rec["dur"] = round(e.dur * 1e6, 3)
            out.append(rec)
        return {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "metadata": {"dropped_events": self.dropped, "recorded_events": self.recorded},
        }

    def write(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
