"""Host-memory spill tier for evicted prefix-cache blocks.

Isambard-AI backs its GPU HBM with two all-flash capacity tiers so hot
working sets can overflow device memory without losing locality; this module
is the serving-stack analogue.  Without it, every LRU eviction from the
``BlockAllocator``'s cached pool destroys the block's K/V content — the
effective prefix cache is capped at one device's HBM.  With a ``SpillPool``
attached (``InferenceEngine(spill_bytes=...)``), eviction instead *demotes*
the block: its K/V rows are gathered off the device pool and parked in host
RAM, the ``PrefixIndex`` entry stays matchable under a negative **spill
handle**, and a later prefix hit swaps the rows back into freshly-allocated
device blocks (``promote``) instead of re-running prefill.

Tier state machine for one prefix-indexed block::

      alloc            free_cached          _evict_one
    free ──► in-use ──────────► cached ─────────────────► spilled
                 ▲                ▲        (SpillPool.put)    │
                 │  reuse_cached  │                           │ prefix hit:
                 │  (device hit)  │                           │ promote + swap-in
                 └────────────────┘◄──────────────────────────┘
                                      restore into a fresh
                                      device block (refcount 1)

    spilled ──► dropped   when the pool's byte budget forces out its own
                          LRU entry (``on_drop`` cascades the index unmap)

Design points:

* **Handles are negative ints** (-1, -2, ...), disjoint from physical block
  ids (>= 1; 0 is the null block) — the ``PrefixIndex`` keys entries by id,
  so a spilled entry needs no second index, just a tier-distinguishable id.
* **Double-buffered writeback**: ``put`` *stages* the raw device rows (the
  jitted gather has already been dispatched by the engine; JAX arrays are
  immutable, so the value is pinned even though the pool block is about to
  be overwritten) and defers the host copy.  Only when a staged entry is
  pushed past ``staging_depth`` by newer spills is it compressed and
  materialized to host numpy — ``np.asarray`` is the device→host sync — so
  the transfer overlaps with whatever decode steps run in between instead
  of blocking the eviction site.  A restore that catches its entry still
  staged is a free device-to-device move (never left the accelerator).
* **At-rest compression** (``mode``): ``"cache"`` stores rows in the pool's
  own dtype (bit-exact roundtrip; with ``quantize_kv=True`` pools the rows
  are already int8+scales, so "at rest" is int8 for free); ``"int8"``
  quantizes float K/V leaves per-(token, head) via ``serving.kvquant``;
  ``"fp8"`` uses the PR-1 e4m3 kernels' saturating cast with one amax scale
  per leaf.  Compression applies at materialization; ``get``/``pop``
  always return rows decompressed back to float (the engine's scatter casts
  to the pool dtype).
* **Byte budget**: ``capacity_bytes`` bounds the *compressed* host bytes
  (computed analytically from shapes, so accounting never waits on a
  device sync).  An admission that would overflow drops the pool's own LRU
  entries first, notifying ``on_drop(handle)`` so the prefix index can
  unmap the entry and cascade to any stranded descendants.
* **TP**: spilled rows are per-shard in a real multi-host deployment; on a
  single-host mesh ``np.asarray`` of a head-sharded leaf materializes the
  full logical row (see docs/serving.md, "Tiered KV cache").

The pool is engine-agnostic: payloads are just dicts of arrays, so the
Hypothesis state machine in ``tests/test_paged.py`` drives it with tiny
numpy payloads against the allocator invariants.
"""

from __future__ import annotations

import warnings
from collections import OrderedDict
from typing import Callable, Optional

import numpy as np

SPILL_MODES = ("cache", "int8", "fp8")

_QSUFFIX = "@qscale"  # compressed-leaf sibling key for quantization scales


def _is_float_leaf(name: str, arr) -> bool:
    """Leaves eligible for lossy at-rest compression: float K/V rows.
    Scale leaves (already fp32 metadata) and int8 rows pass through raw."""
    return not name.endswith("_scale") and np.issubdtype(
        np.dtype(arr.dtype), np.floating
    )


class SpillPool:
    """Byte-budgeted host-RAM pool of spilled KV blocks (LRU, compressed)."""

    def __init__(
        self,
        capacity_bytes: int,
        *,
        mode: str = "cache",
        staging_depth: int = 2,
        on_drop: Optional[Callable[[int], None]] = None,
    ):
        if capacity_bytes <= 0:
            raise ValueError(f"capacity_bytes={capacity_bytes} (need > 0)")
        if mode not in SPILL_MODES:
            raise ValueError(f"mode={mode!r} (choose from {SPILL_MODES})")
        if staging_depth < 0:
            raise ValueError(f"staging_depth={staging_depth}")
        self.capacity_bytes = capacity_bytes
        self.mode = mode
        self.staging_depth = staging_depth
        self.on_drop = on_drop  # called AFTER the entry is removed
        self._next = -1  # handles count down: -1, -2, ...
        self._payload: OrderedDict[int, dict] = OrderedDict()  # LRU order
        self._nbytes: dict[int, int] = {}
        self._staged: set[int] = set()  # handles whose payload is still device-side
        self._staging_order: list[int] = []  # oldest first
        self.bytes_used = 0
        self.spills = 0  # entries admitted
        self.drops = 0  # entries evicted by the byte budget
        self.restores = 0  # entries swapped back to device (engine-reported)
        self.refused = 0  # put() refusals (entry alone exceeds capacity)
        self._metrics = None

    def attach_metrics(self, registry) -> None:
        self._metrics = registry
        self._m_blocks = registry.gauge("spill_blocks", "KV blocks resident in the host spill tier")
        self._m_bytes = registry.gauge("spill_bytes_used", "compressed host bytes held by spilled blocks")
        self._m_spills = registry.counter("spill_blocks_total", "blocks demoted to the host tier")
        self._m_drops = registry.counter("spill_drops_total", "spilled blocks evicted by the byte budget")
        self._publish()

    def _publish(self) -> None:
        if self._metrics is not None:
            self._m_blocks.set(len(self._payload))
            self._m_bytes.set(self.bytes_used)

    def __len__(self) -> int:
        return len(self._payload)

    def __contains__(self, handle: int) -> bool:
        return handle in self._payload

    # -- byte accounting (analytic: no device syncs) --------------------
    def _leaf_nbytes(self, name: str, arr) -> int:
        size = int(np.prod(arr.shape, dtype=np.int64))
        if self.mode == "cache" or not _is_float_leaf(name, arr):
            return size * np.dtype(arr.dtype).itemsize
        if self.mode == "int8":
            # int8 rows + one fp32 scale per (..., head) row
            return size + int(np.prod(arr.shape[:-1], dtype=np.int64)) * 4
        return size + 4  # fp8: e4m3 rows + one fp32 scale per leaf

    def entry_nbytes(self, payload: dict) -> int:
        return sum(self._leaf_nbytes(n, a) for n, a in payload.items())

    # -- compression codecs ---------------------------------------------
    def _compress(self, payload: dict) -> dict:
        """Raw device/host rows -> compressed host numpy (the D2H sync)."""
        out = {}
        for name, arr in payload.items():
            if self.mode == "cache" or not _is_float_leaf(name, arr):
                out[name] = np.asarray(arr)
            elif self.mode == "int8":
                from repro.serving.kvquant import quantize

                q, scale = quantize(arr)
                out[name] = np.asarray(q)
                out[name + _QSUFFIX] = np.asarray(scale)
            else:  # fp8 at rest: one saturating e4m3 cast per leaf
                from repro.fp8.quantize import E4M3, compute_scale, quantize, tensor_amax

                scale = compute_scale(tensor_amax(arr), E4M3)
                out[name] = np.asarray(quantize(arr, scale, E4M3))
                out[name + _QSUFFIX] = np.asarray(scale)
        return out

    def _decompress(self, comp: dict) -> dict:
        """Compressed host numpy -> float rows (engine casts to pool dtype)."""
        import jax.numpy as jnp

        out = {}
        for name, arr in comp.items():
            if name.endswith(_QSUFFIX):
                continue
            scale = comp.get(name + _QSUFFIX)
            if scale is None:
                out[name] = jnp.asarray(arr)
            elif self.mode == "int8":
                from repro.serving.kvquant import dequantize

                out[name] = dequantize(jnp.asarray(arr), jnp.asarray(scale), jnp.float32)
            else:
                from repro.fp8.quantize import dequantize

                out[name] = dequantize(jnp.asarray(arr), jnp.asarray(scale), jnp.float32)
        return out

    # -- staging ring (the double buffer) -------------------------------
    def _materialize(self, handle: int) -> None:
        if handle not in self._staged:
            return
        self._staged.discard(handle)
        if handle in self._staging_order:
            self._staging_order.remove(handle)
        self._payload[handle] = self._compress(self._payload[handle])

    def flush(self) -> None:
        """Materialize every staged entry (tests / shutdown)."""
        for h in list(self._staging_order):
            self._materialize(h)

    # -- admission / eviction -------------------------------------------
    def put(self, payload: dict) -> Optional[int]:
        """Admit one block's raw rows; returns the spill handle, or None
        when the entry alone exceeds the byte budget (caller drops it).
        May evict the pool's own LRU entries (``on_drop`` per victim)."""
        nbytes = self.entry_nbytes(payload)
        if nbytes > self.capacity_bytes:
            self.refused += 1
            return None
        while self.bytes_used + nbytes > self.capacity_bytes:
            victim = next(iter(self._payload))
            self._drop(victim)
        handle = self._next
        self._next -= 1
        self._payload[handle] = payload
        self._nbytes[handle] = nbytes
        self.bytes_used += nbytes
        self._staged.add(handle)
        self._staging_order.append(handle)
        # drain the staging ring: entries pushed past the depth pay their
        # compress + host copy now, overlapped with the steps since their put
        while len(self._staging_order) > self.staging_depth:
            self._materialize(self._staging_order[0])
        self.spills += 1
        if self._metrics is not None:
            self._m_spills.inc()
        self._publish()
        return handle

    def _drop(self, handle: int) -> None:
        self._remove(handle)
        self.drops += 1
        if self._metrics is not None:
            self._m_drops.inc()
        if self.on_drop is not None:
            self.on_drop(handle)

    def _remove(self, handle: int) -> None:
        del self._payload[handle]
        self.bytes_used -= self._nbytes.pop(handle)
        self._staged.discard(handle)
        if handle in self._staging_order:
            self._staging_order.remove(handle)
        self._publish()

    def discard(self, handle: int) -> None:
        """Remove an entry without the ``on_drop`` callback (the prefix
        index calls this from its own unmap cascade)."""
        if handle in self._payload:
            self._remove(handle)
            self.drops += 1
            if self._metrics is not None:
                self._m_drops.inc()

    # -- lookup / restore -----------------------------------------------
    def touch(self, handle: int) -> None:
        """LRU bump on a match."""
        if handle in self._payload:
            self._payload.move_to_end(handle)

    def get(self, handle: int) -> dict:
        """The entry's rows, decompressed, without removing it (partial-hit
        copy-on-write keeps the canonical spilled entry in place)."""
        payload = self._payload[handle]
        self._payload.move_to_end(handle)
        if handle in self._staged:
            return dict(payload)  # raw device rows: free D2D restore
        return self._decompress(payload)

    def pop(self, handle: int) -> dict:
        """Remove the entry and return its rows, decompressed.  The caller
        owns the payload from here — a restore admission pops *before*
        allocating device blocks so eviction churn inside ``alloc`` can
        never LRU-drop an entry that is about to be swapped back in."""
        payload = self._payload[handle]
        staged = handle in self._staged
        self._remove(handle)
        return dict(payload) if staged else self._decompress(payload)

    def stats(self) -> dict:
        return {
            "blocks": len(self._payload),
            "bytes_used": self.bytes_used,
            "capacity_bytes": self.capacity_bytes,
            "mode": self.mode,
            "staged": len(self._staged),
            "spills": self.spills,
            "drops": self.drops,
            "restores": self.restores,
            "refused": self.refused,
        }


def warn_if_fp8_over_int8(quantize_kv, mode: str) -> str:
    """fp8-at-rest over a quantized (int8/fp8) pool would quantize already-
    quantized rows; fall back to the exact pool-native bytes instead.
    ``quantize_kv``: the engine's normalized pool mode (None/"int8"/"fp8")."""
    if quantize_kv and mode == "fp8":
        warnings.warn(
            f"spill_dtype='fp8' over a quantized (quantize_kv={quantize_kv!r}) pool "
            "would re-quantize quantized rows; spilling pool-native bytes+scales instead",
            RuntimeWarning,
            stacklevel=3,
        )
        return "cache"
    return mode
