"""Scheduler core for the serving engine: SLO policy, admission, preemption.

The paper's access model is a supercomputer operated like a cloud service —
Jupyter, MLOps and web front-ends under continuous interactive load — so the
serving stack's scheduling brain must be a component of its own, shared
between the closed-loop drain path (``InferenceEngine.run_until_drained``)
and the always-on asyncio loop (``serving.async_engine``).  This module is
that brain, extracted from the formerly monolithic ``engine.step()``:

* **Queue ordering (SLO policy)** — ``policy="slo"`` (default) orders the
  waiting queue by ``(priority desc, online first, earliest absolute
  deadline, FCFS)``: a request's ``priority`` is an integer class (higher
  admits first) and ``deadline_s`` is a per-request TTFT target in seconds
  from submit, used as an earliest-deadline-first tiebreak within a
  priority class.  With every knob left at its default the order reduces
  exactly to the historical behaviour (online ahead of offline backfill,
  FCFS within each class), so ``policy="fcfs"`` — which ignores priorities
  and deadlines outright — only differs when SLO knobs are actually used.
* **Admission** — the scheduler walks the queue head-first, placing
  requests into free batch slots through the engine's admission primitives
  (prefix-matched block-budgeted chunked admission, or the blocking
  prefill+graft path).  Admission backpressures head-of-line when the block
  pool can't cover the head request, exactly as before.
* **Preemption** — under pressure (no free slot, or the pool can't cover a
  strictly-higher-priority head request), the SLO policy evicts a victim:
  the lowest-priority running request (offline before online, most recently
  admitted first — least computed work lost).  The engine releases the
  victim's blocks through the prefix index, so the committed context parks
  in the LRU cached pool and the re-admission mostly *recovers* the work as
  a prefix hit; the victim requeues at its policy position and resumes via
  the normal chunked-admission path.  Preemption needs the chunk-resumable
  paged path (dense/moe families); hybrid/dense-cache engines never preempt.
* **Chunked-prefill budgeting** — each step spends ``prefill_budget``
  prompt tokens (0 = drain) on the oldest admitted prompts, FCFS in
  admission order, with the binary chunk decomposition bounding the jitted
  trace count.  Resumed (previously preempted) requests prefill their
  committed context ``prompt + generated[:-1]``; the trailing generated
  token is re-fed by the next decode step, so no first-token is re-emitted.
* **Spec-decode windows** — the per-slot draft window is clamped here
  (never draft past the generation budget), keeping every scheduling
  decision in one place.
* **Fused planning** — ``plan()`` runs the same admission/restore pass as
  ``schedule()`` but returns one ``StepPlan`` of typed ``PlanRow``s
  (``decode`` / ``chunk`` / ``verify``) instead of making imperative model
  calls: the fused engine (``fused=True``) lowers the whole plan into a
  single jitted dispatch and applies the side effects afterwards.

The scheduler drives the engine through a narrow operations surface
(``free_slots`` / ``running`` / ``try_admit`` / ``preempt`` / ``can_preempt``
/ ``chunked`` / ``run_chunk`` / ``finish_prefill``) and owns only host-side
Python state — no device work, no clocks, no metrics of its own — so it is
trivially mesh-invariant and reusable by the async front-end unchanged.
"""

from __future__ import annotations

import math
from bisect import insort
from dataclasses import dataclass, field
from enum import Enum
from typing import Optional


def binary_chunks(n: int) -> list[int]:
    """Split ``n`` tokens into power-of-two chunk sizes, largest first
    (e.g. 52 -> [32, 16, 4]).  Chunk lengths drawn from a log-bounded set
    keep the jitted ``prefill_step`` trace count O(log max_seq) without any
    pad tokens — padding would perturb MoE expert-capacity routing."""
    out = []
    bit = 1 << max(n.bit_length() - 1, 0)
    while n > 0:
        if n >= bit:
            out.append(bit)
            n -= bit
        bit >>= 1
    return out


class RequestState(Enum):
    WAITING = "waiting"
    ACTIVE = "active"
    DONE = "done"


@dataclass
class Request:
    req_id: int
    prompt: list[int]
    max_new_tokens: int = 32
    online: bool = True  # online requests admit before offline ones
    priority: int = 0  # SLO class: higher admits first, can preempt lower
    deadline_s: Optional[float] = None  # TTFT target (seconds from submit); EDF tiebreak
    temperature: float = 0.0
    top_k: int = 0  # 0 = full softmax (only applies when temperature > 0)
    state: RequestState = RequestState.WAITING
    # how the request ended: "eos" / "length", or an abort cause
    # ("aborted", "cancelled", "deadline_exceeded", "migrated", ...)
    finish_reason: Optional[str] = None
    generated: list[int] = field(default_factory=list)
    slot: Optional[int] = None
    blocks: list[int] = field(default_factory=list)  # paged: owned physical blocks
    freed_blocks: int = 0  # paged: leading blocks already reclaimed (sliding window)
    # spill tier: device blocks whose rows are still in flight from the host
    # pool — the request may not prefill or publish until this empties
    pending_restores: set[int] = field(default_factory=set)
    prefill_pos: int = 0  # chunked: context tokens already in the cache
    prefilling: bool = False  # chunked: admitted but context not fully processed
    preemptions: int = 0  # times this request was evicted and requeued
    prefix_hit_tokens: int = 0  # context tokens served from the prefix cache
    reg_block: int = 0  # prefix registration resume point (block index, ...
    reg_parent: int = 0  # ... chain hash) — registration is incremental
    # timestamps come from the engine's injectable clock (metrics.ManualClock
    # in tests), not time.monotonic directly — latencies are assertable
    submit_t: float = 0.0
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    energy_j: float = 0.0  # IT-side joules attributed to this request
    step_work: int = 0  # tokens computed this step (energy attribution; reset per step)

    def context(self) -> list[int]:
        """Committed token context: prompt plus everything generated."""
        return self.prompt + self.generated

    @property
    def prefill_target(self) -> int:
        """Context tokens that must be in the cache before decode can run.

        Fresh requests prefill the whole prompt (the first generated token
        is sampled from the final chunk's logits); a resumed request
        prefills ``prompt + generated[:-1]`` — the trailing generated token
        is re-fed by the next decode step, which writes its K/V row and
        samples the continuation, exactly as if it had never left its slot.
        """
        return len(self.prompt) + max(len(self.generated) - 1, 0)

    @property
    def deadline_t(self) -> float:
        """Absolute TTFT deadline on the engine clock (inf when unset)."""
        return math.inf if self.deadline_s is None else self.submit_t + self.deadline_s

    @property
    def ttft(self) -> Optional[float]:
        return None if self.first_token_t is None else self.first_token_t - self.submit_t

    @property
    def queue_wait(self) -> Optional[float]:
        return None if self.admit_t is None else self.admit_t - self.submit_t

    @property
    def tpot(self) -> Optional[float]:
        """Mean inter-token time after the first token (finished requests
        with >= 2 generated tokens)."""
        if self.done_t is None or self.first_token_t is None or len(self.generated) < 2:
            return None
        return (self.done_t - self.first_token_t) / (len(self.generated) - 1)

    @property
    def joules_per_token(self) -> Optional[float]:
        return self.energy_j / len(self.generated) if self.generated else None


POLICIES = ("slo", "fcfs")


@dataclass
class PlanRow:
    """One typed row of a fused step: what the engine feeds, not how.

    ``kind``: ``"decode"`` (one token, published table), ``"chunk"`` (a
    prefill chunk of ``take`` tokens from the request's private table) or
    ``"verify"`` (a spec_k+1 speculative window).  ``start`` is the row's
    absolute cache position (-1 = engine-resolved from its position array —
    decode/verify rows).  ``final`` marks the chunk that completes the
    prompt: its table publishes and (for fresh requests) its last real
    lane's logits yield the first token."""

    kind: str  # "decode" | "chunk" | "verify"
    req: Request
    start: int = -1
    take: int = 1
    final: bool = False


@dataclass
class StepPlan:
    """One scheduler tick's worth of model work as a unified row batch.

    Produced by ``SchedulerCore.plan()`` (the fused engine path) instead of
    the imperative ``schedule()`` walk: the scheduler decides WHAT runs —
    admission, restores, the prefill-budget split into binary chunks, the
    decode/verify row set — and the engine lowers the whole plan into ONE
    jitted dispatch.  ``plan()`` mutates no request state; the engine applies
    positions/bookkeeping after the dispatch returns."""

    rows: list[PlanRow] = field(default_factory=list)

    @property
    def chunk_rows(self) -> list[PlanRow]:
        return [r for r in self.rows if r.kind == "chunk"]

    @property
    def model_rows(self) -> list[PlanRow]:
        return [r for r in self.rows if r.kind != "chunk"]


class SchedulerCore:
    """Admission, SLO ordering, preemption and prefill budgeting.

    ``ops`` is the execution backend (the ``InferenceEngine``), driven
    through a narrow surface:

    ==================  =====================================================
    ``free_slots()``    free batch-slot indices
    ``running()``       requests currently holding a slot (decoding or
                        mid-prefill)
    ``try_admit(r, s)`` place request ``r`` into slot ``s``; False when the
                        block pool can't cover it (backpressure)
    ``can_preempt()``   True when eviction+resume is supported (chunked
                        paged engines)
    ``preempt(r)``      evict ``r``: release its blocks (prefix-indexed
                        content parks in the LRU pool), clear its slot,
                        mark it WAITING
    ``chunked()``       True when prompts stream in budgeted chunks
    ``run_chunk(r, c)`` run one c-token context chunk; returns the logits
    ``finish_prefill``  publish the block table; fresh requests sample
                        their first token, resumed ones re-enter decode
    ==================  =====================================================
    """

    def __init__(
        self,
        ops,
        *,
        policy: str = "slo",
        prefill_budget: int = 0,
        restore_budget: int = 4,
    ):
        if policy not in POLICIES:
            raise ValueError(f"policy={policy!r} (choose from {POLICIES})")
        self.ops = ops
        self.policy = policy
        self.prefill_budget = prefill_budget
        self.restore_budget = restore_budget  # spill swap-ins executed per step
        self.queue: list[Request] = []  # maintained in policy order
        self.prefilling: list[Request] = []  # admission (FCFS) order
        self.preemptions = 0  # eviction decisions taken

    # -- queue ---------------------------------------------------------
    def _key(self, r: Request):
        if self.policy == "fcfs":
            return (not r.online, r.req_id)
        return (-r.priority, not r.online, r.deadline_t, r.req_id)

    def enqueue(self, req: Request) -> None:
        """Insert at the request's policy position (binary search — the
        queue is kept sorted, never re-sorted per admission pass)."""
        insort(self.queue, req, key=self._key)

    def dequeue(self, req: Request) -> bool:
        """Remove a waiting request from the queue (abort path).  Returns
        False when it is not queued (already admitted or finished)."""
        if req in self.queue:
            self.queue.remove(req)
            return True
        return False

    def drop_prefilling(self, req: Request) -> None:
        """Forget a mid-prefill request (preempted before its first token)."""
        if req in self.prefilling:
            self.prefilling.remove(req)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.prefilling

    # -- spec-decode windows -------------------------------------------
    @staticmethod
    def spec_window(req: Request, k: int) -> int:
        """Draft window for one slot: never draft past the generation
        budget — at most ``remaining - 1`` so the verify window's +1
        correction/bonus token stays within ``max_new_tokens``."""
        return min(k, req.max_new_tokens - len(req.generated) - 1)

    # -- admission + preemption ----------------------------------------
    def _preempt_for(self, req: Request) -> bool:
        """Evict one victim to make room for ``req``.  Victim: the
        lowest-priority running request strictly below ``req.priority``
        (offline before online, most recently admitted first — the least
        computed work is lost).  Returns False when nothing is evictable."""
        if self.policy != "slo" or not self.ops.can_preempt():
            return False
        victims = [r for r in self.ops.running() if r.priority < req.priority]
        if not victims:
            return False
        victim = min(victims, key=lambda r: (r.priority, r.online, -(r.admit_t or 0.0)))
        self.ops.preempt(victim)
        self.drop_prefilling(victim)
        self.preemptions += 1
        self.enqueue(victim)
        return True

    def _admit(self) -> None:
        ops = self.ops
        free = ops.free_slots()
        while self.queue:
            req = self.queue[0]
            if not free:
                if not self._preempt_for(req):
                    break  # batch full, nothing evictable
                free = ops.free_slots()
                continue
            if ops.try_admit(req, free[0]):
                self.queue.pop(0)
                free.pop(0)
                continue
            # out of blocks: evict a lower-priority victim and retry, else
            # backpressure head-of-line until finishes free their blocks
            if not self._preempt_for(req):
                break
            free = ops.free_slots()

    def _prefill(self) -> None:
        """Spend this step's prefill token budget on the oldest admitted
        contexts (FCFS).  ``prefill_budget <= 0`` drains every pending
        context (the blocking-throughput configuration); a positive budget
        bounds prefill work per step so decode latency stays flat while
        long prompts stream in."""
        if not self.ops.chunked():
            return
        budget = self.prefill_budget if self.prefill_budget > 0 else math.inf
        restoring = getattr(self.ops, "restoring", None)
        i = 0
        while i < len(self.prefilling) and budget > 0:
            req = self.prefilling[i]
            if restoring is not None and restoring(req):
                # spill swap-ins still in flight: the request's block table
                # points at rows the restore pass has not written yet, so it
                # must not prefill (or publish) this step.  Skip — don't
                # stall the budget behind it — and let younger admitted
                # prompts spend the tokens; FCFS order is preserved among
                # the runnable ones.
                i += 1
                continue
            take = int(min(budget, req.prefill_target - req.prefill_pos))
            logits = None
            for c in binary_chunks(take):
                logits = self.ops.run_chunk(req, c)
            budget -= take
            if req.prefill_pos >= req.prefill_target:
                self.prefilling.pop(i)
                self.ops.finish_prefill(req, logits)
            else:
                i += 1

    def _restore(self) -> None:
        """Execute up to ``restore_budget`` queued spill swap-ins (host ->
        device block-row copies) before prefill, so requests admitted
        against spilled prefix entries become runnable as early as
        possible.  Engines without a spill tier simply lack the op."""
        run = getattr(self.ops, "run_restores", None)
        if run is not None:
            run(self.restore_budget)

    def schedule(self) -> None:
        """One scheduling pass: admission (with preemption under the SLO
        policy), spill restores, then the chunked-prefill budget."""
        self._admit()
        self._restore()
        self._prefill()

    # -- fused planning ------------------------------------------------
    def _plan_prefill(self) -> list[PlanRow]:
        """The ``_prefill`` budget walk re-expressed as rows: same FCFS
        order, same restore skip, same binary-chunk decomposition — but no
        ``run_chunk`` calls and no request mutation.  Several chunks of one
        request become several rows (the fused dispatch scatters each
        layer's K/V before attending, so a later chunk row reads the earlier
        chunk row's same-layer writes exactly as sequential chunking would)."""
        if not self.ops.chunked():
            return []
        budget = self.prefill_budget if self.prefill_budget > 0 else math.inf
        restoring = getattr(self.ops, "restoring", None)
        rows: list[PlanRow] = []
        for req in self.prefilling:
            if budget <= 0:
                break
            if restoring is not None and restoring(req):
                continue  # swap-ins in flight: skip, don't stall the budget
            take = int(min(budget, req.prefill_target - req.prefill_pos))
            pos = req.prefill_pos
            chunks = binary_chunks(take)
            for c in chunks:
                pos += c
                rows.append(
                    PlanRow("chunk", req, pos - c, c, final=pos >= req.prefill_target)
                )
            if not chunks and pos >= req.prefill_target:
                # fully prefix-matched resumed context: nothing to feed, the
                # table just publishes (a zero-width row the engine masks out)
                rows.append(PlanRow("chunk", req, pos, 0, final=True))
            budget -= take
        return rows

    def plan(self, *, spec: bool = False) -> StepPlan:
        """One scheduling pass for the fused engine: admission + restores as
        ``schedule()``, then ONE ``StepPlan`` of typed rows instead of
        imperative per-chunk model calls.  Decoding slots become ``decode``
        rows (or ``verify`` rows when ``spec``); the prefill budget becomes
        ``chunk`` rows.  The engine owns applying the plan's side effects."""
        self._admit()
        self._restore()
        rows = [
            PlanRow("verify" if spec else "decode", r)
            for r in self.ops.running()
            if not r.prefilling
        ]
        rows += self._plan_prefill()
        return StepPlan(rows)
