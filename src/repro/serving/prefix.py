"""Prefix cache: content-addressed index over paged KV blocks.

Shared system prompts dominate interactive serving traffic (every request in
a deployment carries the same instruction header), yet a naive engine
re-prefills that prefix per request.  This module lets admission *reuse* the
K/V blocks of any previously-prefilled prompt prefix:

* Every **full, token-aligned** block of a prefilled prompt is registered
  under a chain hash ``h_i = H(h_{i-1}, tokens[i*bs:(i+1)*bs])`` — the hash
  commits to the whole prefix, not just the block's own tokens, so two
  prompts share a block only when *everything before it* matches too.
  Token tuples are stored alongside and compared on lookup, so a Python
  hash collision can never alias two different prefixes.
* ``match`` walks a new prompt's chain as far as it stays indexed, then
  looks at the *children* of the last matched node for a block whose tokens
  extend the prompt partially — the *partial tail* case.  Full-block hits
  are shared by refcount (copy never happens: full prompt blocks are
  write-once); a partial hit is **copy-on-write** — the caller copies the
  cached block's K/V rows into a freshly-allocated private block and
  overwrites from the divergence point.
* Matching is capped at ``len(prompt) - 1`` tokens: at least one suffix
  token must run through the model so admission has logits to sample the
  first generated token from.
* The index keys on *tokens and block ids only* — under tensor-parallel
  serving the pools are head-sharded but block ids stay device-invariant,
  so one replicated host-side index serves the whole mesh unchanged
  (counters are asserted mesh-invariant in ``tests/test_sharded_serving.py``).

Lifecycle is refcount-driven (``serving.paged.BlockAllocator``): a matched
block gains one reference per sharer; ``release`` routes indexed blocks to
the allocator's LRU cached pool instead of the free list, so a prefix stays
matchable after its last user finishes and is only evicted (``on_evict``
unmaps it here) when an allocation actually needs the space.  Evicting a
parent can strand still-cached children — they become unreachable for
matching (walks start at the root) and simply age out of the LRU.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Optional

from repro.serving.paged import BlockAllocator

_ROOT = 0  # chain-hash seed


def chain_hash(parent: int, tokens: tuple[int, ...]) -> int:
    return hash((parent, tokens))


def routing_key(prompt: list[int], block_size: int) -> int:
    """Coarse affinity key for a prompt: the chain hash of its first block
    (short prompts hash whatever they have).

    Two prompts share cached blocks only if their chains agree from the
    root, and the chain's first link is exactly this value — so a router
    that keeps requests with equal keys on one replica keeps every
    same-system-prompt burst where its blocks are, even before the first
    request of the burst has prefilled anything the index could ``match``.
    """
    return chain_hash(_ROOT, tuple(prompt[: min(block_size, len(prompt))]))


class PartialHit(NamedTuple):
    block: int  # cached physical block to copy-on-write from
    tokens: int  # leading tokens of that block shared with the prompt


@dataclass
class _Entry:
    hash: int
    parent: int
    tokens: tuple[int, ...]


@dataclass
class PrefixIndex:
    allocator: BlockAllocator
    block_size: int
    by_hash: dict[int, int] = field(default_factory=dict)  # chain hash -> block
    meta: dict[int, _Entry] = field(default_factory=dict)  # block -> entry
    children: dict[int, list[int]] = field(default_factory=dict)  # parent hash -> blocks
    registered: int = 0
    _metrics: Optional[object] = field(default=None, repr=False)

    def __post_init__(self):
        self.allocator.on_evict = self._on_evict

    def attach_metrics(self, registry) -> None:
        """Publish index size and registration volume into a
        ``serving.metrics`` registry."""
        self._metrics = registry
        self._m_entries = registry.gauge("prefix_entries", "indexed (matchable) prefix blocks")
        self._m_registered = registry.counter("prefix_registrations_total", "blocks ever indexed")
        self._m_entries.set(len(self.by_hash))

    def _publish(self) -> None:
        if self._metrics is not None:
            self._m_entries.set(len(self.by_hash))

    def __len__(self) -> int:
        return len(self.by_hash)

    # -- lookup --------------------------------------------------------
    def _lookup(self, parent: int, tokens: tuple[int, ...]) -> Optional[int]:
        h = chain_hash(parent, tokens)
        b = self.by_hash.get(h)
        if b is None:
            return None
        ent = self.meta[b]
        # verify: chain hashes are Python hashes, not cryptographic
        if ent.parent != parent or ent.tokens != tokens:
            return None
        return b

    def match(self, prompt: list[int]) -> tuple[list[int], Optional[PartialHit]]:
        """Longest indexed prefix of ``prompt``: (full blocks, partial tail).

        Pure lookup — takes no references; call ``acquire`` on the returned
        blocks (and the partial source, around the COW copy) to pin them.
        Never matches past ``len(prompt) - 1`` tokens.
        """
        bs = self.block_size
        limit = len(prompt) - 1  # leave >= 1 token to prefill
        blocks: list[int] = []
        parent = _ROOT
        i = 0
        while i + bs <= limit:
            blk = self._lookup(parent, tuple(prompt[i : i + bs]))
            if blk is None:
                break
            blocks.append(blk)
            parent = self.meta[blk].hash
            i += bs
        partial = None
        best = 0
        rem = prompt[i:limit]
        if rem:
            for cand in self.children.get(parent, ()):
                toks = self.meta[cand].tokens
                n = 0
                while n < len(rem) and n < len(toks) and toks[n] == rem[n]:
                    n += 1
                if n > best:
                    best, partial = n, PartialHit(cand, n)
        return blocks, partial

    def match_tokens(self, prompt: list[int]) -> int:
        """Tokens of ``prompt`` a ``match`` would serve from cache — a pure
        peek (no references taken), used by the router to score replicas."""
        full, partial = self.match(prompt)
        return len(full) * self.block_size + (partial.tokens if partial else 0)

    # -- reference management -------------------------------------------
    def acquire(self, blocks: list[int]) -> None:
        """Pin matched blocks: revive cached (refcount-0) entries, add a
        reference to live ones."""
        for b in blocks:
            if self.allocator.is_cached(b):
                self.allocator.reuse_cached(b)
            else:
                self.allocator.incref(b)

    def release(self, blocks: list[int]) -> None:
        """Drop one reference per block; indexed blocks park in the LRU
        cached pool (still matchable), unindexed ones free eagerly."""
        cached = [b for b in blocks if b in self.meta]
        plain = [b for b in blocks if b not in self.meta]
        if cached:
            self.allocator.free_cached(cached)
        if plain:
            self.allocator.free(plain)

    def parent_hash(self, blocks: list[int]) -> int:
        """Chain state after the given indexed prefix blocks (root if
        empty) — seed for incremental ``register`` calls."""
        return self.meta[blocks[-1]].hash if blocks else _ROOT

    # -- registration / eviction ----------------------------------------
    def register(
        self,
        prompt: list[int],
        blocks: list[int],
        upto: int,
        *,
        start_block: int = 0,
        parent: int = _ROOT,
    ) -> tuple[int, int]:
        """Index the full blocks of ``prompt[:upto]`` (already written to
        ``blocks``).  Idempotent; a hash already mapping to a *different*
        block keeps the first mapping (the newcomer keeps a private copy).

        ``start_block``/``parent`` resume the chain walk where a previous
        call left off, so per-chunk registration costs only the newly
        completed blocks instead of re-hashing the whole prefix; returns the
        updated ``(start_block, parent)`` pair to pass next time."""
        bs = self.block_size
        for j in range(start_block, min(upto, len(prompt)) // bs):
            toks = tuple(prompt[j * bs : (j + 1) * bs])
            h = chain_hash(parent, toks)
            b = blocks[j]
            if h not in self.by_hash and b not in self.meta:
                self.by_hash[h] = b
                self.meta[b] = _Entry(hash=h, parent=parent, tokens=toks)
                self.children.setdefault(parent, []).append(b)
                self.registered += 1
                if self._metrics is not None:
                    self._m_registered.inc()
            parent = h
            start_block = j + 1
        self._publish()
        return start_block, parent

    def _on_evict(self, block: int) -> None:
        ent = self.meta.pop(block, None)
        if ent is None:
            return
        if self.by_hash.get(ent.hash) == block:
            del self.by_hash[ent.hash]
        sibs = self.children.get(ent.parent)
        if sibs and block in sibs:
            sibs.remove(block)
            if not sibs:
                del self.children[ent.parent]
        self._publish()

    def stats(self) -> dict:
        return {"entries": len(self.by_hash), "registered": self.registered}
