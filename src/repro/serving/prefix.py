"""Prefix cache: content-addressed index over paged KV blocks.

Shared system prompts dominate interactive serving traffic (every request in
a deployment carries the same instruction header), yet a naive engine
re-prefills that prefix per request.  This module lets admission *reuse* the
K/V blocks of any previously-prefilled prompt prefix:

* Every **full, token-aligned** block of a prefilled prompt is registered
  under a chain hash ``h_i = H(h_{i-1}, tokens[i*bs:(i+1)*bs])`` — the hash
  commits to the whole prefix, not just the block's own tokens, so two
  prompts share a block only when *everything before it* matches too.
  Token tuples are stored alongside and compared on lookup, so a Python
  hash collision can never alias two different prefixes.
* ``match`` walks a new prompt's chain as far as it stays indexed, then
  looks at the *children* of the last matched node for a block whose tokens
  extend the prompt partially — the *partial tail* case.  Full-block hits
  are shared by refcount (copy never happens: full prompt blocks are
  write-once); a partial hit is **copy-on-write** — the caller copies the
  cached block's K/V rows into a freshly-allocated private block and
  overwrites from the divergence point.
* Matching is capped at ``len(prompt) - 1`` tokens: at least one suffix
  token must run through the model so admission has logits to sample the
  first generated token from.
* The index keys on *tokens and block ids only* — under tensor-parallel
  serving the pools are head-sharded but block ids stay device-invariant,
  so one replicated host-side index serves the whole mesh unchanged
  (counters are asserted mesh-invariant in ``tests/test_sharded_serving.py``).

Lifecycle is refcount-driven (``serving.paged.BlockAllocator``): a matched
block gains one reference per sharer; ``release`` routes indexed blocks to
the allocator's LRU cached pool instead of the free list, so a prefix stays
matchable after its last user finishes and is only evicted (``on_evict``
fires here) when an allocation actually needs the space.

**Tiers**: with a ``serving.spill.SpillPool`` attached (``attach_spill``),
eviction *demotes* instead of dropping — the block's K/V rows move to host
RAM and the entry is re-keyed under the pool's negative **spill handle**
(``is_spilled``), staying fully matchable: ``match`` walks chains through
mixed device/spilled entries unchanged.  A hit on a spilled entry is
``promote``d back to a freshly-allocated device block (the engine swaps the
rows in asynchronously); a cancelled swap-in is ``demote``d back.  Without
a pool (or when the pool refuses), eviction drops the entry — and runs the
**stranding cascade**: dropping a parent makes every descendant unreachable
for matching (walks start at the root), so ``_drop_entry`` unmaps the whole
subtree, discards spilled descendants from the pool and returns cached
device descendants to the free list (``BlockAllocator.uncache``) instead of
letting unreachable-but-resident blocks leak LRU capacity.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, NamedTuple, Optional

from repro.serving.paged import BlockAllocator

_ROOT = 0  # chain-hash seed


def is_spilled(block: int) -> bool:
    """Tier tag of an index id: physical device blocks are >= 1 (0 is the
    null block); spill handles are negative (``SpillPool`` counts down)."""
    return block < 0


def chain_hash(parent: int, tokens: tuple[int, ...]) -> int:
    return hash((parent, tokens))


def routing_key(prompt: list[int], block_size: int) -> int:
    """Coarse affinity key for a prompt: the chain hash of its first block
    (short prompts hash whatever they have).

    Two prompts share cached blocks only if their chains agree from the
    root, and the chain's first link is exactly this value — so a router
    that keeps requests with equal keys on one replica keeps every
    same-system-prompt burst where its blocks are, even before the first
    request of the burst has prefilled anything the index could ``match``.
    """
    return chain_hash(_ROOT, tuple(prompt[: min(block_size, len(prompt))]))


class PartialHit(NamedTuple):
    block: int  # cached physical block to copy-on-write from
    tokens: int  # leading tokens of that block shared with the prompt


@dataclass
class _Entry:
    hash: int
    parent: int
    tokens: tuple[int, ...]


@dataclass
class PrefixIndex:
    allocator: BlockAllocator
    block_size: int
    by_hash: dict[int, int] = field(default_factory=dict)  # chain hash -> block
    meta: dict[int, _Entry] = field(default_factory=dict)  # block -> entry
    children: dict[int, list[int]] = field(default_factory=dict)  # parent hash -> blocks
    registered: int = 0
    spill: Optional[object] = field(default=None, repr=False)  # serving.spill.SpillPool
    _fetch: Optional[Callable[[int], dict]] = field(default=None, repr=False)
    spilled: int = 0  # entries demoted to the host tier
    promoted: int = 0  # spilled entries rewired back to device blocks
    demoted: int = 0  # cancelled swap-ins re-parked in the pool
    stranded_dropped: int = 0  # descendants unmapped by the cascade
    _metrics: Optional[object] = field(default=None, repr=False)

    def __post_init__(self):
        self.allocator.on_evict = self._on_evict

    def attach_spill(self, pool, fetch: Callable[[int], dict]) -> None:
        """Enable the host spill tier: ``pool`` holds demoted rows, ``fetch``
        (engine-provided) gathers one device block's K/V rows at evict time.
        The pool's own byte-budget drops cascade back through this index."""
        self.spill = pool
        self._fetch = fetch
        pool.on_drop = self._drop_entry

    def attach_metrics(self, registry) -> None:
        """Publish index size and registration volume into a
        ``serving.metrics`` registry."""
        self._metrics = registry
        self._m_entries = registry.gauge("prefix_entries", "indexed (matchable) prefix blocks")
        self._m_registered = registry.counter("prefix_registrations_total", "blocks ever indexed")
        self._m_entries.set(len(self.by_hash))

    def _publish(self) -> None:
        if self._metrics is not None:
            self._m_entries.set(len(self.by_hash))

    def __len__(self) -> int:
        return len(self.by_hash)

    # -- lookup --------------------------------------------------------
    def _lookup(self, parent: int, tokens: tuple[int, ...]) -> Optional[int]:
        h = chain_hash(parent, tokens)
        b = self.by_hash.get(h)
        if b is None:
            return None
        ent = self.meta[b]
        # verify: chain hashes are Python hashes, not cryptographic
        if ent.parent != parent or ent.tokens != tokens:
            return None
        return b

    def match(self, prompt: list[int]) -> tuple[list[int], Optional[PartialHit]]:
        """Longest indexed prefix of ``prompt``: (full blocks, partial tail).

        Pure lookup — takes no references; call ``acquire`` on the returned
        blocks (and the partial source, around the COW copy) to pin them.
        Never matches past ``len(prompt) - 1`` tokens.
        """
        bs = self.block_size
        limit = len(prompt) - 1  # leave >= 1 token to prefill
        blocks: list[int] = []
        parent = _ROOT
        i = 0
        while i + bs <= limit:
            blk = self._lookup(parent, tuple(prompt[i : i + bs]))
            if blk is None:
                break
            blocks.append(blk)
            parent = self.meta[blk].hash
            i += bs
        partial = None
        best = 0
        rem = prompt[i:limit]
        if rem:
            for cand in self.children.get(parent, ()):
                toks = self.meta[cand].tokens
                n = 0
                while n < len(rem) and n < len(toks) and toks[n] == rem[n]:
                    n += 1
                if n > best:
                    best, partial = n, PartialHit(cand, n)
        return blocks, partial

    def match_tokens(self, prompt: list[int]) -> int:
        """Tokens of ``prompt`` a ``match`` would serve from cache — a pure
        peek (no references taken), used by the router to score replicas."""
        full, partial = self.match(prompt)
        return len(full) * self.block_size + (partial.tokens if partial else 0)

    # -- reference management -------------------------------------------
    def acquire(self, blocks: list[int]) -> None:
        """Pin matched blocks: revive cached (refcount-0) entries, add a
        reference to live ones."""
        for b in blocks:
            if self.allocator.is_cached(b):
                self.allocator.reuse_cached(b)
            else:
                self.allocator.incref(b)

    def release(self, blocks: list[int]) -> None:
        """Drop one reference per block; indexed blocks park in the LRU
        cached pool (still matchable), unindexed ones free eagerly."""
        cached = [b for b in blocks if b in self.meta]
        plain = [b for b in blocks if b not in self.meta]
        if cached:
            self.allocator.free_cached(cached)
        if plain:
            self.allocator.free(plain)

    def parent_hash(self, blocks: list[int]) -> int:
        """Chain state after the given indexed prefix blocks (root if
        empty) — seed for incremental ``register`` calls."""
        return self.meta[blocks[-1]].hash if blocks else _ROOT

    # -- registration / eviction ----------------------------------------
    def register(
        self,
        prompt: list[int],
        blocks: list[int],
        upto: int,
        *,
        start_block: int = 0,
        parent: int = _ROOT,
    ) -> tuple[int, int]:
        """Index the full blocks of ``prompt[:upto]`` (already written to
        ``blocks``).  Idempotent; a hash already mapping to a *different*
        block keeps the first mapping (the newcomer keeps a private copy).

        ``start_block``/``parent`` resume the chain walk where a previous
        call left off, so per-chunk registration costs only the newly
        completed blocks instead of re-hashing the whole prefix; returns the
        updated ``(start_block, parent)`` pair to pass next time."""
        bs = self.block_size
        for j in range(start_block, min(upto, len(prompt)) // bs):
            toks = tuple(prompt[j * bs : (j + 1) * bs])
            h = chain_hash(parent, toks)
            b = blocks[j]
            if h not in self.by_hash and b not in self.meta:
                self.by_hash[h] = b
                self.meta[b] = _Entry(hash=h, parent=parent, tokens=toks)
                self.children.setdefault(parent, []).append(b)
                self.registered += 1
                if self._metrics is not None:
                    self._m_registered.inc()
            parent = h
            start_block = j + 1
        self._publish()
        return start_block, parent

    def _rekey(self, old: int, new: int, ent: _Entry) -> None:
        """Move an entry between ids (device block <-> spill handle) without
        touching the chain structure: hash map, meta and the parent's child
        list all follow; entries keyed by *hash* (children of this entry)
        are untouched — descendants stay reachable through the chain walk."""
        del self.meta[old]
        self.meta[new] = ent
        self.by_hash[ent.hash] = new
        sibs = self.children.get(ent.parent)
        if sibs and old in sibs:
            sibs[sibs.index(old)] = new

    def _on_evict(self, block: int) -> Optional[str]:
        """Allocator LRU eviction: demote the entry to the spill tier when a
        pool is attached and admits it, else drop it (with the stranding
        cascade).  The returned tier tag feeds the allocator's accounting."""
        ent = self.meta.get(block)
        if ent is None:
            return None
        if self.spill is not None and self._fetch is not None:
            handle = self.spill.put(self._fetch(block))
            if handle is not None:
                if block not in self.meta:
                    # reentrancy: the put's own byte-budget drop cascaded
                    # through an *ancestor* of this entry mid-spill, so the
                    # chain above it is gone and the rows are unmatchable —
                    # discard them rather than strand them in the pool
                    self.spill.discard(handle)
                    return "dropped"
                self._rekey(block, handle, ent)
                self.spilled += 1
                self._publish()
                return "spilled"
        self._drop_entry(block)
        return "dropped"

    def promote(self, handle: int, block: int) -> None:
        """Rewire a spilled entry onto a freshly-allocated device block (the
        caller has popped the rows from the pool and owns the swap-in)."""
        self._rekey(handle, block, self.meta[handle])
        self.promoted += 1
        self._publish()

    def demote(self, block: int, payload: dict) -> None:
        """Inverse of ``promote`` for a cancelled swap-in: re-park the rows
        in the pool and re-key the entry back to a spill handle.  When the
        pool refuses, the entry drops (the device block was never written,
        so it must not stay indexed — a later match would read garbage)."""
        ent = self.meta.get(block)
        if ent is None:
            return
        handle = self.spill.put(payload) if self.spill is not None else None
        if handle is None:
            self._drop_entry(block)
            return
        if block not in self.meta:
            # same reentrancy guard as ``_on_evict``: the put's budget drop
            # cascaded through an ancestor and already unmapped this entry
            self.spill.discard(handle)
            return
        self._rekey(block, handle, ent)
        self.demoted += 1
        self._publish()

    def _drop_entry(self, bid: int) -> None:
        """Unmap one entry and cascade over its now-unreachable descendants
        (matching always walks from the root, so a dropped parent strands
        its whole subtree): spilled descendants leave the pool, cached
        refcount-0 device descendants return to the free list
        (``uncache``), live ones are merely unindexed — their eventual
        release plain-frees them.  Also the ``SpillPool.on_drop`` hook."""
        ent = self.meta.pop(bid, None)
        if ent is None:
            return
        if self.by_hash.get(ent.hash) == bid:
            del self.by_hash[ent.hash]
        sibs = self.children.get(ent.parent)
        if sibs and bid in sibs:
            sibs.remove(bid)
            if not sibs:
                del self.children[ent.parent]
        for child in list(self.children.get(ent.hash, ())):
            self.stranded_dropped += 1
            if is_spilled(child):
                if self.spill is not None:
                    self.spill.discard(child)
            elif self.allocator.is_cached(child):
                self.allocator.uncache(child)
            self._drop_entry(child)
        self._publish()

    def stats(self) -> dict:
        spilled_entries = sum(1 for b in self.meta if is_spilled(b))
        return {
            "entries": len(self.by_hash),
            "device_entries": len(self.by_hash) - spilled_entries,
            "spilled_entries": spilled_entries,
            "registered": self.registered,
            "spilled": self.spilled,
            "promoted": self.promoted,
            "demoted": self.demoted,
            "stranded_dropped": self.stranded_dropped,
        }
