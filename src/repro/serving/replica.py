"""One serving replica: an ``InferenceEngine`` plus health/fault state.

A replica is the router's unit of capacity and of failure — one engine on
its own ``(data=1, model=tp)`` device slice (``launch.mesh
.make_replica_meshes``), stepped by the router, mirroring one Grace-Hopper
node of the paper's 1,362.  The wrapper owns exactly the state the seed
cluster model (``core/cluster.py``) keeps per node, translated to serving:

* a **heartbeat timestamp**, refreshed after every successfully executed
  step; the router's sweep turns heartbeat age into SUSPECT (routed around)
  or UNHEALTHY (failed over) exactly like ``Cluster.sweep_heartbeats``
  turns it into SUSPECT/FAILED;
* a **lifecycle state** — HEALTHY → SUSPECT ⇄ HEALTHY, DRAINING (admission
  stopped, work finishing or migrating), UNHEALTHY/DEAD (failed over),
  RETIRED (drained clean and removed from rotation);
* a **fault plan** (``serving.faults.FaultPlan``) evaluated on the
  replica's own step counter, so chaos runs replay deterministically.

``step()`` is the only execution entry: a crash step raises
``ReplicaCrashed`` *before* touching the engine (no partial-step tokens —
the router's committed-token failover accounting stays exact), a hang step
does nothing and skips the heartbeat, and a slow window heartbeats only
every ``slow_every``-th step so the router sees a straggler, not a corpse.
"""

from __future__ import annotations

import time
from enum import Enum
from typing import Callable, Optional

from repro.serving.engine import InferenceEngine
from repro.serving.faults import FaultPlan, ReplicaCrashed


class ReplicaState(Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"  # heartbeat stale: routed around, not failed over
    DRAINING = "draining"  # admission stopped; finishing or migrating work
    UNHEALTHY = "unhealthy"  # heartbeat dead: failed over
    DEAD = "dead"  # crashed: failed over
    RETIRED = "retired"  # drained clean and removed from rotation


#: states a replica can still execute steps in
LIVE_STATES = (ReplicaState.HEALTHY, ReplicaState.SUSPECT, ReplicaState.DRAINING)


class Replica:
    """One engine behind the router, with heartbeat + fault bookkeeping."""

    def __init__(
        self,
        replica_id: int,
        engine: InferenceEngine,
        *,
        clock: Optional[Callable[[], float]] = None,
        fault_plan: Optional[FaultPlan] = None,
    ):
        if replica_id < 0:
            raise ValueError(f"replica_id={replica_id} (need >= 0)")
        self.id = replica_id
        self.engine = engine
        self.fault = fault_plan if fault_plan is not None else FaultPlan()
        self._clock = clock if clock is not None else time.monotonic
        self.state = ReplicaState.HEALTHY
        self.steps = 0
        self.last_heartbeat = self._clock()
        self.failovers_in = 0  # requests adopted from failed peers

    # -- routing predicates --------------------------------------------
    @property
    def alive(self) -> bool:
        """Can still execute steps (healthy, suspect or draining)."""
        return self.state in LIVE_STATES

    @property
    def admittable(self) -> bool:
        """Can accept new or failed-over requests.  SUSPECT stays
        admittable as a last resort — the router prefers HEALTHY peers but
        a straggler beats a 503."""
        return self.state in (ReplicaState.HEALTHY, ReplicaState.SUSPECT)

    def heartbeat_age(self, now: float) -> float:
        return now - self.last_heartbeat

    @property
    def load(self) -> int:
        """Queued + slotted requests — the router's load-balance score."""
        eng = self.engine
        return len(eng.queue) + sum(r is not None for r in eng.slots)

    # -- execution ------------------------------------------------------
    def step(self) -> int:
        """Run one engine step under the fault plan; returns tokens
        produced.  Raises ``ReplicaCrashed`` on a crash step (state moves
        to DEAD first, so the raise is observable but the replica is
        already out of rotation)."""
        k = self.steps
        self.steps += 1
        if self.fault.crashes_at(k):
            self.state = ReplicaState.DEAD
            raise ReplicaCrashed(f"replica {self.id} crashed at step {k} (injected)")
        if self.fault.hangs_at(k):
            return 0  # wedged: no work, no heartbeat — the sweep notices
        produced = self.engine.step()
        if not self.fault.slow_at(k) or k % self.fault.slow_every == 0:
            self.last_heartbeat = self._clock()
        return produced

    def __repr__(self) -> str:
        return (
            f"Replica(id={self.id}, state={self.state.value}, "
            f"steps={self.steps}, load={self.load})"
        )
