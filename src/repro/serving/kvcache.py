"""KV-cache management: prefill -> ring-buffered decode cache, slot surgery.

``decode_cache_from_prefill`` converts the full-length K/V returned by
``models.prefill`` into the fixed-size ring-buffer cache the decode step
consumes (sliding-window archs keep only the last W tokens; the ring-slot
invariant is slot = pos % W).

``write_request_into_slot`` grafts a single request's cache into one batch
slot of the engine's persistent cache — the core mutation of continuous
batching.  Batch-dim discovery is driven by the cache's logical axes
("kv_batch"), so the same code serves dense KV caches, RWKV states, hybrid
conv/SSM states and VLM grouped caches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.cache import (
    cache_window,
    init_cache,
    stacked_cache_axes,
)


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def _ring_kv(full: jax.Array, seq_filled: int, W: int):
    """full: (L, B, S, KV, hd) -> ring (L, B, W, KV, hd) + pos (B, W)."""
    L, B, S = full.shape[:3]
    start = max(seq_filled - W, 0)
    idx = jnp.arange(start, start + min(W, seq_filled))
    slots = idx % W
    ring = jnp.zeros((L, B, W) + full.shape[3:], full.dtype)
    ring = ring.at[:, :, slots].set(full[:, :, idx])
    pos = jnp.full((B, W), -1, jnp.int32)
    pos = pos.at[:, slots].set(idx.astype(jnp.int32))
    return ring, pos


def decode_cache_from_prefill(cfg, raw_cache, *, seq_filled: int, decode_len: int):
    """Build the decode cache from prefill output.

    decode_len: total positions the decode cache must address (>= seq_filled +
    new tokens for full-attention archs; ignored by constant-state families).
    """
    fam = cfg.family
    W = cache_window(cfg, decode_len)
    if fam in ("dense", "moe"):
        k, pos = _ring_kv(raw_cache["k"], seq_filled, W)
        v, _ = _ring_kv(raw_cache["v"], seq_filled, W)
        return {"k": k, "v": v, "pos": _layer_pos(pos, k.shape[0])}
    if fam == "ssm":
        return dict(raw_cache)  # states pass through (O(1) decode)
    if fam == "hybrid":
        k, pos = _ring_kv(raw_cache["k"], seq_filled, W)
        v, _ = _ring_kv(raw_cache["v"], seq_filled, W)
        return {
            "k": k,
            "v": v,
            "pos": _layer_pos(pos, k.shape[0]),
            "conv": raw_cache["conv"],
            "ssm": raw_cache["ssm"],
        }
    if fam == "vlm":
        sk = raw_cache["self"]["k"]  # (G, g, B, S, KV, hd)
        G, g = sk.shape[:2]
        flat_k = sk.reshape((G * g,) + sk.shape[2:])
        flat_v = raw_cache["self"]["v"].reshape((G * g,) + sk.shape[2:])
        rk, pos = _ring_kv(flat_k, seq_filled, W)
        rv, _ = _ring_kv(flat_v, seq_filled, W)
        return {
            "self": {
                "k": rk.reshape((G, g) + rk.shape[1:]),
                "v": rv.reshape((G, g) + rv.shape[1:]),
                "pos": jnp.broadcast_to(pos, (G, g) + pos.shape),
            },
            "cross": raw_cache["cross"],
        }
    raise ValueError(fam)


def _layer_pos(pos: jax.Array, L: int) -> jax.Array:
    """Broadcast the (B, W) position buffer across the L stacked layers."""
    return jnp.broadcast_to(pos[None], (L,) + pos.shape)


# ---------------------------------------------------------------------------
# continuous-batching slot surgery
# ---------------------------------------------------------------------------


def batch_dim_of(axes: tuple) -> int | None:
    for i, a in enumerate(axes):
        if a == "kv_batch":
            return i
    return None


def write_request_into_slot(cfg, engine_cache, request_cache, slot: int):
    """Graft a (batch=1) request cache into batch slot ``slot``."""
    axes = stacked_cache_axes(cfg)

    def graft(ax, full, one):
        b = batch_dim_of(ax)
        if b is None:
            return full
        idx = [slice(None)] * full.ndim
        idx[b] = slot
        return full.at[tuple(idx)].set(jnp.take(one, 0, axis=b).astype(full.dtype))

    return jax.tree.map(graft, axes, engine_cache, request_cache, is_leaf=_is_axes)


def clear_slot(cfg, engine_cache, slot: int):
    """Reset one batch slot (freed request): zeros, pos -> -1."""
    axes = stacked_cache_axes(cfg)

    def wipe(path_ax, leaf):
        ax = path_ax
        b = batch_dim_of(ax)
        if b is None:
            return leaf
        idx = [slice(None)] * leaf.ndim
        idx[b] = slot
        fill = -1 if ax[-1] == "kv_seq" and leaf.dtype == jnp.int32 else 0
        return leaf.at[tuple(idx)].set(jnp.full(leaf[tuple(idx)].shape, fill, leaf.dtype))

    return jax.tree.map(wipe, axes, engine_cache, is_leaf=_is_axes)


def make_engine_cache(cfg, max_batch: int, max_seq: int, dtype=jnp.bfloat16):
    return init_cache(cfg, max_batch, max_seq, dtype)
