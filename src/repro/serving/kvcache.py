"""KV-cache management: prefill -> decode cache, slot and block-table surgery.

Dense layout: ``decode_cache_from_prefill`` converts the full-length K/V
returned by ``models.prefill`` into the fixed-size ring-buffer cache the
decode step consumes (sliding-window archs keep only the last W tokens; the
ring-slot invariant is slot = pos % W), and ``write_request_into_slot``
grafts a single request's cache into one batch slot of the engine's
persistent cache.  Batch-dim discovery is driven by the cache's logical axes
("kv_batch"), so the same code serves dense KV caches, RWKV states, hybrid
conv/SSM states and VLM grouped caches.

Paged layout: ``graft_prefill_into_blocks`` scatters the prompt's K/V into
the physical blocks a request was allocated (quantizing on the way in for
int8 pools), ``copy_block_rows`` is the copy-on-write step behind partial
prefix hits, ``truncate_block_rows`` zeroes a rejected speculative tail,
and ``clear_block_row`` resets a freed slot's table row to the null block —
graft/COW/truncate/clear become block-table ops instead of cache-line
copies, which is exactly why freeing a paged request is O(blocks) metadata
instead of an O(max_seq) wipe.

Paged layout invariants (shared with ``models.cache`` and the
``kernels.paged_attention*`` consumers):

* Pools are stacked ``(L, num_blocks, block_size, kv_heads, head_dim)``;
  logical position ``t`` of a request lives in physical block
  ``tbl_row[t // block_size]`` at offset ``t % block_size``.
* **Null rows** — table entries are ``NULL_BLOCK`` (0) wherever a slot owns
  no block: inactive slots, mid-prefill slots (published only when the
  prompt completes), and window-reclaimed leading blocks.  Writes through a
  null entry land in the reserved scratch block; reads through it are
  position-masked.
* **Quantized pools** — ``quantize_kv`` stores ``k``/``v`` as int8 with
  per-(token, head) fp32 scales in sibling ``k_scale``/``v_scale`` leaves
  of shape ``(L, num_blocks, block_size, kv_heads, 1)``; every op here
  that moves K/V rows moves the scale rows with them.
* Rows past a request's committed position are never attended (causal /
  window masks key on positions), so stale content after truncation is a
  hygiene concern, not a correctness one — the ops still zero it so COW
  copies and int8 scale reads stay canonical.
* Every op here indexes blocks/rows along the **unsharded** pool dims
  (block id, block offset, batch slot) and treats heads as payload, so
  under tensor-parallel serving (pools head-sharded, tables replicated)
  graft / COW / truncate partition trivially via GSPMD — the engine pins
  their jitted outputs to the cache's ``NamedSharding`` tree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.cache import (
    NULL_BLOCK,
    cache_window,
    init_cache,
    stacked_cache_axes,
)


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def _ring_kv(full: jax.Array, seq_filled: int, W: int):
    """full: (L, B, S, KV, hd) -> ring (L, B, W, KV, hd) + pos (B, W)."""
    L, B, S = full.shape[:3]
    start = max(seq_filled - W, 0)
    idx = jnp.arange(start, start + min(W, seq_filled))
    slots = idx % W
    ring = jnp.zeros((L, B, W) + full.shape[3:], full.dtype)
    ring = ring.at[:, :, slots].set(full[:, :, idx])
    pos = jnp.full((B, W), -1, jnp.int32)
    pos = pos.at[:, slots].set(idx.astype(jnp.int32))
    return ring, pos


def decode_cache_from_prefill(cfg, raw_cache, *, seq_filled: int, decode_len: int):
    """Build the decode cache from prefill output.

    decode_len: total positions the decode cache must address (>= seq_filled +
    new tokens for full-attention archs; ignored by constant-state families).
    """
    fam = cfg.family
    W = cache_window(cfg, decode_len)
    if fam in ("dense", "moe"):
        k, pos = _ring_kv(raw_cache["k"], seq_filled, W)
        v, _ = _ring_kv(raw_cache["v"], seq_filled, W)
        return {"k": k, "v": v, "pos": _layer_pos(pos, k.shape[0])}
    if fam == "ssm":
        return dict(raw_cache)  # states pass through (O(1) decode)
    if fam == "hybrid":
        k, pos = _ring_kv(raw_cache["k"], seq_filled, W)
        v, _ = _ring_kv(raw_cache["v"], seq_filled, W)
        return {
            "k": k,
            "v": v,
            "pos": _layer_pos(pos, k.shape[0]),
            "conv": raw_cache["conv"],
            "ssm": raw_cache["ssm"],
        }
    if fam == "vlm":
        sk = raw_cache["self"]["k"]  # (G, g, B, S, KV, hd)
        G, g = sk.shape[:2]
        flat_k = sk.reshape((G * g,) + sk.shape[2:])
        flat_v = raw_cache["self"]["v"].reshape((G * g,) + sk.shape[2:])
        rk, pos = _ring_kv(flat_k, seq_filled, W)
        rv, _ = _ring_kv(flat_v, seq_filled, W)
        return {
            "self": {
                "k": rk.reshape((G, g) + rk.shape[1:]),
                "v": rv.reshape((G, g) + rv.shape[1:]),
                "pos": jnp.broadcast_to(pos, (G, g) + pos.shape),
            },
            "cross": raw_cache["cross"],
        }
    raise ValueError(fam)


def _layer_pos(pos: jax.Array, L: int) -> jax.Array:
    """Broadcast the (B, W) position buffer across the L stacked layers."""
    return jnp.broadcast_to(pos[None], (L,) + pos.shape)


# ---------------------------------------------------------------------------
# continuous-batching slot surgery
# ---------------------------------------------------------------------------


def batch_dim_of(axes: tuple) -> int | None:
    for i, a in enumerate(axes):
        if a == "kv_batch":
            return i
    return None


def write_request_into_slot(cfg, engine_cache, request_cache, slot: int):
    """Graft a (batch=1) request cache into batch slot ``slot``."""
    axes = stacked_cache_axes(cfg)

    def graft(ax, full, one):
        b = batch_dim_of(ax)
        if b is None:
            return full
        idx = [slice(None)] * full.ndim
        idx[b] = slot
        return full.at[tuple(idx)].set(jnp.take(one, 0, axis=b).astype(full.dtype))

    return jax.tree.map(graft, axes, engine_cache, request_cache, is_leaf=_is_axes)


def clear_slot(cfg, engine_cache, slot: int):
    """Reset one batch slot (freed request): zeros, pos -> -1."""
    axes = stacked_cache_axes(cfg)

    def wipe(path_ax, leaf):
        ax = path_ax
        b = batch_dim_of(ax)
        if b is None:
            return leaf
        idx = [slice(None)] * leaf.ndim
        idx[b] = slot
        fill = -1 if ax[-1] == "kv_seq" and leaf.dtype == jnp.int32 else 0
        return leaf.at[tuple(idx)].set(jnp.full(leaf[tuple(idx)].shape, fill, leaf.dtype))

    return jax.tree.map(wipe, axes, engine_cache, is_leaf=_is_axes)


def make_engine_cache(cfg, max_batch: int, max_seq: int, dtype=jnp.bfloat16):
    return init_cache(cfg, max_batch, max_seq, dtype)


# ---------------------------------------------------------------------------
# paged block-table surgery
# ---------------------------------------------------------------------------


def _scatter_prompt(pool, kv, blocks):
    """kv: (L, nb*bs, ...token dims) -> pool (L, N, bs, ...) at ``blocks``."""
    L, T = kv.shape[:2]
    nb = len(blocks)
    bs = T // nb
    tiles = kv.reshape((L, nb, bs) + kv.shape[2:])
    return pool.at[:, jnp.asarray(blocks, jnp.int32)].set(tiles.astype(pool.dtype))


def graft_prefill_into_blocks(cfg, pool_cache, raw_cache, blocks, seq_filled: int, slot: int):
    """Write a (batch=1) prefill raw cache into the allocated pool blocks.

    ``blocks``: physical block ids covering logical positions
    [0, len(blocks)*bs); positions beyond ``seq_filled`` (right-padded
    bucketed prefill, partial last block) are written as zeros — they are
    masked at attention time and overwritten by decode as the sequence grows.
    Hybrid conv/SSM states are grafted into batch slot ``slot`` of their
    slot-dense entries.  Returns the updated pool cache.
    """
    from repro.serving.kvquant import kv_quant_mode_of

    bs = pool_cache["k"].shape[2]
    span = len(blocks) * bs
    quant_mode = kv_quant_mode_of(pool_cache["k"].dtype)
    new = dict(pool_cache)
    for name in ("k", "v"):
        kv = raw_cache[name][:, 0]  # (L, S, KV, hd)
        S = kv.shape[1]
        if S < span:
            kv = jnp.pad(kv, ((0, 0), (0, span - S), (0, 0), (0, 0)))
        elif S > span:
            kv = kv[:, :span]
        # zero pad positions >= seq_filled so reused blocks never leak stale K/V
        valid = jnp.arange(span) < seq_filled
        kv = jnp.where(valid[None, :, None, None], kv, 0)
        if quant_mode is not None:
            from repro.serving.kvquant import quantize

            q, scale = quantize(kv, quant_mode)
            new[name] = _scatter_prompt(pool_cache[name], q, blocks)
            new[f"{name}_scale"] = _scatter_prompt(pool_cache[f"{name}_scale"], scale, blocks)
        else:
            new[name] = _scatter_prompt(pool_cache[name], kv, blocks)
    for state in ("conv", "ssm"):
        if state in pool_cache:
            new[state] = pool_cache[state].at[:, slot].set(
                raw_cache[state][:, 0].astype(pool_cache[state].dtype)
            )
    return new


def gather_block_rows(pool_cache, block):
    """One physical block's K/V rows (and scale rows) as a standalone dict
    of ``(L, bs, ...)`` arrays — the device side of a spill-tier demotion.

    Dispatched *at evict time*, before the allocator hands the block out for
    reuse: JAX arrays are immutable, so the gathered value pins the rows even
    though every subsequent pool update functionally overwrites that block.
    The host copy (``np.asarray`` in ``serving.spill``) is deferred through
    the pool's staging ring so the D2H transfer overlaps with decode."""
    out = {}
    for name in ("k", "v", "k_scale", "v_scale"):
        if name in pool_cache:
            out[name] = jnp.take(pool_cache[name], block, axis=1)
    return out


def restore_block_rows(pool_cache, blocks, rows):
    """Scatter previously-spilled rows back into the pools — the device side
    of a spill-tier promotion, batched: ``blocks`` is ``(n,)`` int32 target
    block ids and each ``rows`` leaf is ``(L, n, bs, ...)`` (n gathered
    payloads stacked on the block axis), so one jitted dispatch swaps in a
    whole restore budget.  Rows are cast to the pool dtype (spill
    decompression returns float; int8 pools carry their scale leaves in
    ``rows`` verbatim).  ``tbl`` and recurrent states pass through."""
    new = dict(pool_cache)
    for name, stacked in rows.items():
        leaf = pool_cache[name]  # (L, N, bs, ...)
        new[name] = leaf.at[:, blocks].set(stacked.astype(leaf.dtype))
    return new


def copy_block_rows(pool_cache, src, dst):
    """Copy one physical block's K/V (and scales) to another block: the
    copy-on-write step behind partial prefix hits.  A request that shares
    only the leading tokens of a cached block gets the block's rows copied
    into a private block, then overwrites from the divergence point — the
    cached original stays immutable for its other sharers.  ``src``/``dst``
    are scalar physical block ids; ``tbl`` and slot-dense recurrent states
    pass through untouched."""
    new = dict(pool_cache)
    for name in ("k", "v", "k_scale", "v_scale"):
        if name in pool_cache:
            leaf = pool_cache[name]  # (L, N, bs, ...)
            new[name] = leaf.at[:, dst].set(jnp.take(leaf, src, axis=1))
    return new


def truncate_block_rows(pool_cache, tbl, start, end, *, span: int):
    """Zero the K/V (and scale) rows for logical positions [start, end) of
    every batch slot at once — the speculative-decoding rollback.

    A verify pass writes the whole draft window's K/V into each request's
    blocks *before* accept/reject; rejected positions must not linger as
    live-looking rows (attention masks them by position, but zeroing keeps
    the pool canonical for copy-on-write block copies and int8 scale reads).

    ``tbl``: (B, nb) int32 block table; ``start``/``end``: (B,) int32
    per-slot truncation ranges (``end <= start`` makes a slot a no-op).
    ``span`` is the static lane count (the engine passes ``spec_k + 1``):
    each slot's candidate positions are ``start + [0, span)`` and lanes at
    or past ``end`` are redirected to the null block, so their zero-write
    is harmless scratch.  One jitted dispatch rolls back the whole batch —
    ``start``/``end`` are traced, so one compiled truncate serves every
    mix of rollback lengths.
    """
    positions = start[:, None] + jnp.arange(span, dtype=jnp.int32)[None, :]  # (B, span)
    bs = pool_cache["k"].shape[2]
    live = positions < end[:, None]
    # dead lanes may index past the table; clamp — their gather is discarded
    idx = jnp.minimum(positions // bs, tbl.shape[1] - 1)
    phys = jnp.where(live, jnp.take_along_axis(tbl, idx, axis=1), NULL_BLOCK)
    off = positions % bs
    new = dict(pool_cache)
    for name in ("k", "v", "k_scale", "v_scale"):
        if name in pool_cache:
            leaf = pool_cache[name]  # (L, N, bs, ...)
            zeros = jnp.zeros((leaf.shape[0],) + phys.shape + leaf.shape[3:], leaf.dtype)
            new[name] = leaf.at[:, phys, off].set(zeros)
    return new


def make_table_row(blocks, max_blocks_per_seq: int):
    """Pad a request's block list to a full table row (null-block padded)."""
    row = list(blocks) + [NULL_BLOCK] * (max_blocks_per_seq - len(blocks))
    return row


def clear_block_row(cfg, pool_cache, slot: int):
    """Free a paged request: reset recurrent-state slots (hybrid).  The K/V
    blocks themselves need no wipe — the allocator recycles them and the
    attention mask hides any stale positions until they are overwritten."""
    new = dict(pool_cache)
    for state in ("conv", "ssm"):
        if state in pool_cache:
            leaf = pool_cache[state]
            new[state] = leaf.at[:, slot].set(jnp.zeros(leaf.shape[2:], leaf.dtype))
    return new
