"""Paged KV-cache block allocator (vLLM-style, host-side, refcounted).

The engine's KV memory is a global pool of fixed-size blocks shared by every
batch slot; a request owns ``ceil(tokens / block_size)`` physical blocks,
recorded in its block-table row.  Admission is gated on *free blocks*, not
free slots — the structural change that decouples max concurrency from
``max_seq``: a 16-token request costs 1 block, not a ``max_seq``-long dense
cache line.

Physical block 0 is the **null block**: never allocated, permanently the
target of inactive slots' block tables, so their (masked) decode writes land
in a scratch bin instead of a live request's memory.

The allocator is deliberately oblivious to device meshes: under
tensor-parallel serving the pools shard along the **kv-head** axis (every
device holds its head slice of every block), so block ids — and with them
every alloc/free/refcount decision here — are device-invariant.  Allocator
state never needs sharding, mirroring, or per-device reconciliation.

Blocks are **refcounted** so prefix caching (``serving.prefix``) can share
one physical block between every request whose prompt starts with the same
token-aligned content: each sharer holds one reference, writes never touch a
block whose positions are covered by more than one table row, and a block
only leaves live accounting when its last reference drops.  A dropped block
goes one of two ways:

* ``free``        — eagerly back to the free list (content dead).
* ``free_cached`` — into an **LRU cached pool**: the content is still a
  valid prefix-cache entry, so the block is only reclaimed (oldest first,
  ``on_evict`` notified so the prefix index unmaps it) when an allocation
  finds the free list empty.  Cached blocks therefore count as free for
  admission gating — they are reclaimable on demand.

Eviction is **tier-aware**: the ``on_evict`` callback may return a tier tag
— ``"spilled"`` when the prefix index demoted the block's content into a
host-RAM ``serving.spill.SpillPool`` (the entry stays matchable), anything
else meaning the content was dropped — and the allocator accounts the two
outcomes separately (``evictions_spilled`` / ``evictions_dropped``).
``uncache`` is the stranding repair path: when an index unmap cascade finds
a still-cached descendant that can no longer be matched (its parent's entry
is gone), the block moves straight from the cached pool to the free list
instead of leaking reclaimable-but-unreachable capacity.

Blocks are position-independent (any physical block can hold any logical
block), so "fragmentation" here is purely a locality concern: a scattered
free list means scattered DMA reads on real hardware.  ``fragmentation()``
reports it and ``defrag()`` sorts the free list so subsequent allocations are
contiguous — allocation/free/defrag accounting without any copying.  (The
cached pool is exempt: those blocks pin live content at their address.)
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Optional


class OutOfBlocks(RuntimeError):
    """Allocation would exceed the pool — admission must backpressure."""


def blocks_needed(tokens: int, block_size: int) -> int:
    """Physical blocks required to hold ``tokens`` cache positions."""
    return -(-max(tokens, 1) // block_size)


def truncate_blocks(
    blocks: list[int], tokens: int, block_size: int
) -> tuple[list[int], list[int]]:
    """Token-level truncate of a block list: ``(kept, tail)``.

    ``kept`` covers logical positions [0, tokens); ``tail`` is every block
    past the truncation point.  Speculative decoding uses this when a
    request finishes mid-window: the engine reserved headroom for the draft
    window, and any tail blocks hold only rejected speculative writes (or
    were never written) — they are dead content that must be freed eagerly,
    never parked in the prefix cache's LRU pool.  ``tokens <= 0`` keeps
    nothing.
    """
    n = blocks_needed(tokens, block_size) if tokens > 0 else 0
    n = min(n, len(blocks))
    return blocks[:n], blocks[n:]


class BlockAllocator:
    def __init__(self, num_blocks: int, on_evict: Optional[Callable[[int], None]] = None):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 null + 1 usable), got {num_blocks}")
        self.num_blocks = num_blocks
        # LIFO free list: freshly freed (cache-warm) blocks are reused first
        self._free = list(range(num_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}  # live block -> refcount
        self._cached: OrderedDict[int, None] = OrderedDict()  # refcount-0, LRU order
        # called with the block id before reclaiming it; may return a tier
        # tag ("spilled" = content demoted to a host pool, else dropped)
        self.on_evict = on_evict
        self.peak_in_use = 0
        self.total_allocs = 0
        self.total_frees = 0
        self.evictions = 0
        self.evictions_spilled = 0  # content demoted to the host spill tier
        self.evictions_dropped = 0  # content destroyed
        self.stranded_reclaims = 0  # cached-but-unreachable blocks uncache()d
        self._metrics = None  # attach_metrics publishes occupancy per mutation

    def attach_metrics(self, registry) -> None:
        """Publish allocator accounting into a ``serving.metrics``
        registry: occupancy gauges refreshed on every alloc/free, eviction
        and alloc/free counters.  Host-side scalar updates only."""
        self._metrics = registry
        self._m_in_use = registry.gauge("pool_blocks_in_use", "live (refcounted) blocks")
        self._m_free = registry.gauge("pool_blocks_free", "allocatable blocks (free list + evictable cached)")
        self._m_cached = registry.gauge("pool_blocks_cached", "refcount-0 blocks parked in the prefix LRU")
        self._m_allocs = registry.counter("pool_allocs_total", "blocks allocated (cached revivals count)")
        self._m_frees = registry.counter("pool_frees_total", "blocks freed or parked in the LRU")
        self._m_evictions = registry.counter("pool_evictions_total", "LRU cached blocks reclaimed on demand")
        self._m_evict_spilled = registry.counter("pool_evictions_spilled_total", "evicted blocks demoted to the host spill tier")
        self._m_stranded = registry.counter("pool_stranded_reclaims_total", "cached-but-unreachable blocks returned to the free list")
        self._publish()

    def _publish(self) -> None:
        if self._metrics is None:
            return
        self._m_in_use.set(len(self._ref))
        self._m_free.set(self.num_free)
        self._m_cached.set(len(self._cached))

    # -- accounting ----------------------------------------------------
    @property
    def capacity(self) -> int:
        """Usable blocks (excludes the null block)."""
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        """Blocks an ``alloc`` can hand out: truly free + evictable cached."""
        return len(self._free) + len(self._cached)

    @property
    def blocks_in_use(self) -> int:
        return len(self._ref)

    @property
    def num_cached(self) -> int:
        return len(self._cached)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def is_cached(self, block: int) -> bool:
        return block in self._cached

    def fragmentation(self) -> float:
        """1 - (longest contiguous free run / free blocks); 0 = fully
        contiguous free space, -> 1 = maximally scattered."""
        if len(self._free) <= 1:
            return 0.0
        ids = sorted(self._free)
        longest = run = 1
        for a, b in zip(ids, ids[1:]):
            run = run + 1 if b == a + 1 else 1
            longest = max(longest, run)
        return 1.0 - longest / len(ids)

    def defrag(self) -> float:
        """Sort the free list so future allocations come out id-contiguous
        (DMA locality on real HW).  Returns the pre-defrag fragmentation."""
        frag = self.fragmentation()
        self._free.sort(reverse=True)  # popped from the tail -> ascending ids
        return frag

    # -- alloc / free --------------------------------------------------
    def _evict_one(self) -> int:
        block, _ = self._cached.popitem(last=False)  # oldest entry
        tier = self.on_evict(block) if self.on_evict is not None else None
        if tier == "spilled":
            self.evictions_spilled += 1
        else:
            self.evictions_dropped += 1
        self.evictions += 1
        if self._metrics is not None:
            self._m_evictions.inc()
            if tier == "spilled":
                self._m_evict_spilled.inc()
        return block

    def alloc(self, n: int) -> list[int]:
        """Allocate ``n`` blocks (refcount 1) or raise ``OutOfBlocks``
        (all-or-nothing).  Draws from the free list first; when it runs dry,
        evicts the least-recently-used cached blocks."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > self.num_free:
            raise OutOfBlocks(f"need {n} blocks, {self.num_free} free of {self.capacity}")
        blocks = []
        for _ in range(n):
            blocks.append(self._free.pop() if self._free else self._evict_one())
        for b in blocks:
            self._ref[b] = 1
        self.total_allocs += n
        self.peak_in_use = max(self.peak_in_use, len(self._ref))
        if self._metrics is not None:
            self._m_allocs.inc(n)
            self._publish()
        return blocks

    def incref(self, block: int) -> None:
        """Add a reference to a live block (prefix sharing)."""
        if block not in self._ref:
            raise ValueError(f"incref on non-live block {block}")
        self._ref[block] += 1

    def reuse_cached(self, block: int) -> None:
        """Revive a refcount-0 cached block into live use (prefix hit on an
        evictable entry): removed from the LRU pool, refcount 1.  Counts as
        an allocation so ``total_allocs == total_frees`` stays the drained-
        engine leak check: every park in the cached pool counted a free."""
        if block not in self._cached:
            raise ValueError(f"block {block} is not in the cached pool")
        del self._cached[block]
        self._ref[block] = 1
        self.total_allocs += 1
        self.peak_in_use = max(self.peak_in_use, len(self._ref))
        if self._metrics is not None:
            self._m_allocs.inc()
            self._publish()

    def _decref(self, block: int) -> bool:
        if block not in self._ref:
            raise ValueError(f"double free / foreign block {block}")
        self._ref[block] -= 1
        if self._ref[block] > 0:
            return False
        del self._ref[block]
        return True

    def free(self, blocks: list[int]) -> None:
        """Drop one reference per block; last reference returns the block to
        the free list (content dead)."""
        for b in blocks:
            if self._decref(b):
                self._free.append(b)
                self.total_frees += 1
                if self._metrics is not None:
                    self._m_frees.inc()
        self._publish()

    def free_cached(self, blocks: list[int]) -> None:
        """Drop one reference per block; last reference parks the block in
        the LRU cached pool (content stays matchable until evicted)."""
        for b in blocks:
            if self._decref(b):
                self._cached[b] = None  # appended at the MRU end
                self.total_frees += 1
                if self._metrics is not None:
                    self._m_frees.inc()
        self._publish()

    def uncache(self, block: int) -> None:
        """Return a refcount-0 cached block straight to the free list — the
        stranding repair: an index unmap cascade found this block cached but
        unreachable for matching (its parent entry is gone), so parking it
        in the LRU any longer only wastes reclaimable capacity.  Not an
        eviction (``on_evict`` already unmapped it) and not a new free (its
        park in the cached pool counted one)."""
        if block not in self._cached:
            raise ValueError(f"block {block} is not in the cached pool")
        del self._cached[block]
        self._free.append(block)
        self.stranded_reclaims += 1
        if self._metrics is not None:
            self._m_stranded.inc()
        self._publish()

    def stats(self) -> dict:
        return {
            "capacity": self.capacity,
            "blocks_in_use": self.blocks_in_use,
            "num_free": self.num_free,
            "num_cached": self.num_cached,
            "peak_in_use": self.peak_in_use,
            "total_allocs": self.total_allocs,
            "total_frees": self.total_frees,
            "evictions_spilled": self.evictions_spilled,
            "evictions_dropped": self.evictions_dropped,
            "stranded_reclaims": self.stranded_reclaims,
            "fragmentation": round(self.fragmentation(), 3),
        }
