"""Paged KV-cache block allocator (vLLM-style, host-side).

The engine's KV memory is a global pool of fixed-size blocks shared by every
batch slot; a request owns ``ceil(tokens / block_size)`` physical blocks,
recorded in its block-table row.  Admission is gated on *free blocks*, not
free slots — the structural change that decouples max concurrency from
``max_seq``: a 16-token request costs 1 block, not a ``max_seq``-long dense
cache line.

Physical block 0 is the **null block**: never allocated, permanently the
target of inactive slots' block tables, so their (masked) decode writes land
in a scratch bin instead of a live request's memory.

Blocks are position-independent (any physical block can hold any logical
block), so "fragmentation" here is purely a locality concern: a scattered
free list means scattered DMA reads on real hardware.  ``fragmentation()``
reports it and ``defrag()`` sorts the free list so subsequent allocations are
contiguous — allocation/free/defrag accounting without any copying.
"""

from __future__ import annotations


class OutOfBlocks(RuntimeError):
    """Allocation would exceed the pool — admission must backpressure."""


def blocks_needed(tokens: int, block_size: int) -> int:
    """Physical blocks required to hold ``tokens`` cache positions."""
    return -(-max(tokens, 1) // block_size)


class BlockAllocator:
    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 null + 1 usable), got {num_blocks}")
        self.num_blocks = num_blocks
        # LIFO free list: freshly freed (cache-warm) blocks are reused first
        self._free = list(range(num_blocks - 1, 0, -1))
        self._used: set[int] = set()
        self.peak_in_use = 0
        self.total_allocs = 0
        self.total_frees = 0

    # -- accounting ----------------------------------------------------
    @property
    def capacity(self) -> int:
        """Usable blocks (excludes the null block)."""
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return len(self._used)

    def fragmentation(self) -> float:
        """1 - (longest contiguous free run / free blocks); 0 = fully
        contiguous free space, -> 1 = maximally scattered."""
        if len(self._free) <= 1:
            return 0.0
        ids = sorted(self._free)
        longest = run = 1
        for a, b in zip(ids, ids[1:]):
            run = run + 1 if b == a + 1 else 1
            longest = max(longest, run)
        return 1.0 - longest / len(ids)

    def defrag(self) -> float:
        """Sort the free list so future allocations come out id-contiguous
        (DMA locality on real HW).  Returns the pre-defrag fragmentation."""
        frag = self.fragmentation()
        self._free.sort(reverse=True)  # popped from the tail -> ascending ids
        return frag

    # -- alloc / free --------------------------------------------------
    def alloc(self, n: int) -> list[int]:
        """Allocate ``n`` blocks or raise ``OutOfBlocks`` (all-or-nothing)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise OutOfBlocks(f"need {n} blocks, {len(self._free)} free of {self.capacity}")
        blocks = [self._free.pop() for _ in range(n)]
        self._used.update(blocks)
        self.total_allocs += n
        self.peak_in_use = max(self.peak_in_use, len(self._used))
        return blocks

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if b not in self._used:
                raise ValueError(f"double free / foreign block {b}")
            self._used.remove(b)
            self._free.append(b)
        self.total_frees += len(blocks)

    def stats(self) -> dict:
        return {
            "capacity": self.capacity,
            "blocks_in_use": self.blocks_in_use,
            "num_free": self.num_free,
            "peak_in_use": self.peak_in_use,
            "total_allocs": self.total_allocs,
            "total_frees": self.total_frees,
            "fragmentation": round(self.fragmentation(), 3),
        }
