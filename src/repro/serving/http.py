"""Stdlib HTTP/SSE front-end over the always-on async engine.

The paper's users reach Isambard-AI through web front-ends, so the serving
stack terminates HTTP itself: one ``asyncio.start_server`` acceptor shares
the event loop with ``AsyncEngine``'s stepping task — no framework, no extra
dependency, one process.  Endpoints:

* ``POST /v1/generate`` — body ``{"prompt": [ints], "max_new_tokens": 16,
  "temperature": 0.0, "top_k": 0, "priority": 0, "deadline_s": null,
  "online": true, "stream": true}``.  With ``stream`` (the default) the
  response is Server-Sent Events: one ``event: token`` frame per emission
  batch (``data`` carries ``{"tokens": [...], "index": N}``) and a final
  ``event: done`` frame with the finish summary; the connection closes
  after ``done`` (``Connection: close`` — no chunked framing needed).
  With ``"stream": false`` the full completion returns as one JSON object.
* ``GET /metrics`` — the registry in Prometheus text exposition format;
  under a multi-replica router, each replica engine's registry renders too,
  prefixed ``replica<N>_``.
* ``GET /stats`` — ``engine.stats()`` as JSON.
* ``GET /healthz`` — readiness probe: 200 while the service can accept
  work, 503 while draining or with no admittable replica; the body reports
  per-replica lifecycle states under a router.

Hardening (the paper's front-ends face real browsers):

* Malformed framing, bad ``Content-Length``, oversized headers/bodies and
  non-JSON payloads all return a structured ``{"error": ...}`` 400 — a
  client can never crash the acceptor with a reader exception.
* A client that disconnects mid-stream aborts its request: the SSE loop's
  failed write closes the stream generator, whose teardown cancels the
  engine request and frees its blocks — no generating into a dead socket.
* Submissions during shutdown / degraded mode (``ServiceUnavailable``)
  return 503.

Request knob validation happens in ``engine.submit`` (negative
``max_new_tokens``/``priority``, non-positive ``deadline_s``, empty or
oversized prompts) and surfaces as a 400 with the error message.

The parser handles exactly what the front-end needs — request line, headers,
``Content-Length`` bodies — and rejects everything else; it is a serving
research harness, not a hardened proxy (deploy behind one for anything
public-facing).
"""

from __future__ import annotations

import asyncio
import json
import signal
from typing import Callable, Optional

from repro.serving.async_engine import AsyncEngine
from repro.serving.faults import ServiceUnavailable

MAX_HEADER_BYTES = 16384
MAX_BODY_BYTES = 4 * 1024 * 1024

_STATUS = {
    200: "OK",
    400: "Bad Request",
    404: "Not Found",
    405: "Method Not Allowed",
    413: "Payload Too Large",
    500: "Internal Server Error",
    503: "Service Unavailable",
}


def _head(status: int, content_type: str, *, length: Optional[int] = None) -> bytes:
    lines = [
        f"HTTP/1.1 {status} {_STATUS.get(status, 'Unknown')}",
        f"Content-Type: {content_type}",
        "Connection: close",
        "Cache-Control: no-store",
    ]
    if length is not None:
        lines.append(f"Content-Length: {length}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode()


def _sse_frame(event: str, data: dict) -> bytes:
    return f"event: {event}\ndata: {json.dumps(data)}\n\n".encode()


async def _respond_json(writer: asyncio.StreamWriter, status: int, obj: dict) -> None:
    body = (json.dumps(obj) + "\n").encode()
    writer.write(_head(status, "application/json", length=len(body)) + body)
    await writer.drain()


async def _read_request(reader: asyncio.StreamReader):
    """Parse one request: (method, path, headers, body) or None on EOF."""
    head = await reader.readuntil(b"\r\n\r\n")
    if len(head) > MAX_HEADER_BYTES:
        raise ValueError("headers too large")
    lines = head.decode("latin-1").split("\r\n")
    method, path, _ = lines[0].split(" ", 2)
    headers = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    try:
        n = int(headers.get("content-length", 0))
    except (TypeError, ValueError):
        raise ValueError("invalid Content-Length") from None
    if n < 0:
        raise ValueError("invalid Content-Length")
    if n > MAX_BODY_BYTES:
        raise ValueError(f"body too large ({n} > {MAX_BODY_BYTES} bytes)")
    body = await reader.readexactly(n) if n else b""
    return method, path, headers, body


class HttpFrontend:
    """One-process HTTP/SSE service over an ``AsyncEngine``.

    ``port=0`` binds an ephemeral port (tests); after ``start()`` the bound
    port is in ``self.port``.
    """

    def __init__(self, async_engine: AsyncEngine, host: str = "127.0.0.1", port: int = 8080):
        self.async_engine = async_engine
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> None:
        self.async_engine.start()
        self._server = await asyncio.start_server(self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.async_engine.stop()

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    async def shutdown(self, timeout: Optional[float] = None) -> bool:
        """Graceful shutdown: close the acceptor (no new connections),
        drain the engine (in-flight requests finish; new submissions on
        already-open connections get 503), then stop the stepping loop.
        Returns True when the drain beat the hard ``timeout``."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        return await self.async_engine.shutdown(timeout)

    # -- request handling ----------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter) -> None:
        try:
            try:
                parsed = await _read_request(reader)
            except (asyncio.IncompleteReadError, ConnectionError):
                return
            except (ValueError, asyncio.LimitOverrunError) as e:
                await _respond_json(writer, 400, {"error": str(e)})
                return
            method, path, _, body = parsed
            if path == "/healthz":
                await self._healthz(writer)
            elif path == "/metrics":
                if method != "GET":
                    await _respond_json(writer, 405, {"error": "GET only"})
                    return
                eng = self.async_engine.engine
                text = eng.metrics.render_text()
                # router fleet: append every replica engine's registry with
                # a replica<N>_ name prefix (one scrape, no collisions)
                for rep in getattr(eng, "replicas", ()):
                    text += rep.engine.metrics.render_text(prefix=f"replica{rep.id}_")
                data = text.encode()
                writer.write(_head(200, "text/plain; version=0.0.4", length=len(data)) + data)
                await writer.drain()
            elif path == "/stats":
                await _respond_json(writer, 200, self.async_engine.engine.stats())
            elif path == "/v1/generate":
                if method != "POST":
                    await _respond_json(writer, 405, {"error": "POST only"})
                    return
                await self._generate(writer, body)
            else:
                await _respond_json(writer, 404, {"error": f"no route {path}"})
        except ConnectionError:
            pass  # client went away mid-stream
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except ConnectionError:
                pass

    async def _healthz(self, writer: asyncio.StreamWriter) -> None:
        """Readiness: 200 while the service can accept a request, 503 while
        draining or with every replica out of rotation.  Under a router the
        body carries per-replica lifecycle states."""
        eng = self.async_engine.engine
        draining = self.async_engine.draining
        replicas = getattr(eng, "replicas", None)
        if replicas is None:
            body = {"ok": not draining, "draining": draining}
        else:
            states = {str(r.id): r.state.value for r in replicas}
            ok = not draining and any(r.admittable for r in replicas)
            body = {"ok": ok, "draining": draining, "replicas": states}
        await _respond_json(writer, 200 if body["ok"] else 503, body)

    async def _generate(self, writer: asyncio.StreamWriter, body: bytes) -> None:
        try:
            payload = json.loads(body.decode() or "{}")
            if not isinstance(payload, dict):
                raise ValueError("body must be a JSON object")
            prompt = payload.get("prompt")
            if not isinstance(prompt, list) or not all(isinstance(t, int) for t in prompt):
                raise ValueError("prompt must be a list of token ids")
            kw = dict(
                max_new_tokens=int(payload.get("max_new_tokens", 16)),
                online=bool(payload.get("online", True)),
                temperature=float(payload.get("temperature", 0.0)),
                top_k=int(payload.get("top_k", 0)),
                priority=int(payload.get("priority", 0)),
                deadline_s=(
                    None if payload.get("deadline_s") is None else float(payload["deadline_s"])
                ),
            )
            stream = bool(payload.get("stream", True))
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            await _respond_json(writer, 400, {"error": str(e)})
            return

        if not stream:
            try:
                final, toks = await self.async_engine.generate(prompt, **kw)
            except ServiceUnavailable as e:  # draining / degraded fleet
                await _respond_json(writer, 503, {"error": str(e)})
                return
            except ValueError as e:  # submit() validation
                await _respond_json(writer, 400, {"error": str(e)})
                return
            await _respond_json(
                writer,
                200,
                {
                    "req_id": final.req_id,
                    "tokens": toks,
                    "reason": final.reason,
                    "ttft_s": final.ttft_s,
                    "preemptions": final.preemptions,
                },
            )
            return

        gen = self.async_engine.submit_stream(prompt, **kw)
        try:
            try:
                first = await gen.__anext__()
            except ServiceUnavailable as e:  # draining / degraded fleet
                await _respond_json(writer, 503, {"error": str(e)})
                return
            except ValueError as e:  # submit() validation
                await _respond_json(writer, 400, {"error": str(e)})
                return
            # headers go out only once submission succeeded; each event frame
            # is drained immediately so tokens reach the client as emitted
            writer.write(_head(200, "text/event-stream"))
            await writer.drain()
            ev = first
            while True:
                if ev.kind == "token":
                    writer.write(
                        _sse_frame("token", {"req_id": ev.req_id, "tokens": list(ev.tokens), "index": ev.index})
                    )
                else:
                    writer.write(
                        _sse_frame(
                            "done",
                            {
                                "req_id": ev.req_id,
                                "reason": ev.reason,
                                "n_tokens": ev.n_tokens,
                                "ttft_s": ev.ttft_s,
                                "preemptions": ev.preemptions,
                            },
                        )
                    )
                await writer.drain()
                if ev.kind == "finish":
                    break
                ev = await gen.__anext__()
        finally:
            # closing the generator before its finish event cancels the
            # engine request (submit_stream's teardown) — a client that
            # disconnected mid-stream stops consuming slots and blocks
            await gen.aclose()


async def serve_http(
    engine,
    host: str = "127.0.0.1",
    port: int = 8080,
    *,
    metrics_json: Optional[str] = None,
    trace_out: Optional[str] = None,
    drain_timeout_s: float = 10.0,
    shutdown_event: Optional[asyncio.Event] = None,
    on_ready: Optional[Callable[["HttpFrontend"], None]] = None,
) -> None:
    """Blocking entry: wrap ``engine`` (an ``InferenceEngine`` or a
    ``Router`` fleet) in an AsyncEngine + HttpFrontend and serve until
    SIGTERM/SIGINT or ``shutdown_event``.

    Shutdown is graceful: admission stops (503), active requests get up to
    ``drain_timeout_s`` to finish, then ``metrics_json`` / ``trace_out``
    flush — a kill doesn't lose the observability record.  ``on_ready``
    fires with the frontend once the port is bound (tests use it with
    ``port=0``).
    """
    front = HttpFrontend(AsyncEngine(engine), host=host, port=port)
    await front.start()
    print(f"[serve] http/sse listening on http://{front.host}:{front.port}", flush=True)
    stop = shutdown_event if shutdown_event is not None else asyncio.Event()
    loop = asyncio.get_running_loop()
    installed = []
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            loop.add_signal_handler(sig, stop.set)
            installed.append(sig)
        except (NotImplementedError, RuntimeError, ValueError):
            pass  # non-unix loop or non-main thread: event-only shutdown
    if on_ready is not None:
        on_ready(front)
    try:
        await stop.wait()
        print("[serve] shutdown requested; draining", flush=True)
        drained = await front.shutdown(drain_timeout_s)
        print(f"[serve] drain {'complete' if drained else 'timed out'}", flush=True)
    finally:
        for sig in installed:
            loop.remove_signal_handler(sig)
        await front.stop()
        eng = front.async_engine.engine
        if metrics_json:
            eng.metrics.write_json(metrics_json)
            print(f"[serve] metrics snapshot -> {metrics_json}", flush=True)
        if trace_out:
            eng.tracer.write(trace_out)
            print(f"[serve] chrome trace -> {trace_out}", flush=True)
