"""Stdlib HTTP/SSE front-end over the always-on async engine.

The paper's users reach Isambard-AI through web front-ends, so the serving
stack terminates HTTP itself: one ``asyncio.start_server`` acceptor shares
the event loop with ``AsyncEngine``'s stepping task — no framework, no extra
dependency, one process.  Endpoints:

* ``POST /v1/generate`` — body ``{"prompt": [ints], "max_new_tokens": 16,
  "temperature": 0.0, "top_k": 0, "priority": 0, "deadline_s": null,
  "online": true, "stream": true}``.  With ``stream`` (the default) the
  response is Server-Sent Events: one ``event: token`` frame per emission
  batch (``data`` carries ``{"tokens": [...], "index": N}``) and a final
  ``event: done`` frame with the finish summary; the connection closes
  after ``done`` (``Connection: close`` — no chunked framing needed).
  With ``"stream": false`` the full completion returns as one JSON object.
* ``GET /metrics`` — the registry in Prometheus text exposition format.
* ``GET /stats`` — ``engine.stats()`` as JSON.
* ``GET /healthz`` — liveness probe.

Request knob validation happens in ``engine.submit`` (negative
``max_new_tokens``/``priority``, non-positive ``deadline_s``, empty or
oversized prompts) and surfaces as a 400 with the error message.

The parser handles exactly what the front-end needs — request line, headers,
``Content-Length`` bodies — and rejects everything else; it is a serving
research harness, not a hardened proxy (deploy behind one for anything
public-facing).
"""

from __future__ import annotations

import asyncio
import json
from typing import Optional

from repro.serving.async_engine import AsyncEngine

MAX_HEADER_BYTES = 16384
MAX_BODY_BYTES = 4 * 1024 * 1024

_STATUS = {
    200: "OK",
    400: "Bad Request",
    404: "Not Found",
    405: "Method Not Allowed",
    413: "Payload Too Large",
    500: "Internal Server Error",
}


def _head(status: int, content_type: str, *, length: Optional[int] = None) -> bytes:
    lines = [
        f"HTTP/1.1 {status} {_STATUS.get(status, 'Unknown')}",
        f"Content-Type: {content_type}",
        "Connection: close",
        "Cache-Control: no-store",
    ]
    if length is not None:
        lines.append(f"Content-Length: {length}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode()


def _sse_frame(event: str, data: dict) -> bytes:
    return f"event: {event}\ndata: {json.dumps(data)}\n\n".encode()


async def _respond_json(writer: asyncio.StreamWriter, status: int, obj: dict) -> None:
    body = (json.dumps(obj) + "\n").encode()
    writer.write(_head(status, "application/json", length=len(body)) + body)
    await writer.drain()


async def _read_request(reader: asyncio.StreamReader):
    """Parse one request: (method, path, headers, body) or None on EOF."""
    head = await reader.readuntil(b"\r\n\r\n")
    if len(head) > MAX_HEADER_BYTES:
        raise ValueError("headers too large")
    lines = head.decode("latin-1").split("\r\n")
    method, path, _ = lines[0].split(" ", 2)
    headers = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    n = int(headers.get("content-length", 0))
    if n > MAX_BODY_BYTES:
        raise ValueError("body too large")
    body = await reader.readexactly(n) if n else b""
    return method, path, headers, body


class HttpFrontend:
    """One-process HTTP/SSE service over an ``AsyncEngine``.

    ``port=0`` binds an ephemeral port (tests); after ``start()`` the bound
    port is in ``self.port``.
    """

    def __init__(self, async_engine: AsyncEngine, host: str = "127.0.0.1", port: int = 8080):
        self.async_engine = async_engine
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> None:
        self.async_engine.start()
        self._server = await asyncio.start_server(self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.async_engine.stop()

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    # -- request handling ----------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter) -> None:
        try:
            try:
                parsed = await _read_request(reader)
            except (asyncio.IncompleteReadError, ConnectionError):
                return
            except (ValueError, asyncio.LimitOverrunError) as e:
                await _respond_json(writer, 400, {"error": str(e)})
                return
            method, path, _, body = parsed
            if path == "/healthz":
                await _respond_json(writer, 200, {"ok": True})
            elif path == "/metrics":
                if method != "GET":
                    await _respond_json(writer, 405, {"error": "GET only"})
                    return
                text = self.async_engine.engine.metrics.render_text().encode()
                writer.write(_head(200, "text/plain; version=0.0.4", length=len(text)) + text)
                await writer.drain()
            elif path == "/stats":
                await _respond_json(writer, 200, self.async_engine.engine.stats())
            elif path == "/v1/generate":
                if method != "POST":
                    await _respond_json(writer, 405, {"error": "POST only"})
                    return
                await self._generate(writer, body)
            else:
                await _respond_json(writer, 404, {"error": f"no route {path}"})
        except ConnectionError:
            pass  # client went away mid-stream
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except ConnectionError:
                pass

    async def _generate(self, writer: asyncio.StreamWriter, body: bytes) -> None:
        try:
            payload = json.loads(body.decode() or "{}")
            if not isinstance(payload, dict):
                raise ValueError("body must be a JSON object")
            prompt = payload.get("prompt")
            if not isinstance(prompt, list) or not all(isinstance(t, int) for t in prompt):
                raise ValueError("prompt must be a list of token ids")
            kw = dict(
                max_new_tokens=int(payload.get("max_new_tokens", 16)),
                online=bool(payload.get("online", True)),
                temperature=float(payload.get("temperature", 0.0)),
                top_k=int(payload.get("top_k", 0)),
                priority=int(payload.get("priority", 0)),
                deadline_s=(
                    None if payload.get("deadline_s") is None else float(payload["deadline_s"])
                ),
            )
            stream = bool(payload.get("stream", True))
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            await _respond_json(writer, 400, {"error": str(e)})
            return

        if not stream:
            try:
                final, toks = await self.async_engine.generate(prompt, **kw)
            except ValueError as e:  # submit() validation
                await _respond_json(writer, 400, {"error": str(e)})
                return
            await _respond_json(
                writer,
                200,
                {
                    "req_id": final.req_id,
                    "tokens": toks,
                    "reason": final.reason,
                    "ttft_s": final.ttft_s,
                    "preemptions": final.preemptions,
                },
            )
            return

        gen = self.async_engine.submit_stream(prompt, **kw)
        try:
            first = await gen.__anext__()
        except ValueError as e:  # submit() validation
            await _respond_json(writer, 400, {"error": str(e)})
            return
        # headers go out only once submission succeeded; each event frame is
        # drained immediately so tokens reach the client as they are emitted
        writer.write(_head(200, "text/event-stream"))
        await writer.drain()
        ev = first
        while True:
            if ev.kind == "token":
                writer.write(
                    _sse_frame("token", {"req_id": ev.req_id, "tokens": list(ev.tokens), "index": ev.index})
                )
            else:
                writer.write(
                    _sse_frame(
                        "done",
                        {
                            "req_id": ev.req_id,
                            "reason": ev.reason,
                            "n_tokens": ev.n_tokens,
                            "ttft_s": ev.ttft_s,
                            "preemptions": ev.preemptions,
                        },
                    )
                )
            await writer.drain()
            if ev.kind == "finish":
                break
            ev = await gen.__anext__()


async def serve_http(engine, host: str = "127.0.0.1", port: int = 8080) -> None:
    """Blocking entry: wrap ``engine`` in an AsyncEngine + HttpFrontend and
    serve until cancelled (``launch.serve --http``)."""
    front = HttpFrontend(AsyncEngine(engine), host=host, port=port)
    await front.start()
    print(f"[serve] http/sse listening on http://{front.host}:{front.port}", flush=True)
    try:
        await front.serve_forever()
    finally:
        await front.stop()
