"""Deterministic fault injection for multi-replica serving.

Isambard-AI fields 1,362 Grace-Hopper nodes; at that scale node failure is
the baseline operating condition, not an anomaly, so the serving stack's
failure handling must be *testable* — a fault that only reproduces on real
flaky hardware cannot gate CI.  This module gives each ``serving.replica``
a ``FaultPlan``: a frozen schedule keyed on the replica's **own step
counter**, so a chaos run replays bit-identically (the router benchmark's
mid-run kill arm asserts token-identical failover against a no-fault run).

Three fault shapes, mirroring the seed cluster model (``core/cluster.py``
drives HEALTHY → SUSPECT → FAILED off heartbeat age; ``core/fault.py``
replays crashes at fixed steps):

* **crash** — from ``crash_at_step`` on, ``Replica.step`` raises
  ``ReplicaCrashed`` *instead of* executing the step: no partial-step
  tokens are ever emitted, so failover's committed-token accounting is
  exact.  Models a process/node loss.
* **hang** — from ``hang_from_step`` on, steps do nothing and stop
  heartbeating; the router's missed-deadline sweep detects the silence
  (SUSPECT after ``suspect_after``, UNHEALTHY + failover after
  ``fail_after``).  Models a wedged process the OS never reaps.
* **slow** — inside ``[slow_from_step, slow_until_step)`` the replica does
  full work but heartbeats only every ``slow_every``-th step, so its
  heartbeat age oscillates into SUSPECT territory: the router routes new
  requests around it without failing over in-flight ones.  Models a
  straggler (thermal throttle, noisy neighbour).

``ReplicaCrashed`` and ``ServiceUnavailable`` are the shared error
vocabulary: the router raises ``ServiceUnavailable`` when no replica is
admittable (degraded mode), ``AsyncEngine`` raises it while draining, and
the HTTP front-end maps it to a 503.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


class ReplicaCrashed(RuntimeError):
    """An injected (or real) replica loss: the engine behind it is gone."""


class ServiceUnavailable(RuntimeError):
    """No replica can accept work (degraded mode / draining) — HTTP 503."""


@dataclass(frozen=True)
class FaultPlan:
    """Deterministic per-replica fault schedule (steps are the replica's
    own counter, starting at 0)."""

    crash_at_step: Optional[int] = None  # raise instead of executing step >= this
    hang_from_step: Optional[int] = None  # no work, no heartbeat from this step on
    slow_from_step: Optional[int] = None  # straggle window start ...
    slow_until_step: Optional[int] = None  # ... and end (None = forever)
    slow_every: int = 4  # while slow, heartbeat every k-th step only

    def __post_init__(self):
        if self.slow_every < 1:
            raise ValueError(f"slow_every={self.slow_every} (need >= 1)")

    def crashes_at(self, step: int) -> bool:
        return self.crash_at_step is not None and step >= self.crash_at_step

    def hangs_at(self, step: int) -> bool:
        return self.hang_from_step is not None and step >= self.hang_from_step

    def slow_at(self, step: int) -> bool:
        if self.slow_from_step is None or step < self.slow_from_step:
            return False
        return self.slow_until_step is None or step < self.slow_until_step

    @property
    def benign(self) -> bool:
        """True when this plan injects nothing (the default plan)."""
        return (
            self.crash_at_step is None
            and self.hang_from_step is None
            and self.slow_from_step is None
        )
