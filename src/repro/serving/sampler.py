"""Token sampling: greedy / temperature / top-k, scalar, batched, speculative.

Three entry points, all consumed by ``serving.engine``:

* ``sample_token``  — scalar (V,) -> token; used for admission's first token.
* ``sample_tokens`` — whole-batch per-step sampler: per-slot temperature /
  top-k carried as *data* so one jitted dispatch covers every request mix.
* ``spec_accept``   — vectorised speculative accept/reject: given the target
  model's logits at k+1 verified positions and the draft distribution each
  drafted token was drawn from, performs the standard rejection-sampling
  recurrence (Leviathan et al., arXiv:2211.17192) whose *combined* output law
  is exactly the target distribution — greedy rows degenerate to "accept
  while the draft matches the argmax", which is what makes greedy speculative
  decode token-identical to the non-speculative engine.
* ``fused_sample_accept`` — ``spec_accept`` generalised to the fused mixed
  row batch (decode / prefill-chunk / spec-verify rows): graph-composable,
  so the one-dispatch step samples inside the same compiled graph.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits: jax.Array, temperature: float, key: jax.Array, *, top_k: int = 0) -> jax.Array:
    """logits: (V,) -> scalar int32 token."""
    if temperature <= 0.0:
        return jnp.argmax(logits).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[-1], -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


@jax.jit
def sample_tokens(
    logits: jax.Array,  # (B, V)
    temperature: jax.Array,  # (B,) fp32; <= 0 means greedy
    top_k: jax.Array,  # (B,) int32; <= 0 means full softmax
    key: jax.Array,
) -> jax.Array:
    """Whole-batch sampler: one dispatch per engine step instead of one per
    slot.  Per-slot temperature / top-k are data (no retrace across request
    mixes); greedy rows take the argmax, sampling rows split ``key`` per
    slot.  The top-k threshold is the k-th largest scaled logit — ties at
    the threshold survive, matching ``sample_token``.  Returns (B,) int32.

    Greedy rows (``temperature <= 0``) still flow through the sampled branch
    before ``jnp.where`` discards it, so they are scaled by a BENIGN
    temperature of 1.0 rather than the 1e-6 clamp: dividing large logits by
    1e-6 overflows fp32 to inf inside sort/categorical, and inf/NaN garbage
    in discarded lanes poisons debug_nans runs (and any backend that traps
    on non-finite intermediates)."""
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.where(temperature <= 0.0, 1.0, jnp.maximum(temperature, 1e-6))
    scaled = logits / safe_t[:, None]
    srt = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth = jnp.clip(top_k, 1, V) - 1
    thresh = jnp.take_along_axis(srt, kth[:, None], axis=1)
    masked = jnp.where((top_k > 0)[:, None] & (scaled < thresh), -jnp.inf, scaled)
    sampled = jax.vmap(jax.random.categorical)(jax.random.split(key, B), masked)
    return jnp.where(temperature <= 0.0, greedy, sampled.astype(jnp.int32))


def _target_probs(logits: jax.Array, temperature: jax.Array, top_k: jax.Array) -> jax.Array:
    """(B, C, V) logits -> per-slot tempered/top-k'd probabilities.

    Greedy rows (temperature <= 0) come out as one-hot argmax so the
    rejection-sampling rule below degenerates to exact argmax comparison —
    they are scaled by a benign temperature of 1.0 first (not the 1e-6
    clamp) so extreme logits can't overflow to inf/NaN in the discarded
    softmax lanes (see ``sample_tokens``).  Top-k thresholding matches
    ``sample_tokens``: ties at the k-th largest scaled logit survive.
    """
    B, C, V = logits.shape
    safe_t = jnp.where(temperature <= 0.0, 1.0, jnp.maximum(temperature, 1e-6))
    scaled = logits / safe_t[:, None, None]
    srt = jnp.sort(scaled, axis=-1)[..., ::-1]
    kth = jnp.clip(top_k, 1, V) - 1
    thresh = jnp.take_along_axis(srt, jnp.broadcast_to(kth[:, None, None], (B, C, 1)), axis=-1)
    masked = jnp.where((top_k > 0)[:, None, None] & (scaled < thresh), -jnp.inf, scaled)
    probs = jax.nn.softmax(masked, axis=-1)
    onehot = jax.nn.one_hot(jnp.argmax(logits, axis=-1), V, dtype=probs.dtype)
    return jnp.where((temperature <= 0.0)[:, None, None], onehot, probs)


@jax.jit
def spec_accept(
    logits: jax.Array,  # (B, K+1, V) target logits at the verified positions
    drafts: jax.Array,  # (B, K) int32 drafted tokens
    draft_probs: jax.Array,  # (B, K, V) fp32 distribution each draft was drawn from
    valid: jax.Array,  # (B, K) bool; False positions force-reject (no draft)
    temperature: jax.Array,  # (B,) fp32; <= 0 means greedy
    top_k: jax.Array,  # (B,) int32; <= 0 means full softmax
    key: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Speculative accept/reject over a whole decode batch in one dispatch.

    ``logits[:, i]`` is the target distribution *after* the i-th fed token
    (``[last_committed, d_1, ..., d_K]``), i.e. the distribution draft
    ``d_{i+1}`` must be judged against.  Per slot:

    * draft ``d_i`` is accepted with probability ``min(1, p_i(d_i)/q_i(d_i))``
      (greedy rows: iff ``d_i == argmax p_i``);
    * the first rejection at position j emits one token from the residual
      ``norm(max(p_j - q_j, 0))`` — for a force-rejected (invalid) position
      ``q_j`` is treated as zero, i.e. a plain sample from ``p_j``;
    * if all K drafts are accepted, a *bonus* token is sampled from the
      (K+1)-th distribution.

    The emitted sequence ``drafts[:n_acc] + [final]`` is therefore exactly
    distributed as n_acc+1 sequential samples from the target model — and
    bit-identical to it under greedy.  Returns ``(n_acc (B,), final (B,))``:
    every slot always emits ``n_acc + 1`` tokens (at least one).
    """
    B, K1, V = logits.shape
    K = K1 - 1
    greedy = temperature <= 0.0
    p = _target_probs(logits, temperature, top_k)  # (B, K+1, V)
    argmax = jnp.argmax(logits, axis=-1)  # (B, K+1)

    k_u, k_f = jax.random.split(key)
    u = jax.random.uniform(k_u, (B, K))
    p_draft = jnp.take_along_axis(p[:, :K], drafts[..., None], axis=-1)[..., 0]
    q_draft = jnp.take_along_axis(draft_probs, drafts[..., None], axis=-1)[..., 0]
    accept_sampled = u < jnp.minimum(p_draft / jnp.maximum(q_draft, 1e-20), 1.0)
    accept_greedy = drafts == argmax[:, :K]
    accept = valid & jnp.where(greedy[:, None], accept_greedy, accept_sampled)
    # accepted prefix length: first rejection stops the window
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)

    # the emitted correction/bonus comes from position j = n_acc
    j = n_acc[:, None, None]
    p_fin = jnp.take_along_axis(p, j, axis=1)[:, 0]  # (B, V)
    q_pad = jnp.pad(draft_probs, ((0, 0), (0, 1), (0, 0)))  # q_K = 0 -> bonus from p
    q_fin = jnp.take_along_axis(q_pad, j, axis=1)[:, 0]
    valid_pad = jnp.pad(valid, ((0, 0), (0, 1)))
    valid_j = jnp.take_along_axis(valid_pad, n_acc[:, None], axis=1)[:, 0]
    q_fin = jnp.where(valid_j[:, None], q_fin, 0.0)  # forced reject: sample from p
    resid = jnp.clip(p_fin - q_fin, 0.0, None)
    norm = jnp.sum(resid, axis=-1, keepdims=True)
    resid = jnp.where(norm > 0, resid / jnp.maximum(norm, 1e-20), p_fin)
    fin_sampled = jax.vmap(jax.random.categorical)(
        jax.random.split(k_f, B), jnp.log(jnp.maximum(resid, 1e-38))
    )
    fin_greedy = jnp.take_along_axis(argmax, n_acc[:, None], axis=1)[:, 0]
    final = jnp.where(greedy, fin_greedy, fin_sampled).astype(jnp.int32)
    return n_acc.astype(jnp.int32), final


def fused_sample_accept(
    logits: jax.Array,  # (R, W, V) all-lane logits from models.unified_step
    drafts: jax.Array,  # (R, W-1) int32 drafted tokens (zeros on non-spec rows)
    draft_probs,  # (R, W-1, V) fp32 draft distributions, or None -> one-hot(drafts)
    valid: jax.Array,  # (R, W-1) bool; all-False rows have no speculative window
    temperature: jax.Array,  # (R,) fp32; <= 0 means greedy
    top_k: jax.Array,  # (R,) int32; <= 0 means full softmax
    sample_lane: jax.Array,  # (R,) int32 lane whose logits the row samples from
    key: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """``spec_accept`` generalised to the fused mixed row batch: sampling is
    graph-composable, so the engine folds it into the one-dispatch step.

    Row types share one recurrence: a spec-verify row passes
    ``sample_lane=0`` and its drafts/valid window — the accept recurrence
    yields ``n_acc`` and the correction/bonus comes from lane ``n_acc``,
    exactly ``spec_accept``.  A decode row passes ``sample_lane=0`` with an
    all-invalid window (``n_acc`` collapses to 0 — sample lane 0); a
    prefill-chunk row passes ``sample_lane = width - 1`` (its first token
    comes from the last REAL lane's logits).  The sampled lane is therefore
    ``n_acc + sample_lane``; an invalid lane's ``q`` is zero, so non-spec
    rows take a plain tempered/top-k sample from ``p`` — greedy rows the
    exact argmax, token-identical to the unfused engine.

    ``draft_probs=None`` builds the one-hot proposal in-graph (the ngram
    drafter / non-spec ticks) instead of materialising a dense (R, W-1, V)
    host array.  Returns ``(n_acc (R,), final (R,))``.
    """
    R, W, V = logits.shape
    K = W - 1
    if draft_probs is None:
        draft_probs = jax.nn.one_hot(drafts, V, dtype=jnp.float32)
    greedy = temperature <= 0.0
    p = _target_probs(logits, temperature, top_k)  # (R, W, V)
    argmax = jnp.argmax(logits, axis=-1)  # (R, W)

    k_u, k_f = jax.random.split(key)
    u = jax.random.uniform(k_u, (R, K))
    p_draft = jnp.take_along_axis(p[:, :K], drafts[..., None], axis=-1)[..., 0]
    q_draft = jnp.take_along_axis(draft_probs, drafts[..., None], axis=-1)[..., 0]
    accept_sampled = u < jnp.minimum(p_draft / jnp.maximum(q_draft, 1e-20), 1.0)
    accept_greedy = drafts == argmax[:, :K]
    accept = valid & jnp.where(greedy[:, None], accept_greedy, accept_sampled)
    n_acc = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)

    lane = jnp.minimum(n_acc + sample_lane, W - 1)  # clamp: width-0 pad rows
    j = lane[:, None, None]
    p_fin = jnp.take_along_axis(p, j, axis=1)[:, 0]  # (R, V)
    q_pad = jnp.pad(draft_probs, ((0, 0), (0, 1), (0, 0)))  # q_K = 0 -> bonus from p
    q_fin = jnp.take_along_axis(q_pad, j, axis=1)[:, 0]
    valid_pad = jnp.pad(valid, ((0, 0), (0, 1)))
    valid_j = jnp.take_along_axis(valid_pad, lane[:, None], axis=1)[:, 0]
    q_fin = jnp.where(valid_j[:, None], q_fin, 0.0)  # non-spec lane: sample from p
    resid = jnp.clip(p_fin - q_fin, 0.0, None)
    norm = jnp.sum(resid, axis=-1, keepdims=True)
    resid = jnp.where(norm > 0, resid / jnp.maximum(norm, 1e-20), p_fin)
    fin_sampled = jax.vmap(jax.random.categorical)(
        jax.random.split(k_f, R), jnp.log(jnp.maximum(resid, 1e-38))
    )
    fin_greedy = jnp.take_along_axis(argmax, lane[:, None], axis=1)[:, 0]
    final = jnp.where(greedy, fin_greedy, fin_sampled).astype(jnp.int32)
    return n_acc.astype(jnp.int32), final
