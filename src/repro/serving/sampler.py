"""Token sampling: greedy / temperature / top-k."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits: jax.Array, temperature: float, key: jax.Array, *, top_k: int = 0) -> jax.Array:
    """logits: (V,) -> scalar int32 token."""
    if temperature <= 0.0:
        return jnp.argmax(logits).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[-1], -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)
