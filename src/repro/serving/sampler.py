"""Token sampling: greedy / temperature / top-k, scalar and batched."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits: jax.Array, temperature: float, key: jax.Array, *, top_k: int = 0) -> jax.Array:
    """logits: (V,) -> scalar int32 token."""
    if temperature <= 0.0:
        return jnp.argmax(logits).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[-1], -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


@jax.jit
def sample_tokens(
    logits: jax.Array,  # (B, V)
    temperature: jax.Array,  # (B,) fp32; <= 0 means greedy
    top_k: jax.Array,  # (B,) int32; <= 0 means full softmax
    key: jax.Array,
) -> jax.Array:
    """Whole-batch sampler: one dispatch per engine step instead of one per
    slot.  Per-slot temperature / top-k are data (no retrace across request
    mixes); greedy rows take the argmax, sampling rows split ``key`` per
    slot.  The top-k threshold is the k-th largest scaled logit — ties at
    the threshold survive, matching ``sample_token``.  Returns (B,) int32."""
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    srt = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth = jnp.clip(top_k, 1, V) - 1
    thresh = jnp.take_along_axis(srt, kth[:, None], axis=1)
    masked = jnp.where((top_k > 0)[:, None] & (scaled < thresh), -jnp.inf, scaled)
    sampled = jax.vmap(jax.random.categorical)(jax.random.split(key, B), masked)
    return jnp.where(temperature <= 0.0, greedy, sampled.astype(jnp.int32))
