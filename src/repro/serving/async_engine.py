"""Always-on asyncio serving loop over the paged engine.

The paper's access model is cloud-style — Jupyter notebooks, MLOps pipelines
and web front-ends submitting continuously — so the engine must serve while
requests *arrive*, not drain a pre-loaded batch.  ``AsyncEngine`` wraps one
``InferenceEngine`` in a single background asyncio task that steps the
scheduler core for as long as there is work and sleeps on an event when
idle; callers get per-token streaming:

* ``submit_stream(prompt, ...)`` — async generator yielding ``StreamEvent``
  records: one ``kind="token"`` event per emission batch (a plain decode
  step yields one token; an accepted speculative window yields several) and
  a final ``kind="finish"`` carrying the reason, TTFT and preemption count.
* ``generate(prompt, ...)`` — convenience await: collects the stream and
  returns ``(finish_event, tokens)``.

Threading model (the engine itself is not thread-safe, so every engine call
is serialized):

* ``engine.step()`` runs in a worker thread via ``asyncio.to_thread`` — the
  event loop stays responsive to new connections/submissions while a step's
  jitted dispatches block.
* Submissions NEVER touch the engine from a coroutine: they append to an
  inbox and set a wake event; the run loop drains the inbox on the loop
  thread *between* steps (no step is in flight at that point).
* The engine's ``on_token`` / ``on_finish`` hooks fire on the worker thread
  mid-step; they forward events into per-request ``asyncio.Queue``s with
  ``loop.call_soon_threadsafe`` — the only cross-thread handoff.

The closed-loop ``engine.run_until_drained()`` drives the exact same
``step()``; this module adds arrival/departure plumbing only, so every
batch-mode test exercises the same scheduling and execution path the
always-on service runs.

Lifecycle extras for production service:

* ``cancel(req_id)`` enqueues an abort that the run loop applies between
  steps (``engine.abort`` frees blocks and prefix refs; the stream gets a
  ``finish`` event with the abort reason).  A consumer that abandons
  ``submit_stream`` mid-flight cancels its request automatically — a dead
  SSE socket stops burning decode slots.
* ``shutdown(timeout)`` is graceful drain: admission stops (new submissions
  raise ``ServiceUnavailable`` → HTTP 503), active requests finish within
  the hard timeout, then the loop stops.  ``launch.serve --http`` wires
  SIGTERM/SIGINT to it.

``engine`` may be a single ``InferenceEngine`` or a ``serving.router
.Router`` fleet — both expose the same ``submit`` / ``step`` / ``abort`` /
``has_work`` / hook surface, so always-on multi-replica serving is the
same loop.
"""

from __future__ import annotations

import asyncio
from collections import deque
from dataclasses import dataclass
from typing import AsyncIterator, Optional

from repro.serving.engine import InferenceEngine, Request
from repro.serving.faults import ServiceUnavailable


@dataclass(frozen=True)
class StreamEvent:
    """One streamed serving event.

    ``kind="token"``: ``tokens`` holds the newly emitted token ids and
    ``index`` the position of ``tokens[0]`` in the request's generated
    sequence (speculative decoding emits several tokens per event).
    ``kind="finish"``: ``reason`` is the request's finish reason —
    ``"eos"``/``"length"`` for normal completion, ``"cancelled"`` /
    ``"deadline_exceeded"`` / ``"aborted"`` for aborts, ``"error"`` for a
    failed loop; ``n_tokens`` the final generated length, ``ttft_s`` the
    time to first token and ``preemptions`` how often the request was
    evicted+resumed (failovers, under a router).
    """

    kind: str
    req_id: int
    tokens: tuple = ()
    index: int = 0
    reason: str = ""
    n_tokens: int = 0
    ttft_s: Optional[float] = None
    preemptions: int = 0


class AsyncEngine:
    """One background stepping task + streaming submission over an engine."""

    def __init__(self, engine: InferenceEngine):
        self.engine = engine
        self._inbox: deque = deque()  # tagged ops: ("submit", ...) / ("abort", ...)
        self._streams: dict[int, asyncio.Queue] = {}
        self._wake = asyncio.Event()
        self._idle = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._draining = False
        engine.on_token = self._on_token
        engine.on_finish = self._on_finish

    # -- engine hooks (called on the worker thread, mid-step) -----------
    def _on_token(self, req: Request, toks: list[int]) -> None:
        q = self._streams.get(req.req_id)
        if q is None or self._loop is None:
            return
        ev = StreamEvent(
            kind="token",
            req_id=req.req_id,
            tokens=tuple(toks),
            index=len(req.generated) - len(toks),
        )
        self._loop.call_soon_threadsafe(q.put_nowait, ev)

    def _on_finish(self, req: Request) -> None:
        q = self._streams.get(req.req_id)
        if q is None or self._loop is None:
            return
        eos = bool(req.generated) and req.generated[-1] == self.engine.eos
        ev = StreamEvent(
            kind="finish",
            req_id=req.req_id,
            reason=req.finish_reason or ("eos" if eos else "length"),
            n_tokens=len(req.generated),
            ttft_s=req.ttft,
            preemptions=req.preemptions,
        )
        self._loop.call_soon_threadsafe(q.put_nowait, ev)

    # -- lifecycle ------------------------------------------------------
    def start(self) -> None:
        """Start the background stepping task (idempotent; needs a running
        event loop).  ``submit_stream`` auto-starts on first use."""
        if self._task is None or self._task.done():
            self._loop = asyncio.get_running_loop()
            self._task = self._loop.create_task(self._run(), name="engine-step-loop")

    async def stop(self) -> None:
        """Cancel the stepping task (pending streams are failed with an
        ``"error"`` finish event)."""
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        self._fail_streams("error")

    async def __aenter__(self) -> "AsyncEngine":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def drain(self) -> None:
        """Wait until the engine has no waiting/active work and the inbox
        is empty (the async analogue of ``run_until_drained``)."""
        await self._idle.wait()

    async def shutdown(self, timeout: Optional[float] = None) -> bool:
        """Graceful shutdown: stop admission (subsequent submissions raise
        ``ServiceUnavailable``), wait for active requests to finish — at
        most ``timeout`` seconds — then stop the loop.  Returns True when
        the drain completed, False when the hard timeout cut it short
        (remaining streams are failed with an ``"error"`` finish)."""
        self._draining = True
        drained = True
        if self._task is not None and not self._task.done():
            try:
                await asyncio.wait_for(self.drain(), timeout)
            except asyncio.TimeoutError:
                drained = False
        await self.stop()
        return drained

    @property
    def draining(self) -> bool:
        return self._draining

    def cancel(self, req_id: int, reason: str = "cancelled") -> None:
        """Abort a request from any thread/coroutine: the run loop applies
        ``engine.abort`` between steps (blocks + prefix refs released, the
        stream receives a ``finish`` event with ``reason``).  Unknown or
        already-finished ids are a no-op."""
        self._inbox.append(("abort", req_id, reason))
        self._idle.clear()
        self._wake.set()

    def _fail_streams(self, reason: str) -> None:
        for req_id, q in list(self._streams.items()):
            q.put_nowait(StreamEvent(kind="finish", req_id=req_id, reason=reason))

    # -- the always-on loop ---------------------------------------------
    async def _run(self) -> None:
        eng = self.engine
        try:
            while True:
                # drain submissions on the loop thread; no step is in
                # flight here, so engine.submit is safe
                while self._inbox:
                    op = self._inbox.popleft()
                    if op[0] == "abort":
                        _, req_id, reason = op
                        eng.abort(req_id, reason)
                        continue
                    _, fut, prompt, kw = op
                    if fut.cancelled():
                        continue
                    try:
                        req = eng.submit(prompt, **kw)
                    except Exception as e:  # validation errors -> caller
                        fut.set_exception(e)
                        continue
                    q: asyncio.Queue = asyncio.Queue()
                    self._streams[req.req_id] = q
                    fut.set_result((req, q))
                if eng.has_work:
                    self._idle.clear()
                    await asyncio.to_thread(eng.step)
                else:
                    self._idle.set()
                    self._wake.clear()
                    await self._wake.wait()
                    self._idle.clear()
        except asyncio.CancelledError:
            raise
        except Exception:
            # a step blew up: fail every open stream so callers unblock,
            # then surface the error on the task
            self._fail_streams("error")
            raise

    # -- submission ------------------------------------------------------
    async def submit_stream(
        self,
        prompt: list[int],
        *,
        max_new_tokens: int = 32,
        online: bool = True,
        temperature: float = 0.0,
        top_k: int = 0,
        priority: int = 0,
        deadline_s: Optional[float] = None,
    ) -> AsyncIterator[StreamEvent]:
        """Submit a request and stream its events until it finishes.

        Yields ``StreamEvent``s; the last one has ``kind="finish"``.
        Validation errors from ``engine.submit`` raise here; while the
        service drains (``shutdown``) submissions raise
        ``ServiceUnavailable``.  Abandoning the generator before the finish
        event cancels the underlying request (its blocks free instead of
        generating for a consumer that left)."""
        if self._draining:
            raise ServiceUnavailable("service is draining; not accepting requests")
        self.start()
        fut = asyncio.get_running_loop().create_future()
        self._inbox.append(
            (
                "submit",
                fut,
                list(prompt),
                dict(
                    max_new_tokens=max_new_tokens,
                    online=online,
                    temperature=temperature,
                    top_k=top_k,
                    priority=priority,
                    deadline_s=deadline_s,
                ),
            )
        )
        self._idle.clear()
        self._wake.set()
        req, q = await fut
        finished = False
        try:
            while True:
                ev = await q.get()
                yield ev
                if ev.kind == "finish":
                    finished = True
                    return
        finally:
            self._streams.pop(req.req_id, None)
            if not finished:
                self.cancel(req.req_id)

    async def generate(self, prompt: list[int], **kw) -> tuple[StreamEvent, list[int]]:
        """Await a whole request: returns (finish event, generated tokens)."""
        toks: list[int] = []
        final: Optional[StreamEvent] = None
        async for ev in self.submit_stream(prompt, **kw):
            if ev.kind == "token":
                toks.extend(ev.tokens)
            else:
                final = ev
        assert final is not None
        return final, toks
