from repro.serving.engine import InferenceEngine, Request, RequestState
from repro.serving.kvcache import (
    clear_block_row,
    clear_slot,
    decode_cache_from_prefill,
    graft_prefill_into_blocks,
    make_engine_cache,
    make_table_row,
    write_request_into_slot,
)
from repro.serving.paged import BlockAllocator, OutOfBlocks, blocks_needed
from repro.serving.sampler import sample_token

__all__ = [
    "InferenceEngine",
    "Request",
    "RequestState",
    "BlockAllocator",
    "OutOfBlocks",
    "blocks_needed",
    "clear_block_row",
    "clear_slot",
    "decode_cache_from_prefill",
    "graft_prefill_into_blocks",
    "make_engine_cache",
    "make_table_row",
    "write_request_into_slot",
    "sample_token",
]
