from repro.serving.async_engine import AsyncEngine, StreamEvent
from repro.serving.engine import InferenceEngine, Request, RequestState, binary_chunks
from repro.serving.faults import FaultPlan, ReplicaCrashed, ServiceUnavailable
from repro.serving.http import HttpFrontend, serve_http
from repro.serving.replica import Replica, ReplicaState
from repro.serving.router import ROUTING_POLICIES, Router, RouterRequest
from repro.serving.scheduler import POLICIES, SchedulerCore
from repro.serving.metrics import (
    Counter,
    EnergyBridge,
    Gauge,
    Histogram,
    ManualClock,
    MetricsRegistry,
    exponential_buckets,
)
from repro.serving.trace import SCHEDULER_TRACK, TraceEvent, Tracer, replica_track, slot_track
from repro.serving.kvcache import (
    clear_block_row,
    clear_slot,
    copy_block_rows,
    decode_cache_from_prefill,
    gather_block_rows,
    graft_prefill_into_blocks,
    make_engine_cache,
    make_table_row,
    restore_block_rows,
    truncate_block_rows,
    write_request_into_slot,
)
from repro.serving.paged import BlockAllocator, OutOfBlocks, blocks_needed, truncate_blocks
from repro.serving.prefix import PartialHit, PrefixIndex, chain_hash, is_spilled, routing_key
from repro.serving.sampler import sample_token, sample_tokens, spec_accept
from repro.serving.spec_decode import DraftModel, make_draft_config, ngram_draft
from repro.serving.spill import SPILL_MODES, SpillPool

__all__ = [
    "InferenceEngine",
    "Request",
    "RequestState",
    "SchedulerCore",
    "POLICIES",
    "AsyncEngine",
    "StreamEvent",
    "HttpFrontend",
    "serve_http",
    "Router",
    "RouterRequest",
    "ROUTING_POLICIES",
    "Replica",
    "ReplicaState",
    "FaultPlan",
    "ReplicaCrashed",
    "ServiceUnavailable",
    "routing_key",
    "replica_track",
    "BlockAllocator",
    "OutOfBlocks",
    "PartialHit",
    "PrefixIndex",
    "binary_chunks",
    "blocks_needed",
    "chain_hash",
    "truncate_blocks",
    "spec_accept",
    "DraftModel",
    "make_draft_config",
    "ngram_draft",
    "SPILL_MODES",
    "SpillPool",
    "is_spilled",
    "clear_block_row",
    "clear_slot",
    "copy_block_rows",
    "decode_cache_from_prefill",
    "gather_block_rows",
    "graft_prefill_into_blocks",
    "make_engine_cache",
    "make_table_row",
    "restore_block_rows",
    "truncate_block_rows",
    "write_request_into_slot",
    "sample_token",
    "sample_tokens",
    "Counter",
    "EnergyBridge",
    "Gauge",
    "Histogram",
    "ManualClock",
    "MetricsRegistry",
    "exponential_buckets",
    "SCHEDULER_TRACK",
    "TraceEvent",
    "Tracer",
    "slot_track",
]
