from repro.serving.engine import InferenceEngine, Request, RequestState
from repro.serving.kvcache import (
    clear_slot,
    decode_cache_from_prefill,
    make_engine_cache,
    write_request_into_slot,
)
from repro.serving.sampler import sample_token

__all__ = [
    "InferenceEngine",
    "Request",
    "RequestState",
    "clear_slot",
    "decode_cache_from_prefill",
    "make_engine_cache",
    "write_request_into_slot",
    "sample_token",
]
