from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import make_schedule

__all__ = ["AdamWState", "adamw_init", "adamw_update", "make_schedule"]
