"""Learning-rate schedules (warmup + cosine / linear / constant)."""

from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str = "cosine", *, base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        if kind == "constant":
            decay = 1.0
        elif kind == "linear":
            frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
            decay = 1.0 - (1.0 - min_ratio) * frac
        elif kind == "cosine":
            frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
            decay = min_ratio + (1.0 - min_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            raise ValueError(f"unknown schedule {kind!r}")
        return base_lr * warm * decay

    return schedule
