"""AdamW with a configurable moment dtype and global-norm clipping.

No optax in this environment, so the optimizer is explicit.  Moments inherit
the parameter sharding (same tree structure -> same PartitionSpecs), which is
what makes optimizer state ZeRO-sharded under FSDP for free.  The
``optimizer_dtype`` knob (fp32 default, bf16 for arctic-480b) is the
"fits-on-one-pod" lever documented in EXPERIMENTS.md.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    m: Any  # tree like params
    v: Any  # tree like params


def adamw_init(params, *, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    params,
    grads,
    state: AdamWState,
    *,
    lr: jax.Array | float,
    beta1: float = 0.9,
    beta2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 0.0,
    layer_scan: bool = False,
):
    """One AdamW step. Returns (new_params, new_state, metrics).

    ``layer_scan``: apply the update one leading-dim (layer) slice at a time
    for stacked >=3-D leaves.  The Adam math upcasts to fp32; on a 480 B-param
    MoE the fp32 intermediates of a whole stacked expert tensor are ~2.4 GB
    per temp PER TENSOR — scanning bounds them to one layer's slice.
    """
    step = state.step + 1
    metrics = {}
    if grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        metrics["grad_norm"] = gnorm
    b1c = 1.0 - beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - beta2 ** step.astype(jnp.float32)

    def upd_math(p, g, m, v, wd):
        gf = g.astype(jnp.float32)
        mf = beta1 * m.astype(jnp.float32) + (1 - beta1) * gf
        vf = beta2 * v.astype(jnp.float32) + (1 - beta2) * gf * gf
        mhat = mf / b1c
        vhat = vf / b2c
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * pf)
        return new_p.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    def upd(p, g, m, v):
        # decoupled weight decay on matrices only (ndim >= 2, excluding the
        # stacked-layer dim convention keeps norms/scales decay-free)
        wd = weight_decay if p.ndim >= 2 else 0.0
        if layer_scan and p.ndim >= 3 and p.shape[0] > 1:
            def body(_, sl):
                return None, upd_math(*sl, wd)

            _, (np_, nm, nv) = jax.lax.scan(body, None, (p, g, m, v))
            return np_, nm, nv
        return upd_math(p, g, m, v, wd)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics
