"""Run-time configuration: meshes, parallelism, precision, train/serve knobs.

The four assigned input shapes are defined here verbatim; every architecture is
crossed with its own shape set at dry-run time (see ``repro.launch.dryrun``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MeshConfig:
    """The production mesh from the assignment.

    single pod : (data=16, model=16)          = 256 chips
    multi pod  : (pod=2, data=16, model=16)   = 512 chips
    """

    multi_pod: bool = False

    @property
    def shape(self) -> tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axis_names(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> tuple[str, ...]:
        """Axes the batch is sharded over (DP/FSDP axes)."""
        return ("pod", "data") if self.multi_pod else ("data",)

    @property
    def model_axis(self) -> str:
        return "model"


@dataclass(frozen=True)
class ParallelConfig:
    """How logical tensor axes map onto the mesh. See parallel/sharding.py."""

    # ZeRO-3/FSDP: shard params + optimizer state over the data axes.
    fsdp: bool = True
    # Tensor parallelism over the "model" axis (heads / FFN hidden / experts).
    tensor_parallel: bool = True
    # Shard the residual-stream sequence dim over "model" between blocks
    # (sequence parallelism; needed for the 32k/500k cells).
    sequence_parallel: bool = False
    # Gradient accumulation microbatches inside one train_step.
    num_microbatches: int = 1
    # Activation checkpointing policy for the scanned block:
    #   "none" | "full" (nothing saveable) | "dots" (dots saveable)
    remat: str = "full"
    # Gradient all-reduce compression: "none" | "bf16" | "int8" (see
    # parallel/collectives.py). Applied to the cross-pod gradient sync.
    grad_compression: str = "none"
    # Apply Adam one layer-slice at a time (bounds fp32 update temps on
    # 100B+ stacked params; see optim/adamw.py).
    optimizer_layer_scan: bool = False


@dataclass(frozen=True)
class PrecisionConfig:
    param_dtype: str = "float32"  # storage dtype of the master weights
    compute_dtype: str = "bfloat16"
    # Optimizer moments; "bfloat16" halves optimizer memory (arctic-480b).
    optimizer_dtype: str = "float32"
    logits_dtype: str = "float32"

    # --- FP8 quantized training (repro.fp8) ---
    # Route FFN + attention-projection GEMMs through FP8 with delayed
    # scaling; logits/norms/softmax stay on the mixed-precision path above.
    fp8: bool = False
    fp8_dtype: str = "e4m3"  # forward operand dtype; gradients always use e5m2
    fp8_amax_history: int = 16  # delayed-scaling amax window (steps)
    fp8_margin: float = 0.0  # scale headroom: scale = fp8_max / (2^margin * amax)
    fp8_gemm: str = "ref"  # "ref" (jnp/XLA) | "pallas" (tiled TPU kernel)


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    z_loss: float = 1e-4  # PaLM-style logit regularizer; also stabilizes fp32 softmax


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 128
    max_seq_len: int = 32_768
    # Paged KV cache block size (tokens per block) for the serving engine.
    page_size: int = 256
    temperature: float = 0.0
    eos_token: int = 1


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assignment's four shapes, verbatim.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    """Everything the launcher needs for one run."""

    arch: str
    mesh: MeshConfig = MeshConfig()
    parallel: ParallelConfig = ParallelConfig()
    precision: PrecisionConfig = PrecisionConfig()
    train: TrainConfig = TrainConfig()
    serve: ServeConfig = ServeConfig()
