from repro.config.model import (
    ModelConfig,
    MoEConfig,
    SSMConfig,
    RWKVConfig,
    VisionConfig,
)
from repro.config.run import (
    MeshConfig,
    ParallelConfig,
    PrecisionConfig,
    TrainConfig,
    ServeConfig,
    ShapeConfig,
    RunConfig,
    SHAPES,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "RWKVConfig",
    "VisionConfig",
    "MeshConfig",
    "ParallelConfig",
    "PrecisionConfig",
    "TrainConfig",
    "ServeConfig",
    "ShapeConfig",
    "RunConfig",
    "SHAPES",
]
