"""Architecture configuration.

One ``ModelConfig`` describes any member of the supported LM families:

* ``dense``   — standard decoder-only transformer (GQA/MQA, RoPE, gated MLP)
* ``moe``     — dense attention + mixture-of-experts FFN (top-k routing,
                optional shared/dense-residual experts, GShard-style dispatch)
* ``ssm``     — attention-free RWKV6 (Finch) stack
* ``hybrid``  — Hymba-style parallel attention + Mamba heads per block
* ``audio``   — encoder-only transformer over precomputed frame embeddings
* ``vlm``     — decoder with interleaved cross-attention image layers

The config is deliberately explicit (no derived magic): every field that a
block builder reads is spelled out here so that ``src/repro/configs/<arch>.py``
files are an exact transcription of the assignment table.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

Family = str  # "dense" | "moe" | "ssm" | "hybrid" | "audio" | "vlm"


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 2
    # Expert FFN hidden size (may differ from cfg.d_ff which is the dense FFN).
    expert_d_ff: int = 0
    # Arctic: a dense FFN runs in parallel with the MoE experts on every layer.
    dense_residual: bool = False
    # DeepSeek-style always-on shared experts (0 = none).
    num_shared_experts: int = 0
    # GShard dispatch parameters.
    capacity_factor: float = 1.25
    # Tokens are dispatched in groups of this size to bound the one-hot
    # dispatch tensor (see models/moe.py); 0 = single group.
    group_size: int = 4096
    # Load-balance auxiliary loss weight.
    aux_loss_weight: float = 0.01
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM (used by the hybrid family)."""

    state_size: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)
    chunk_size: int = 256


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) time-mix / channel-mix parameters."""

    head_size: int = 64
    # Low-rank adapter widths for the data-dependent mixing / decay.
    lora_rank_decay: int = 64
    lora_rank_mix: int = 32
    lora_rank_gate: int = 64
    chunk_size: int = 128


@dataclass(frozen=True)
class VisionConfig:
    """Stubbed modality frontend: precomputed patch embeddings are model input."""

    num_image_tokens: int = 1600
    cross_attn_every: int = 5  # every Nth layer is a cross-attention layer


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- normalization / activation ---
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm" | "layernorm_np" (OLMo)
    norm_eps: float = 1e-5
    activation: str = "swiglu"  # "swiglu" | "geglu" | "gelu" | "silu" | "relu2"
    use_bias: bool = False
    parallel_residual: bool = False  # attn and FFN read the same normed input
    qk_norm: bool = False  # Qwen3: RMSNorm on q/k per head

    # --- position / attention ---
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0  # StableLM2 uses 0.25
    causal: bool = True  # False for encoder-only
    sliding_window: int = 0  # 0 = full attention
    attn_logit_softcap: float = 0.0
    # Gemma scales embeddings by sqrt(d_model).
    scale_embedding: bool = False
    tie_embeddings: bool = True
    # Encoder-only models use learned absolute positions (stub frontend).
    learned_pos_embedding: bool = False
    max_position: int = 524_288

    # --- family-specific sub-configs ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    vision: Optional[VisionConfig] = None

    # --- bookkeeping ---
    # True if the architecture has a sub-quadratic sequence mechanism, i.e.
    # the long_500k shape is runnable (assignment rule).
    subquadratic: bool = False
    # Citation string straight from the assignment table.
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(
                f"{self.name}: num_heads={self.num_heads} not a multiple of "
                f"num_kv_heads={self.num_kv_heads}"
            )
        if self.family in ("moe",) and self.moe is None:
            raise ValueError(f"{self.name}: family=moe requires moe config")
        if self.family == "ssm" and self.rwkv is None:
            raise ValueError(f"{self.name}: family=ssm requires rwkv config")
        if self.family == "hybrid" and self.ssm is None:
            raise ValueError(f"{self.name}: family=hybrid requires ssm config")
        if self.family == "vlm" and self.vision is None:
            raise ValueError(f"{self.name}: family=vlm requires vision config")

    # ------------------------------------------------------------------
    @property
    def is_encoder_only(self) -> bool:
        return self.family == "audio" or not self.causal

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the logits/vocab dim
        shards on the model axis (hymba's 32,001 would otherwise replicate a
        4 GB fp32 logits tensor per device).  Padded columns are masked to
        -1e9 in lm_logits; every production framework does this."""
        return -(-self.vocab_size // 128) * 128

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline cross-checks)."""
        import numpy as np

        from repro.models.initializers import param_specs
        from repro.models.layers import is_spec
        import jax

        total = 0
        for s in jax.tree.leaves(param_specs(self), is_leaf=is_spec):
            total += int(np.prod(s.shape, dtype=np.int64))
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts active)."""
        total = self.param_count()
        if self.family != "moe" or self.moe is None:
            return total
        m = self.moe
        per_expert = self._expert_params()
        inactive = (m.num_experts - m.top_k) * per_expert * self.num_layers
        return total - inactive

    def _expert_params(self) -> int:
        m = self.moe
        gated = self.activation in ("swiglu", "geglu")
        in_w = self.d_model * m.expert_d_ff * (2 if gated else 1)
        out_w = m.expert_d_ff * self.d_model
        return in_w + out_w


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Shrink a full config to something a 1-core CPU can run a step of.

    Keeps the *family machinery* (MoE routing, RWKV scan, cross-attention,
    parallel SSM heads) while cutting widths/depths/experts/vocab.
    """
    kw: dict = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        max_position=512,
    )
    if cfg.family == "vlm":
        # keep the 4-self + 1-cross group structure -> 5 layers minimum
        kw["num_layers"] = cfg.vision.cross_attn_every
        kw["vision"] = VisionConfig(num_image_tokens=8, cross_attn_every=cfg.vision.cross_attn_every)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), expert_d_ff=32, group_size=64
        )
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(
            cfg.rwkv, head_size=16, lora_rank_decay=8, lora_rank_mix=4, lora_rank_gate=8, chunk_size=16
        )
        kw["num_heads"] = 4  # d_model / head_size
        kw["head_dim"] = 16
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_size=4, chunk_size=16)
        kw["num_heads"] = 5 if cfg.num_heads % 2 == 1 else 4  # keep odd-head coverage
        kw["num_kv_heads"] = 1
        kw["head_dim"] = 16
        kw["d_model"] = kw["num_heads"] * 16
    if cfg.sliding_window:
        kw["sliding_window"] = 32
    return cfg.replace(**kw)
