"""QoS-class scheduler: the paper's four AI usage patterns, made executable.

Paper §IV.F identifies four patterns the resource manager must serve —

* **experimentation** — short, large-capacity, interactive (fast start)
* **training**        — days-to-months, large capacity
* **fine-tuning**     — short, low capacity
* **inference**       — online/offline serving pipelines (latency-sensitive)

— and two scheduling modes borrowed from Google's AI-hypercomputer model:

* **Flex Start with guaranteed completion**: batch jobs that may be
  preempted/interrupted but are ALWAYS resumed from their periodic
  checkpoint until they complete (modes 2 & 4 in the paper).
* **Calendar**: reserved start/stop windows with automated start (1, 3, 4).

This module implements both on top of ``core.cluster.Cluster`` with
conservative backfill, per-QoS priorities/preemption and placement that
prefers keeping a job inside one pod (the paper's tightly-integrated-fabric
argument).  It is a deterministic discrete-time simulator: production would
drive ``tick`` from a wall clock, tests drive it manually.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional

from repro.core.cluster import CHIPS_PER_NODE, Cluster, Node, NodeState


class QoS(enum.Enum):
    EXPERIMENTATION = "experimentation"
    TRAINING = "training"
    FINE_TUNING = "fine_tuning"
    INFERENCE = "inference"


# priority: inference serving first (latency), interactive next, batch last
PRIORITY = {QoS.INFERENCE: 0, QoS.EXPERIMENTATION: 1, QoS.FINE_TUNING: 2, QoS.TRAINING: 3}

# preemption: lower-priority-value jobs may preempt higher-value ones
PREEMPTIBLE_BY_DEFAULT = {QoS.TRAINING: True, QoS.FINE_TUNING: True, QoS.EXPERIMENTATION: False, QoS.INFERENCE: False}


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    PREEMPTED = "preempted"  # will flex-restart from checkpoint
    INTERRUPTED = "interrupted"  # node failure; awaiting restart
    COMPLETED = "completed"
    FAILED = "failed"  # exceeded restart budget


@dataclass
class Job:
    job_id: str
    tenant: str
    qos: QoS
    chips: int  # requested chips (rounded up to whole nodes)
    duration: float  # estimated remaining runtime (sim seconds)
    submit_time: float = 0.0
    preemptible: Optional[bool] = None
    checkpoint_interval: float = 60.0  # flex-start periodic checkpoint cadence
    # elasticity: the job can run on any chip count in [min_chips, chips]
    min_chips: Optional[int] = None
    state: JobState = JobState.PENDING
    nodes: list[int] = field(default_factory=list)
    start_time: float = -1.0
    progress: float = 0.0  # completed work (sim seconds at full capacity)
    last_checkpoint: float = 0.0  # progress value at the last checkpoint
    restarts: int = 0
    max_restarts: int = 16
    preemptions: int = 0

    def __post_init__(self):
        if self.preemptible is None:
            self.preemptible = PREEMPTIBLE_BY_DEFAULT[self.qos]
        if self.min_chips is None:
            self.min_chips = self.chips

    @property
    def nodes_needed(self) -> int:
        return -(-self.chips // CHIPS_PER_NODE)

    @property
    def remaining(self) -> float:
        return max(self.duration - self.progress, 0.0)


@dataclass
class Reservation:
    """Calendar mode: a guaranteed capacity window with automated start."""

    res_id: str
    tenant: str
    chips: int
    start: float
    end: float
    job: Optional[Job] = None  # job auto-started inside the window

    @property
    def nodes_needed(self) -> int:
        return -(-self.chips // CHIPS_PER_NODE)


class Scheduler:
    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        self.queue: list[Job] = []
        self.running: dict[str, Job] = {}
        self.done: dict[str, Job] = {}
        self.reservations: list[Reservation] = []
        self.log: list[tuple[float, str, str]] = []  # (time, event, job/res id)
        cluster.on_event(self._cluster_event)
        self._now = 0.0

    # ------------------------------------------------------------------
    def submit(self, job: Job) -> Job:
        job.submit_time = self._now
        self.queue.append(job)
        self._log("submit", job.job_id)
        return job

    def reserve(self, res: Reservation) -> Reservation:
        self.reservations.append(res)
        self._log("reserve", res.res_id)
        return res

    def _log(self, event: str, ident: str) -> None:
        self.log.append((self._now, event, ident))

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------

    def _reserved_nodes_now(self, horizon: float = 0.0) -> int:
        """Nodes that must stay free for reservations active at now+horizon."""
        t = self._now + horizon
        return sum(
            r.nodes_needed
            for r in self.reservations
            if r.start <= t < r.end and r.job is None
        )

    def _pick_nodes(self, job: Job) -> Optional[list[int]]:
        """Prefer a single pod (tight fabric); spill across pods only if the
        job itself is bigger than a pod."""
        need = job.nodes_needed
        pods = sorted({n.pod for n in self.cluster.nodes.values()})
        # single-pod placement
        for pod in pods:
            free = self.cluster.free_nodes(pod)
            if len(free) >= need:
                return [n.node_id for n in free[:need]]
        # multi-pod spill: largest-free-first
        free_all = sorted(self.cluster.free_nodes(), key=lambda n: n.pod)
        if len(free_all) >= need:
            return [n.node_id for n in free_all[:need]]
        return None

    def _start(self, job: Job, nodes: list[int]) -> None:
        self.cluster.allocate(nodes, job.job_id, job.tenant)
        job.nodes = nodes
        job.state = JobState.RUNNING
        job.start_time = self._now
        self.running[job.job_id] = job
        self._log("start", job.job_id)

    def _stop(self, job: Job, state: JobState, *, rollback: bool) -> None:
        self.cluster.release(job.job_id)
        job.nodes = []
        job.state = state
        self.running.pop(job.job_id, None)
        if rollback:
            # flex-start semantics: lose work since the last checkpoint
            job.progress = job.last_checkpoint
        if state in (JobState.COMPLETED, JobState.FAILED):
            self.done[job.job_id] = job
        self._log(state.value, job.job_id)

    # ------------------------------------------------------------------
    # the clock
    # ------------------------------------------------------------------

    def tick(self, now: float) -> None:
        """Advance simulated time to ``now``: progress work, checkpoint,
        complete, start reservations, schedule the queue (with backfill)."""
        dt = now - self._now
        assert dt >= 0, "time went backwards"
        self._now = now

        # 1. progress running jobs; take periodic checkpoints; complete
        for job in list(self.running.values()):
            job.progress += dt
            while job.progress - job.last_checkpoint >= job.checkpoint_interval:
                job.last_checkpoint += job.checkpoint_interval
                self._log("checkpoint", job.job_id)
            if job.progress >= job.duration:
                self._stop(job, JobState.COMPLETED, rollback=False)

        # 2. calendar reservations: auto-start at window open, stop at close
        for res in self.reservations:
            if res.job is not None and res.job.state == JobState.RUNNING and now >= res.end:
                self._stop(res.job, JobState.COMPLETED, rollback=False)
            if res.job is None and res.start <= now < res.end:
                job = Job(
                    job_id=f"res:{res.res_id}",
                    tenant=res.tenant,
                    qos=QoS.TRAINING,
                    chips=res.chips,
                    duration=res.end - res.start,
                    preemptible=False,
                )
                nodes = self._pick_nodes(job)
                if nodes is None:
                    nodes = self._evict_for(job)
                if nodes is not None:
                    res.job = job
                    self._start(job, nodes)

        # 3. schedule the queue by priority, then backfill
        self._schedule_queue()

    def _schedule_queue(self) -> None:
        self.queue.sort(key=lambda j: (PRIORITY[j.qos], j.submit_time))
        scheduled = []
        reserved = self._reserved_nodes_now(horizon=0.0)
        for job in self.queue:
            free = len(self.cluster.free_nodes()) - reserved
            need = job.nodes_needed
            nodes = self._pick_nodes(job) if free >= need else None
            if nodes is not None:
                self._start(job, nodes)
                scheduled.append(job)
                continue
            # elastic shrink: flex jobs can start on fewer chips
            if job.min_chips < job.chips and free * CHIPS_PER_NODE >= job.min_chips:
                shrunk = Job(**{**job.__dict__, "chips": free * CHIPS_PER_NODE})
                nodes = self._pick_nodes(shrunk)
                if nodes is not None:
                    job.chips = shrunk.chips
                    self._start(job, nodes)
                    scheduled.append(job)
                    self._log("elastic_shrink_start", job.job_id)
                    continue
            # preemption: inference/experimentation may evict flex batch jobs
            if PRIORITY[job.qos] <= PRIORITY[QoS.EXPERIMENTATION]:
                nodes = self._evict_for(job)
                if nodes is not None:
                    self._start(job, nodes)
                    scheduled.append(job)
        for job in scheduled:
            self.queue.remove(job)

    def _evict_for(self, job: Job) -> Optional[list[int]]:
        """Preempt lowest-priority preemptible jobs until ``job`` fits."""
        victims = sorted(
            (j for j in self.running.values() if j.preemptible),
            key=lambda j: -PRIORITY[j.qos],
        )
        freed = len(self.cluster.free_nodes())
        plan = []
        for v in victims:
            if freed >= job.nodes_needed:
                break
            freed += len(v.nodes)
            plan.append(v)
        if freed < job.nodes_needed:
            return None
        for v in plan:
            v.preemptions += 1
            self._stop(v, JobState.PREEMPTED, rollback=True)
            self.queue.append(v)  # flex-start: guaranteed completion
            v.state = JobState.PENDING
        return self._pick_nodes(job)

    # ------------------------------------------------------------------
    # fault events (wired by core.fault.FaultTolerantRunner as well)
    # ------------------------------------------------------------------

    def _cluster_event(self, event: str, node: Node) -> None:
        if event != "failed" or node.job is None:
            return
        job = self.running.get(node.job)
        if job is None:
            return
        job.restarts += 1
        if job.restarts > job.max_restarts:
            self._stop(job, JobState.FAILED, rollback=True)
            return
        # flex-start: roll back to checkpoint and requeue (guaranteed completion)
        self._stop(job, JobState.INTERRUPTED, rollback=True)
        job.state = JobState.PENDING
        self.queue.append(job)
        self._log("restart_queued", job.job_id)

    # ------------------------------------------------------------------
    def utilization(self) -> float:
        busy = sum(len(j.nodes) for j in self.running.values())
        total = len([n for n in self.cluster.nodes.values() if n.state == NodeState.HEALTHY]) or 1
        return busy / total
