"""Elastic scaling: re-mesh + re-shard a live job when capacity changes.

Paper §III.F lists elasticity as a first-class AI-platform requirement.  For
a JAX SPMD job that means: pick a new (data, model) mesh for the surviving
chip count, keep per-chip batch constant (global batch scales with capacity —
the standard elastic-training contract), and ``jax.device_put`` every state
leaf onto the new sharding.  Re-sharding moves only data (parameters are
resharded, not re-initialized), so the loss trajectory continues within
optimizer-batch tolerance — asserted in tests/test_elastic.py.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh

from repro.config import MeshConfig, ParallelConfig


@dataclass(frozen=True)
class ElasticPlan:
    old_chips: int
    new_chips: int
    data: int  # new data-parallel degree
    model: int  # new model-parallel degree
    old_global_batch: int
    new_global_batch: int

    @property
    def shape(self) -> tuple[int, int]:
        return (self.data, self.model)


def plan_resize(
    *,
    old_chips: int,
    new_chips: int,
    model_parallel: int,
    global_batch: int,
    batch_divisor: int = 1,
) -> ElasticPlan:
    """Choose the largest usable mesh on the new capacity.

    Keeps the model-parallel degree (sharding the model differently would
    need a full re-layout); data-parallel shrinks to what fits; per-chip
    batch stays constant so step time is unchanged and throughput scales
    with capacity.
    """
    if new_chips < model_parallel:
        raise ValueError(f"cannot fit model_parallel={model_parallel} on {new_chips} chips")
    data = new_chips // model_parallel
    # keep global batch divisible by the new data degree (and any divisor)
    per_data = max(global_batch // max(old_chips // model_parallel, 1), 1)
    new_batch = max(per_data * data, batch_divisor)
    new_batch -= new_batch % max(batch_divisor, 1)
    return ElasticPlan(
        old_chips=old_chips,
        new_chips=new_chips,
        data=data,
        model=model_parallel,
        old_global_batch=global_batch,
        new_global_batch=max(new_batch, batch_divisor),
    )


def make_elastic_mesh(plan: ElasticPlan, devices=None) -> Mesh:
    import numpy as np

    devices = devices if devices is not None else jax.devices()
    need = plan.data * plan.model
    if len(devices) < need:
        # CPU test hosts have fewer devices: tile the plan onto what exists
        # (sharding semantics preserved; physical placement degenerate)
        need = len(devices)
        data = max(need // plan.model, 1)
        grid = np.array(devices[: data * min(plan.model, need)]).reshape(data, -1)
        return Mesh(grid, ("data", "model"))
    grid = np.array(devices[:need]).reshape(plan.data, plan.model)
    return Mesh(grid, ("data", "model"))


def reshard_state(state, new_shardings):
    """Move every leaf onto its new sharding (data motion only)."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, new_shardings)


def resize_batch(batch, plan: ElasticPlan):
    """Shrink/grow the global batch to the plan (drop or repeat tail)."""

    def fix(x):
        b = x.shape[0]
        if b == plan.new_global_batch:
            return x
        if b > plan.new_global_batch:
            return x[: plan.new_global_batch]
        reps = -(-plan.new_global_batch // b)
        import jax.numpy as jnp

        return jnp.concatenate([x] * reps, axis=0)[: plan.new_global_batch]

    return jax.tree.map(fix, batch)
