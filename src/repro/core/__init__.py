"""The paper's contribution: an AI-platform runtime for a leadership-class
facility — QoS scheduling, tenancy, elasticity, fault tolerance, telemetry."""

from repro.core.cluster import (
    CHIPS_PER_NODE,
    Cluster,
    ClusterSpec,
    Node,
    NodeState,
    DRYRUN_MULTI,
    DRYRUN_SINGLE,
    PHASE1,
    PHASE2,
)
from repro.core.elastic import ElasticPlan, make_elastic_mesh, plan_resize, reshard_state, resize_batch
from repro.core.fault import FaultTolerantRunner, RunReport
from repro.core.federation import IAM, Identity, Role
from repro.core.scheduler import Job, JobState, QoS, Reservation, Scheduler
from repro.core.straggler import StragglerDetector
from repro.core.telemetry import EnergyLedger, effective_pue, mw_check
from repro.core.tenancy import Tenant, TenantManager

__all__ = [
    "CHIPS_PER_NODE",
    "Cluster",
    "ClusterSpec",
    "Node",
    "NodeState",
    "DRYRUN_MULTI",
    "DRYRUN_SINGLE",
    "PHASE1",
    "PHASE2",
    "ElasticPlan",
    "make_elastic_mesh",
    "plan_resize",
    "reshard_state",
    "resize_batch",
    "FaultTolerantRunner",
    "RunReport",
    "IAM",
    "Identity",
    "Role",
    "Job",
    "JobState",
    "QoS",
    "Reservation",
    "Scheduler",
    "StragglerDetector",
    "EnergyLedger",
    "effective_pue",
    "mw_check",
    "Tenant",
    "TenantManager",
]
