"""RBAC-lite identity layer: roles, limited-duration tokens, federation stub.

Paper §III.G/H: MyAccessID-federated single sign-on, KeyCloak+OPA RBAC with
limited-duration tokens, tenant-admin vs infrastructure-admin personas.  This
module provides exactly the subset the scheduler/tenancy APIs need to enforce
those semantics in-process (no network identity provider is emulated — the
federation handshake is reduced to ``federated_login`` returning a token).
"""

from __future__ import annotations

import enum
import hashlib
import hmac
import time
from dataclasses import dataclass, field
from typing import Optional


class Role(enum.Enum):
    USER = "user"
    TENANT_ADMIN = "tenant_admin"
    INFRA_ADMIN = "infra_admin"


_ORDER = {Role.USER: 0, Role.TENANT_ADMIN: 1, Role.INFRA_ADMIN: 2}


@dataclass
class Identity:
    subject: str  # e.g. "alice@bristol.ac.uk"
    home_idp: str  # institutional IdP (eduGAIN federation)
    roles: dict[str, Role] = field(default_factory=dict)  # scope -> role


@dataclass
class Token:
    subject: str
    issued: float
    expires: float
    mac: str


class IAM:
    """In-process KeyCloak/OPA stand-in with HMAC'd expiring tokens."""

    def __init__(self, *, token_ttl: float = 3600.0, secret: bytes = b"isambard-ai", clock=time.monotonic):
        self.token_ttl = token_ttl
        self._secret = secret
        self._clock = clock
        self.identities: dict[str, Identity] = {}
        self._tokens: dict[str, Token] = {}

    # ------------------------------------------------------------------
    def federated_login(self, subject: str, home_idp: str) -> str:
        """MyAccessID-style login: auto-provision on first arrival."""
        ident = self.identities.setdefault(subject, Identity(subject, home_idp))
        ident.roles.setdefault("*", Role.USER)
        now = self._clock()
        payload = f"{subject}|{now}".encode()
        mac = hmac.new(self._secret, payload, hashlib.sha256).hexdigest()[:32]
        tok = Token(subject=subject, issued=now, expires=now + self.token_ttl, mac=mac)
        self._tokens[mac] = tok
        return mac

    def grant(self, subject: str, role: Role, scope: str = "*") -> None:
        ident = self.identities.setdefault(subject, Identity(subject, "local"))
        ident.roles[scope] = role

    # ------------------------------------------------------------------
    def resolve(self, token: str) -> Identity:
        tok = self._tokens.get(token)
        if tok is None:
            raise PermissionError("unknown token")
        if self._clock() > tok.expires:
            raise PermissionError("token expired")
        return self.identities[tok.subject]

    def require(self, token: str, role: Role, scope: str = "*") -> Identity:
        ident = self.resolve(token)
        have = ident.roles.get(scope, ident.roles.get("*", Role.USER))
        if _ORDER[have] < _ORDER[role]:
            raise PermissionError(f"{ident.subject} lacks {role.value} on {scope!r}")
        return ident
