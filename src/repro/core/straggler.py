"""Straggler detection & mitigation from step-time telemetry.

At 1,000+ nodes, tail latency from a single slow blade gates every
synchronous collective (the paper's tightly-coupled fabric makes the whole
step wait).  The detector keeps per-node EWMA step times, flags nodes whose
EWMA exceeds the healthy median by a configurable factor, and recommends the
standard mitigation ladder: (1) observe, (2) drain+replace at the next
checkpoint boundary, (3) hard-evict (triggering flex-restart).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class StragglerDetector:
    alpha: float = 0.3  # EWMA coefficient
    slow_factor: float = 1.5  # flag if ewma > factor * median
    evict_factor: float = 3.0  # hard-evict threshold
    min_samples: int = 3
    ewma: dict[int, float] = field(default_factory=dict)
    samples: dict[int, int] = field(default_factory=dict)

    def observe(self, node_id: int, step_time: float) -> None:
        prev = self.ewma.get(node_id)
        self.ewma[node_id] = step_time if prev is None else (1 - self.alpha) * prev + self.alpha * step_time
        self.samples[node_id] = self.samples.get(node_id, 0) + 1

    def _median(self) -> float:
        vals = sorted(v for k, v in self.ewma.items() if self.samples.get(k, 0) >= self.min_samples)
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def stragglers(self) -> dict[int, str]:
        """node_id -> recommended action ("drain" | "evict")."""
        med = self._median()
        if med <= 0:
            return {}
        out = {}
        for nid, v in self.ewma.items():
            if self.samples.get(nid, 0) < self.min_samples:
                continue
            if v > self.evict_factor * med:
                out[nid] = "evict"
            elif v > self.slow_factor * med:
                out[nid] = "drain"
        return out

    def step_slowdown(self) -> float:
        """Synchronous-step slowdown = max(ewma)/median (1.0 = no straggler)."""
        med = self._median()
        if med <= 0:
            return 1.0
        worst = max(
            (v for k, v in self.ewma.items() if self.samples.get(k, 0) >= self.min_samples),
            default=med,
        )
        return worst / med
