"""TAPMS-style multi-tenancy: tenant partitions over the device grid.

Paper §IV.F: CSM's Tenant and Partition Management System (TAPMS) assigns
*bare-metal nodes* to tenants; tenant admins get a "repurposed compute node"
(rCN) as their login/JupyterHub frontend.  The TPU adaptation (DESIGN.md §2):
a tenant owns a contiguous sub-grid of chips, which materializes as a JAX
sub-mesh carved out of the production mesh — Slingshot VNI isolation becomes
mesh-partition isolation.

``TenantManager`` enforces: capacity quotas, node exclusivity, rCN
assignment, and RBAC via ``core.federation`` (tenant-admin vs infra-admin
personas, limited-duration tokens).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cluster import CHIPS_PER_NODE, Cluster
from repro.core.federation import IAM, Role


@dataclass
class Tenant:
    name: str
    quota_nodes: int
    nodes: list[int] = field(default_factory=list)
    rcn: Optional[int] = None  # repurposed compute node (login frontend)
    admins: list[str] = field(default_factory=list)

    @property
    def chips(self) -> int:
        return len(self.nodes) * CHIPS_PER_NODE


class TenantManager:
    def __init__(self, cluster: Cluster, iam: IAM | None = None):
        self.cluster = cluster
        self.iam = iam or IAM()
        self.tenants: dict[str, Tenant] = {}

    # ------------------------------------------------------------------
    def create_tenant(self, name: str, quota_nodes: int, admin: str, *, token: str) -> Tenant:
        self.iam.require(token, Role.INFRA_ADMIN)
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} exists")
        t = Tenant(name=name, quota_nodes=quota_nodes, admins=[admin])
        self.tenants[name] = t
        self.iam.grant(admin, Role.TENANT_ADMIN, scope=name)
        return t

    def grow_tenant(self, name: str, n_nodes: int, *, token: str) -> Tenant:
        """Assign n_nodes free healthy nodes to the tenant (pod-local first)."""
        t = self.tenants[name]
        self.iam.require(token, Role.INFRA_ADMIN)
        if len(t.nodes) + n_nodes > t.quota_nodes:
            raise PermissionError(f"tenant {name!r} quota exceeded")
        free = [n for n in self.cluster.free_nodes() if n.tenant is None]
        free.sort(key=lambda n: n.pod)
        if len(free) < n_nodes:
            raise RuntimeError("insufficient free nodes")
        for n in free[:n_nodes]:
            n.tenant = name
            t.nodes.append(n.node_id)
        if t.rcn is None and t.nodes:
            # first node becomes the tenant's login frontend (rCN)
            t.rcn = t.nodes[0]
        return t

    def shrink_tenant(self, name: str, n_nodes: int, *, token: str) -> Tenant:
        t = self.tenants[name]
        self.iam.require(token, Role.INFRA_ADMIN)
        removable = [nid for nid in t.nodes if self.cluster.nodes[nid].job is None and nid != t.rcn]
        if len(removable) < n_nodes:
            raise RuntimeError("nodes busy; drain jobs first")
        for nid in removable[:n_nodes]:
            t.nodes.remove(nid)
            self.cluster.nodes[nid].tenant = None
        return t

    # ------------------------------------------------------------------
    def tenant_submesh_shape(self, name: str, model_parallel: int = 1) -> tuple[int, int]:
        """(data, model) sub-mesh shape over the tenant's chips."""
        t = self.tenants[name]
        chips = t.chips
        if chips % model_parallel != 0:
            raise ValueError(f"{chips} chips not divisible by model={model_parallel}")
        return (chips // model_parallel, model_parallel)

    def make_tenant_mesh(self, name: str, model_parallel: int = 1):
        """A real jax mesh over the tenant's share of the local device pool.

        On the CPU test host this carves the tenant's proportional slice of
        ``jax.devices()``; on a real pod the same code receives the tenant's
        physical chips from the fabric inventory.
        """
        import jax

        t = self.tenants[name]
        total_nodes = len(self.cluster.nodes)
        devs = jax.devices()
        share = max(1, len(devs) * len(t.nodes) // max(total_nodes, 1))
        share = (share // model_parallel) * model_parallel or model_parallel
        sel = np.array(devs[:share]).reshape(share // model_parallel, model_parallel)
        from jax.sharding import Mesh

        return Mesh(sel, ("data", "model"))

    # ------------------------------------------------------------------
    def check_isolation(self) -> list[str]:
        """Invariant: no node is owned by two tenants / no job crosses
        tenant boundaries. Returns violations (tests assert empty)."""
        owner: dict[int, str] = {}
        bad = []
        for t in self.tenants.values():
            for nid in t.nodes:
                if nid in owner:
                    bad.append(f"node {nid} in tenants {owner[nid]} and {t.name}")
                owner[nid] = t.name
        for n in self.cluster.nodes.values():
            if n.job is not None and n.tenant is not None:
                jt = [x for x in self.cluster.job_nodes(n.job) if x.tenant != n.tenant]
                bad.extend(f"job {n.job} crosses tenants via node {x.node_id}" for x in jt)
        return bad
