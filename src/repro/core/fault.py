"""Fault-tolerant training runner: heartbeats -> detection -> restore -> resume.

This is the executable version of the paper's "Flex Start with guaranteed
completion": a REAL training loop (CPU-executed on reduced configs in tests)
wrapped with the failure machinery a 1,320-node system needs:

* per-node heartbeats into ``core.cluster``; missed beats -> suspect -> failed
* hard failure injection (chaos schedule) at arbitrary steps
* on failure: roll back to the newest checkpoint, replay deterministically
  (the data pipeline is step-keyed, so recovery is *bit-exact* — asserted in
  tests/test_fault_tolerance.py)
* optional elastic recovery: shrink to the surviving nodes at a checkpoint
  boundary instead of waiting for a replacement (core.elastic)
* straggler observations feed ``core.straggler`` and can drain slow nodes.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.core.cluster import Cluster, NodeState
from repro.core.straggler import StragglerDetector
from repro.core.telemetry import EnergyLedger


@dataclass
class RunReport:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    rollback_steps: int = 0  # work re-executed after rollbacks
    losses: dict = field(default_factory=dict)  # step -> loss
    events: list = field(default_factory=list)


class FaultTolerantRunner:
    def __init__(
        self,
        *,
        step_fn: Callable,  # (state, batch) -> (state, metrics)
        init_state,
        batch_fn: Callable,  # step -> batch (deterministic => bit-exact replay)
        cluster: Cluster,
        ckpt: CheckpointManager,
        job_id: str = "train-job",
        checkpoint_every: int = 10,
        heartbeat_timeout: tuple[float, float] = (2.0, 4.0),  # (suspect, fail)
        ledger: Optional[EnergyLedger] = None,
        straggler: Optional[StragglerDetector] = None,
    ):
        self.step_fn = step_fn
        self.state = init_state
        self.batch_fn = batch_fn
        self.cluster = cluster
        self.ckpt = ckpt
        self.job_id = job_id
        self.checkpoint_every = checkpoint_every
        self.suspect_after, self.fail_after = heartbeat_timeout
        self.ledger = ledger or EnergyLedger()
        self.straggler = straggler or StragglerDetector()
        self.report = RunReport()
        self._step = 0

    # ------------------------------------------------------------------
    def _heartbeat_all(self, now: float, dead: set[int]) -> None:
        for n in self.cluster.job_nodes(self.job_id):
            if n.node_id not in dead and n.state == NodeState.HEALTHY:
                self.cluster.heartbeat(n.node_id, now)

    def _detect_failures(self, now: float) -> list[int]:
        failed = self.cluster.sweep_heartbeats(
            now, suspect_after=self.suspect_after, fail_after=self.fail_after
        )
        return [n.node_id for n in failed if n.job == self.job_id]

    # ------------------------------------------------------------------
    def _restore(self) -> None:
        """Roll back to the newest checkpoint (or step 0 state)."""
        step = self.ckpt.latest_step()
        if step is None:
            raise RuntimeError("no checkpoint to restore from")
        self.state, extra = self.ckpt.restore(self.state, step=step)
        self.report.rollback_steps += self._step - step
        self._step = step
        self.report.restores += 1
        self.report.events.append(("restore", step))

    def _maybe_checkpoint(self) -> None:
        if self._step % self.checkpoint_every == 0 and self._step > 0:
            self.ckpt.save(self.state, step=self._step, block=True)
            self.report.events.append(("checkpoint", self._step))

    # ------------------------------------------------------------------
    def run(
        self,
        num_steps: int,
        *,
        failure_schedule: dict[int, int] | None = None,  # step -> node_id to kill
        repair_after_steps: int = 2,
        now_fn: Callable[[], float] | None = None,
    ) -> RunReport:
        """Run to ``num_steps`` TOTAL steps, surviving the failure schedule."""
        failure_schedule = dict(failure_schedule or {})
        sim_now = [0.0]

        def now() -> float:
            sim_now[0] += 1.0
            return sim_now[0]

        now_fn = now_fn or now
        dead: dict[int, int] = {}  # node -> steps until repair
        # capture the job's node set up front: a co-attached Scheduler may
        # release node->job bindings on failure events, but the runner owns
        # the training loop and re-attaches the same nodes after repair
        my_nodes = [n.node_id for n in self.cluster.job_nodes(self.job_id)]

        # initial checkpoint so any early failure has a restore point
        self.ckpt.save(self.state, step=0, block=True)

        while self._step < num_steps:
            t = now_fn()
            # chaos injection scheduled for this step
            if self._step in failure_schedule:
                nid = failure_schedule.pop(self._step)
                self.cluster.fail_node(nid)
                dead[nid] = repair_after_steps
                self.report.failures += 1
                self.report.events.append(("failure", self._step, nid))

            for nid in my_nodes:
                if nid not in dead and self.cluster.nodes[nid].state == NodeState.HEALTHY:
                    self.cluster.heartbeat(nid, t)
            lost = self._detect_failures(t)
            failed_now = [
                nid for nid in my_nodes if self.cluster.nodes[nid].state == NodeState.FAILED
            ]
            if lost or failed_now or dead:
                # wait for repair (simulated), then restore and resume
                for nid in list(dead):
                    dead[nid] -= 1
                    if dead[nid] <= 0:
                        self.cluster.repair_node(nid, t)
                        del dead[nid]
                if dead:
                    continue  # still waiting for spare capacity
                # re-attach the full node set to the job and resume
                for nid in my_nodes:
                    self.cluster.nodes[nid].job = self.job_id
                self._restore()
                continue

            t0 = time.monotonic()
            batch = self.batch_fn(self._step)
            self.state, metrics = self.step_fn(self.state, batch)
            loss = metrics["loss"]
            wall = time.monotonic() - t0
            self._step += 1
            self.report.steps_run += 1
            self.report.losses[self._step] = float(loss)
            for n in self.cluster.job_nodes(self.job_id):
                self.straggler.observe(n.node_id, wall)
            self.ledger.record(
                self.job_id,
                chips=sum(n.chips for n in self.cluster.job_nodes(self.job_id)),
                seconds=wall,
                utilization=0.5,
            )
            self._maybe_checkpoint()

        self.ckpt.save(self.state, step=self._step, block=True)
        return self.report
