"""DCIM-style sustainability telemetry: energy, PUE, scope-2 emissions.

Paper §IV.A: the MDC integrates a DCIM that correlates facility data (power,
cooling) with IT-side provisioning; the facility targets PUE < 1.1 with
free-air cooling >95% of operations, ~90% of lifecycle emissions scope-2, a
5 MW envelope.  This module reproduces that accounting for the TPU adaptation:
per-job energy integrates chip-seconds x power drawn from the roofline
utilization, facility overhead applies the PUE model, and the report mirrors
the paper's sustainability tables.
"""

from __future__ import annotations

from dataclasses import dataclass, field

# TPU v5e adaptation constants (DESIGN.md §2)
CHIP_PEAK_W = 250.0  # per-chip board power envelope
CHIP_IDLE_W = 75.0
HOST_OVERHEAD_W = 350.0  # CPU host, NICs, fans per 4-chip node
PUE_FREE_COOLING = 1.08  # paper: < 1.1 in free-cooling operation
PUE_CHILLER = 1.25  # the ~2% of hours chillers engage
FREE_COOLING_FRACTION = 0.98  # paper §IV.D: chillers unneeded ~98% of ops
GRID_KGCO2_PER_KWH = 0.207  # UK grid intensity (2023 avg), scope 2


def effective_pue() -> float:
    return FREE_COOLING_FRACTION * PUE_FREE_COOLING + (1 - FREE_COOLING_FRACTION) * PUE_CHILLER


def chip_power(utilization: float) -> float:
    """Linear activity model between idle and peak board power."""
    u = min(max(utilization, 0.0), 1.0)
    return CHIP_IDLE_W + u * (CHIP_PEAK_W - CHIP_IDLE_W)


@dataclass
class EnergyLedger:
    """Accumulates per-job and facility energy like a DCIM historian."""

    job_joules: dict[str, float] = field(default_factory=dict)
    job_chipseconds: dict[str, float] = field(default_factory=dict)
    facility_joules: float = 0.0

    def record(self, job_id: str, *, chips: int, seconds: float, utilization: float) -> float:
        """Integrate one interval; returns IT-side joules charged to the job."""
        nodes = -(-chips // 4)
        it_watts = chips * chip_power(utilization) + nodes * HOST_OVERHEAD_W
        joules = it_watts * seconds
        self.job_joules[job_id] = self.job_joules.get(job_id, 0.0) + joules
        self.job_chipseconds[job_id] = self.job_chipseconds.get(job_id, 0.0) + chips * seconds
        self.facility_joules += joules * effective_pue()
        return joules

    # ------------------------------------------------------------------
    def job_kwh(self, job_id: str) -> float:
        return self.job_joules.get(job_id, 0.0) / 3.6e6

    def facility_kwh(self) -> float:
        return self.facility_joules / 3.6e6

    def scope2_kgco2(self) -> float:
        return self.facility_kwh() * GRID_KGCO2_PER_KWH

    def report(self) -> dict:
        it_kwh = sum(self.job_joules.values()) / 3.6e6
        fac = self.facility_kwh()
        return {
            "it_kwh": round(it_kwh, 3),
            "facility_kwh": round(fac, 3),
            "effective_pue": round(effective_pue(), 4),
            "scope2_kgco2": round(self.scope2_kgco2(), 3),
            "jobs": {k: round(v / 3.6e6, 4) for k, v in self.job_joules.items()},
        }


def train_step_utilization(roofline_terms: dict) -> float:
    """Map roofline terms to a utilization proxy: compute share of the
    bottleneck time (what fraction of the step the MXU is busy)."""
    bound = max(roofline_terms.values())
    return 0.0 if bound <= 0 else roofline_terms["compute_s"] / bound


def mw_check(chips: int, utilization: float = 1.0) -> float:
    """Facility MW at the given utilization (paper: 5 MW envelope)."""
    nodes = -(-chips // 4)
    watts = (chips * chip_power(utilization) + nodes * HOST_OVERHEAD_W) * effective_pue()
    return watts / 1e6
