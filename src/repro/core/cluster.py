"""Cluster model: nodes, superchips, topology, health — the facility layer.

Mirrors the paper's Table I: a node is 4 superchips (4x GH200 on Isambard-AI;
adapted here to 4 TPU v5e chips per host, DESIGN.md §2), nodes aggregate into
pods, pods into the facility.  Phase 1 = 42 nodes / 168 chips; phase 2 =
1,320 nodes / 5,280 chips — both are presets below, and the runtime simulates
thousands of nodes without allocating anything per-chip.

The cluster is the substrate the scheduler (QoS classes), tenancy (TAPMS) and
fault-tolerance layers operate on.  Health transitions are event-driven so
tests can inject blade failures exactly like the serviceability story in
paper §IV.D (quick-connect blades, service without full-system shutdown).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Iterable, Optional


class NodeState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"  # missed heartbeats, not yet evicted
    FAILED = "failed"
    DRAINING = "draining"  # administratively removed (blade service)
    REPAIRING = "repairing"


CHIPS_PER_NODE = 4  # 4 superchips per node (paper Fig. 4)


@dataclass
class Node:
    node_id: int
    pod: int
    state: NodeState = NodeState.HEALTHY
    # facility telemetry (DCIM): watts drawn, last heartbeat timestamp
    power_w: float = 0.0
    last_heartbeat: float = 0.0
    tenant: Optional[str] = None
    job: Optional[str] = None

    @property
    def chips(self) -> int:
        return CHIPS_PER_NODE


@dataclass
class ClusterSpec:
    name: str
    nodes_per_pod: int
    num_pods: int

    @property
    def total_nodes(self) -> int:
        return self.nodes_per_pod * self.num_pods

    @property
    def total_chips(self) -> int:
        return self.total_nodes * CHIPS_PER_NODE


# presets mirroring the paper + the assignment's dry-run mesh
PHASE1 = ClusterSpec("isambard-ai-phase1", nodes_per_pod=42, num_pods=1)  # 168 chips
PHASE2 = ClusterSpec("isambard-ai-phase2", nodes_per_pod=110, num_pods=12)  # 5,280 chips
DRYRUN_SINGLE = ClusterSpec("dryrun-single-pod", nodes_per_pod=64, num_pods=1)  # 256 chips
DRYRUN_MULTI = ClusterSpec("dryrun-multi-pod", nodes_per_pod=64, num_pods=2)  # 512 chips


class Cluster:
    """In-memory facility state. Time is injected (simulation-friendly)."""

    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        self.nodes: dict[int, Node] = {}
        nid = 0
        for pod in range(spec.num_pods):
            for _ in range(spec.nodes_per_pod):
                self.nodes[nid] = Node(node_id=nid, pod=pod)
                nid += 1
        self._listeners = []

    # ------------------------------------------------------------------
    def on_event(self, fn) -> None:
        """fn(event: str, node: Node) — scheduler/FT layers subscribe."""
        self._listeners.append(fn)

    def _emit(self, event: str, node: Node) -> None:
        for fn in self._listeners:
            fn(event, node)

    # ------------------------------------------------------------------
    def healthy_nodes(self, pod: int | None = None) -> list[Node]:
        return [
            n
            for n in self.nodes.values()
            if n.state == NodeState.HEALTHY and (pod is None or n.pod == pod)
        ]

    def free_nodes(self, pod: int | None = None) -> list[Node]:
        return [n for n in self.healthy_nodes(pod) if n.job is None]

    def free_chips(self, pod: int | None = None) -> int:
        return sum(n.chips for n in self.free_nodes(pod))

    # ------------------------------------------------------------------
    def heartbeat(self, node_id: int, now: float) -> None:
        n = self.nodes[node_id]
        n.last_heartbeat = now
        if n.state == NodeState.SUSPECT:
            n.state = NodeState.HEALTHY
            self._emit("recovered", n)

    def sweep_heartbeats(self, now: float, *, suspect_after: float, fail_after: float) -> list[Node]:
        """Mark nodes suspect/failed by heartbeat age. Returns newly failed."""
        failed = []
        for n in self.nodes.values():
            if n.state not in (NodeState.HEALTHY, NodeState.SUSPECT):
                continue
            age = now - n.last_heartbeat
            if age >= fail_after:
                n.state = NodeState.FAILED
                failed.append(n)
                self._emit("failed", n)
            elif age >= suspect_after and n.state == NodeState.HEALTHY:
                n.state = NodeState.SUSPECT
                self._emit("suspect", n)
        return failed

    def fail_node(self, node_id: int) -> Node:
        """Hard failure injection (tests / chaos engineering)."""
        n = self.nodes[node_id]
        n.state = NodeState.FAILED
        self._emit("failed", n)
        return n

    def repair_node(self, node_id: int, now: float = 0.0) -> Node:
        n = self.nodes[node_id]
        n.state = NodeState.HEALTHY
        n.last_heartbeat = now
        n.job = None
        self._emit("repaired", n)
        return n

    def drain_node(self, node_id: int) -> Node:
        n = self.nodes[node_id]
        n.state = NodeState.DRAINING
        self._emit("draining", n)
        return n

    # ------------------------------------------------------------------
    def allocate(self, node_ids: Iterable[int], job: str, tenant: str | None = None) -> None:
        for nid in node_ids:
            n = self.nodes[nid]
            if n.state != NodeState.HEALTHY or n.job is not None:
                raise RuntimeError(f"node {nid} not allocatable (state={n.state}, job={n.job})")
            n.job = job
            if tenant is not None:
                n.tenant = tenant

    def release(self, job: str) -> list[int]:
        freed = []
        for n in self.nodes.values():
            if n.job == job:
                n.job = None
                freed.append(n.node_id)
        return freed

    def job_nodes(self, job: str) -> list[Node]:
        return [n for n in self.nodes.values() if n.job == job]
