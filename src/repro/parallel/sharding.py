"""Logical-axis sharding rule engine (MaxText-style, but dependency-free).

Every parameter / activation / cache tensor carries a tuple of *logical* axis
names (assigned in the model code).  This module maps logical axes onto mesh
axes with a **greedy, divisibility-checked** assignment:

* each logical axis has an ordered candidate list of mesh axes (or axis
  tuples, e.g. the combined FSDP axes ``("pod", "data")``);
* per tensor, candidates are claimed first-come-first-served so no mesh axis
  is used twice on one tensor;
* a candidate is skipped when the dim size is not divisible by the mesh-axis
  size — this is what makes one rule table serve all ten architectures
  (arctic's 56 heads or hymba's 25 heads simply fall back to replicated while
  their FFN/expert dims still shard).

Rule tables differ between *parameters* (FSDP over the data axes + TP/EP over
"model") and *activations* (batch over data axes, heads/mlp/experts over
"model", optional sequence parallelism).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, ParallelConfig

Candidate = tuple[str, ...]  # one candidate = tuple of mesh axes used together


def _axis_size(mesh: Mesh, cand: Candidate) -> int:
    n = 1
    for a in cand:
        n *= mesh.shape[a]
    return n


@dataclass(frozen=True)
class ShardingRules:
    mesh_cfg: MeshConfig
    parallel: ParallelConfig

    # ------------------------------------------------------------------
    def _data_axes(self) -> Candidate:
        return tuple(self.mesh_cfg.data_axes)

    def param_rules(self) -> dict[str, tuple[Candidate, ...]]:
        fsdp: tuple[Candidate, ...] = ((self._data_axes(),) if self.parallel.fsdp else ())
        tp: tuple[Candidate, ...] = ((("model",),) if self.parallel.tensor_parallel else ())
        # dict order = priority: the expert dim (EP) claims "model" first
        # (experts-over-data was tried and REFUTED: the dense GShard dispatch
        # einsum then reduces a dense (E,C,D) tensor over the data axis —
        # qwen3 train collective term went 109 s -> 209 s; see §Perf), then
        # attention heads / FFN hidden (TP); "embed" takes the FSDP axes.
        return {
            "expert": tp,
            "vocab": tp,  # vocab tables: model-axis only (see initializers)
            "heads": tp,
            "kv_heads": tp,
            "mlp": tp,
            # expert FFN hidden: "model" is usually taken by the expert dim.
            # In DECODE (fsdp off) fall back to the data axes so expert
            # weights are fully sharded AND stationary; in training the same
            # fallback was REFUTED (dense-dispatch grads reduce over data:
            # qwen3 train collective 109 s -> 223 s, §Perf).
            "expert_mlp": tp + (() if self.parallel.fsdp else (self._data_axes(),)),
            "heads_x_dim": tp,
            "ssm_inner": tp,
            "embed": fsdp + tp,  # FSDP primary; TP fallback (odd vocab sizes)
            "embed_v": (),  # embed dim of vocab tables: never sharded
            "expert_router": tp,
            # head_dim: TP fallback for indivisible head counts (arctic's 56
            # heads, hymba's 25) — contraction over head_dim psums cheaply.
            "head_dim": tp,
            "layers": (),
            "layers_inner": (),
        }

    def act_rules(self) -> dict[str, tuple[Candidate, ...]]:
        batch: tuple[Candidate, ...] = (self._data_axes(),)
        tp: tuple[Candidate, ...] = ((("model",),) if self.parallel.tensor_parallel else ())
        seq: tuple[Candidate, ...] = ((("model",),) if self.parallel.sequence_parallel else ())
        # dict order = priority: TP-style dims (heads/mlp/experts/vocab) claim
        # the model axis before the sequence-parallel fallback, so attention
        # internals shard heads while the residual stream shards seq.
        return {
            "batch": batch,
            "kv_batch": batch,
            "heads": tp,
            "kv_heads": tp,
            "mlp": tp,
            "expert_mlp": tp,
            "expert": tp,
            "vocab": tp,
            "ssm_inner": tp,
            "heads_x_dim": tp,
            "seq": seq,
            "kv_seq": (),  # claimed via fallback in cache specs (see below)
            "embed": (),
            "layers": (),
            "layers_inner": (),
        }

    def cache_rules(self) -> dict[str, tuple[Candidate, ...]]:
        """KV-cache specific: prefer head sharding, fall back to sequence
        (flash-decoding style split-KV) when head count doesn't divide.
        Priority is the dict order: kv_seq is appended LAST so kv_heads
        claims the model axis first."""
        rules = dict(self.act_rules())
        rules.pop("kv_seq", None)
        rules["kv_seq"] = (("model",),)
        return rules

    # ------------------------------------------------------------------
    def spec_for(
        self, axes: tuple[str | None, ...], dims: tuple[int, ...], mesh: Mesh, rules: dict
    ) -> P:
        """Greedy one-tensor assignment honoring divisibility.

        Priority = position of the logical axis in the rule table (dict
        order), so e.g. "kv_heads" (preferred) claims "model" before the
        "kv_seq" flash-decoding fallback.
        """
        used: set[str] = set()
        assign: list[tuple[str, ...] | None] = [None] * len(axes)
        rule_order = {name: i for i, name in enumerate(rules)}
        order = sorted(
            range(len(axes)),
            key=lambda i: (
                len(rules.get(axes[i], ())) == 0,
                rule_order.get(axes[i], len(rule_order)),
            ),
        )
        # simple two-round greedy: round 1 tries first candidates, round 2 rest
        for i in order:
            ax = axes[i]
            if ax is None:
                continue
            for cand in rules.get(ax, ()):
                if any(a in used for a in cand):
                    continue
                if dims[i] % _axis_size(mesh, cand) != 0:
                    continue
                assign[i] = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        return P(*assign)

    # ------------------------------------------------------------------
    def tree_specs(self, axes_tree, shape_tree, mesh: Mesh, rules: dict):
        """PartitionSpec tree for (logical-axes tree, shape-carrying tree)."""

        def one(axes, leaf):
            dims = tuple(leaf.shape)
            assert len(axes) == len(dims), f"axes {axes} vs shape {dims}"
            return self.spec_for(axes, dims, mesh, rules)

        is_axes = lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
        return jax.tree.map(one, axes_tree, shape_tree, is_leaf=is_axes)

    def param_shardings(self, model_cfg, mesh: Mesh, abstract):
        from repro.models import param_logical_axes

        axes = param_logical_axes(model_cfg)
        specs = self.tree_specs(axes, abstract, mesh, self.param_rules())
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P))

    def cache_shardings(self, model_cfg, mesh: Mesh, abstract_cache_tree):
        from repro.models import stacked_cache_axes

        axes = stacked_cache_axes(model_cfg)
        specs = self.tree_specs(axes, abstract_cache_tree, mesh, self.cache_rules())
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P))

    def paged_cache_shardings(self, model_cfg, mesh: Mesh, abstract_cache_tree):
        """NamedShardings for the serving engine's paged block-pool cache.

        Pools partition along the kv-head ("model") axis — every device holds
        its head slice of EVERY physical block — while block tables and the
        hybrid recurrent states replicate, so the host-side allocator /
        prefix index see the same block ids regardless of mesh size.  When
        the head count doesn't divide the model axis the divisibility check
        in ``spec_for`` falls the pool back to replicated."""
        from repro.models import paged_cache_axes

        quantized = "k_scale" in abstract_cache_tree
        axes = paged_cache_axes(model_cfg, quantized=quantized)
        specs = self.tree_specs(axes, abstract_cache_tree, mesh, self.cache_rules())
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P))

    def logits_sharding(self, model_cfg, mesh: Mesh, ndim: int = 2) -> NamedSharding:
        """Vocab-sharded logits spec (batch and any inner dims replicated);
        replicated when the padded vocab doesn't divide the model axis."""
        spec = self.spec_for(
            (None,) * (ndim - 1) + ("vocab",),
            (1,) * (ndim - 1) + (model_cfg.padded_vocab,),
            mesh,
            self.act_rules(),
        )
        return NamedSharding(mesh, spec)

    def batch_sharding(self, mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, P(self._data_axes()))

    # ------------------------------------------------------------------
    def make_sharder(self, mesh: Mesh):
        """``sh(x, logical_axes)`` -> with_sharding_constraint inside jit."""
        rules = self.act_rules()

        def sh(x, axes):
            spec = self.spec_for(tuple(axes), tuple(x.shape), mesh, rules)
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

        return sh


def make_rules(mesh_cfg: MeshConfig, parallel: ParallelConfig | None = None) -> ShardingRules:
    return ShardingRules(mesh_cfg, parallel or ParallelConfig())
