from repro.parallel.sharding import ShardingRules, make_rules
from repro.parallel.collectives import (
    CollectiveModel,
    compress_gradients,
    compression_ratio,
    init_compression_state,
)

__all__ = [
    "ShardingRules",
    "make_rules",
    "CollectiveModel",
    "compress_gradients",
    "compression_ratio",
    "init_compression_state",
]
