"""Distributed-optimization helpers: gradient compression & collective models.

Two roles:

1. ``compress_gradients`` — gradient compression with error feedback
   (1-bit-Adam-style int8, or bf16 truncation).  With FSDP the intra-pod
   reduce-scatter happens inside XLA's backward; the *cross-pod* (DCN) hop is
   the thin pipe the paper's phase-2 system worries about, so the compressor
   targets the bytes that cross it.  Quantization happens before the optimizer
   and an error-feedback residual keeps the scheme convergent.

2. ``CollectiveModel`` — the analytic cost model the roofline/report uses for
   ring all-reduce / all-gather / reduce-scatter / all-to-all byte counts on a
   torus, matching the assignment's ``collective_bytes / (chips x link_bw)``
   convention.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# gradient compression (with error feedback)
# ---------------------------------------------------------------------------


def _quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_gradients(grads, residual, method: str = "none"):
    """Returns (compressed-then-decompressed grads, new residual).

    ``residual`` is the error-feedback state (same tree as grads, fp32).
    """
    if method == "none":
        return grads, residual

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if method == "bf16":
            gq = gf.astype(jnp.bfloat16).astype(jnp.float32)
        elif method == "int8":
            q, scale = _quantize_int8(gf)
            gq = q.astype(jnp.float32) * scale
        else:
            raise ValueError(f"unknown grad_compression {method!r}")
        return gq.astype(g.dtype), gf - gq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_r = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_g, new_r


def init_compression_state(grads_like, method: str = "none"):
    if method == "none":
        return None
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compression_ratio(method: str) -> float:
    """Bytes-on-the-wire ratio vs fp32 (used by the DCN cost model)."""
    return {"none": 1.0, "bf16": 0.5, "int8": 0.25}[method]


# ---------------------------------------------------------------------------
# analytic collective cost model (ring algorithms on a torus)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CollectiveModel:
    """Per-chip wire-byte estimates for ring collectives over n participants."""

    link_bw: float = 50e9  # bytes/s per ICI link (assignment constant)

    def all_reduce(self, bytes_per_chip: float, n: int) -> float:
        # ring: 2(n-1)/n of the buffer crosses each chip's link
        return 2.0 * (n - 1) / max(n, 1) * bytes_per_chip

    def all_gather(self, result_bytes: float, n: int) -> float:
        return (n - 1) / max(n, 1) * result_bytes

    def reduce_scatter(self, input_bytes: float, n: int) -> float:
        return (n - 1) / max(n, 1) * input_bytes

    def all_to_all(self, bytes_per_chip: float, n: int) -> float:
        return (n - 1) / max(n, 1) * bytes_per_chip

    def time(self, wire_bytes: float) -> float:
        return wire_bytes / self.link_bw
