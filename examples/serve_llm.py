"""Serving example: continuous batching with online/offline QoS.

    PYTHONPATH=src python examples/serve_llm.py

Submits a mixed stream of online (latency-sensitive) and offline (backfill)
requests against a reduced model and prints per-request TTFT + engine stats —
the inference usage pattern of paper §IV.F.
"""

import jax
import jax.numpy as jnp

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InferenceEngine


def main() -> None:
    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = InferenceEngine(cfg, params, max_batch=4, max_seq=256)

    reqs = []
    for i in range(6):
        reqs.append(eng.submit([10 + i, 20, 30], max_new_tokens=12, online=True))
    for i in range(6):
        reqs.append(eng.submit([100 + i, 7], max_new_tokens=24, online=False, temperature=0.8))

    eng.run_until_drained()
    for r in reqs:
        kind = "online " if r.online else "offline"
        ttft = f"{r.ttft*1e3:7.1f}ms" if r.ttft is not None else "  never admitted"
        print(f"req {r.req_id:2d} [{kind}] ttft={ttft}  tokens={r.generated[:8]}...")
    print("engine stats:", eng.stats())


if __name__ == "__main__":
    main()
