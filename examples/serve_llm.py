"""Serving example: continuous batching with online/offline QoS.

    PYTHONPATH=src python examples/serve_llm.py
    PYTHONPATH=src python examples/serve_llm.py --spec-decode ngram --spec-k 4

Submits a mixed stream of online (latency-sensitive) and offline (backfill)
requests against a reduced model and prints per-request TTFT + engine stats —
the inference usage pattern of paper §IV.F.  ``--spec-decode`` turns on
speculative decoding (the CI docs job runs this as its smoke test); the
offline requests carry a repetitive suffix so the n-gram drafter has
something to look up.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InferenceEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec-decode", default="off", choices=("off", "ngram", "draft"))
    ap.add_argument("--spec-k", type=int, default=4)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = InferenceEngine(
        cfg, params, max_batch=4, max_seq=256,
        spec_decode=args.spec_decode, spec_k=args.spec_k,
    )

    reqs = []
    for i in range(6):
        reqs.append(eng.submit([10 + i, 20, 30], max_new_tokens=12, online=True))
    for i in range(6):
        prompt = [100 + i, 7] + [31, 41, 59] * 4  # repetitive suffix
        reqs.append(eng.submit(prompt, max_new_tokens=24, online=False, temperature=0.8))

    eng.run_until_drained()
    for r in reqs:
        kind = "online " if r.online else "offline"
        ttft = f"{r.ttft*1e3:7.1f}ms" if r.ttft is not None else "  never admitted"
        print(f"req {r.req_id:2d} [{kind}] ttft={ttft}  tokens={r.generated[:8]}...")
    stats = eng.stats()

    # end-of-run summary from the metrics registry: latency percentiles per
    # phase plus the DCIM-style energy attribution (docs/observability.md)
    print()
    print(f"{'latency':<24} {'p50':>10} {'p90':>10} {'p99':>10}")
    for label, name in (
        ("queue wait", "engine_queue_wait_seconds"),
        ("ttft", "engine_ttft_seconds"),
        ("tpot", "engine_tpot_seconds"),
        ("engine step", "engine_step_seconds"),
        ("prefill chunk", "engine_prefill_chunk_seconds"),
    ):
        p = eng.metrics.percentiles(name)
        cells = "".join(
            f" {v*1e3:9.2f}ms" if v is not None else f" {'-':>11}" for v in p.values()
        )
        print(f"{label:<24}{cells}")
    print(
        f"{'throughput':<24} {stats['tokens_out']} tokens, "
        f"{stats['decode_steps']} decode steps"
    )
    if "joules_per_token" in stats:
        print(
            f"{'energy':<24} {stats['energy_joules']:.1f} J IT-side, "
            f"{stats['joules_per_token']:.2f} J/token"
        )
    assert all(len(r.generated) > 0 for r in reqs), "a request produced no tokens"
    if args.spec_decode != "off":
        print(
            f"[spec] mode={stats['spec_decode']} accepted_per_step="
            f"{stats['accepted_per_step']:.2f} acceptance_rate={stats['acceptance_rate']:.2f}"
        )


if __name__ == "__main__":
    main()
