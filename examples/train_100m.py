"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py --steps 200

Exercises the full production path on CPU: config -> sharded init (1-device
mesh) -> train loop with microbatching + remat -> async tiered checkpointing
-> periodic eval -> DCIM energy accounting.  The same driver runs unchanged
on a pod (the mesh and shardings scale via repro.launch.mesh).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.config import ModelConfig, ParallelConfig, RunConfig, TrainConfig
from repro.core import EnergyLedger
from repro.data import make_batch_fn
from repro.train.step import init_train_state, make_train_step


def model_100m() -> ModelConfig:
    """A ~100M-param LLaMA-style config (not reduced — the real thing)."""
    return ModelConfig(
        name="llama-100m",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        d_ff=2048,
        vocab_size=32000,
        norm="rmsnorm",
        activation="swiglu",
        tie_embeddings=True,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = model_100m()
    print(f"params: {cfg.param_count()/1e6:.1f}M")
    run = RunConfig(
        arch=cfg.name,
        train=TrainConfig(global_batch=args.batch, seq_len=args.seq, warmup_steps=20, total_steps=args.steps),
        parallel=ParallelConfig(num_microbatches=2, remat="full"),
    )
    state = init_train_state(cfg, run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, run))
    batch_fn = make_batch_fn(cfg, global_batch=args.batch, seq_len=args.seq)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2, qos="training", async_save=True)
    ledger = EnergyLedger()

    # resume if a checkpoint exists (flex-start semantics)
    start = ckpt.latest_step() or 0
    if start:
        state, _ = ckpt.restore(state, step=start)
        print(f"resumed from step {start}")

    tokens_per_step = args.batch * args.seq
    t_run = time.time()
    for s in range(start, args.steps):
        t0 = time.time()
        state, metrics = step(state, batch_fn(s))
        dt = time.time() - t0
        ledger.record("train-100m", chips=1, seconds=dt, utilization=0.6)
        if (s + 1) % 10 == 0:
            tps = tokens_per_step / dt
            print(
                f"step {s+1:4d}  loss {float(metrics['loss']):.4f}  "
                f"grad_norm {float(metrics['grad_norm']):.2f}  tok/s {tps:,.0f}"
            )
        if (s + 1) % args.ckpt_every == 0:
            ckpt.save(state, step=s + 1)
    ckpt.save(state, step=args.steps, block=True)
    ckpt.close()
    print(f"done in {time.time()-t_run:.0f}s; energy report: {ledger.report()}")


if __name__ == "__main__":
    main()
