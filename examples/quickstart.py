"""Quickstart: build a model, train a few steps, decode a few tokens.

    PYTHONPATH=src python examples/quickstart.py [--arch olmo-1b]

Uses the reduced config of any assigned architecture so it runs on a laptop
CPU in under a minute.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.config import RunConfig, TrainConfig
from repro.config.model import reduce_for_smoke
from repro.configs import ASSIGNED, get_config
from repro.data import make_batch_fn
from repro.models import init_params
from repro.serving import InferenceEngine
from repro.train.step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ASSIGNED + ["bert-large"])
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    print(f"arch={cfg.name} family={cfg.family} reduced params={cfg.param_count()/1e6:.2f}M")

    run = RunConfig(arch=args.arch, train=TrainConfig(global_batch=8, seq_len=64))
    state = init_train_state(cfg, run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, run))
    batch_fn = make_batch_fn(cfg, global_batch=8, seq_len=64)

    for s in range(args.steps):
        state, metrics = step(state, batch_fn(s))
        print(f"step {s:3d}  loss {float(metrics['loss']):.4f}  lr {float(metrics['lr']):.2e}")

    if not cfg.is_encoder_only:
        eng = InferenceEngine(cfg, state.params, max_batch=2, max_seq=128)
        req = eng.submit([1, 2, 3, 4], max_new_tokens=8)
        eng.run_until_drained()
        print(f"generated: {req.generated}")


if __name__ == "__main__":
    main()
