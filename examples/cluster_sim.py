"""Facility simulation: phase-2-scale scheduling, failures, sustainability.

    PYTHONPATH=src python examples/cluster_sim.py

Simulates a week of the 1,320-node phase-2 system under a realistic mixed
workload (the paper's four QoS classes), with random node failures at a
50k-hour node MTBF, calendar reservations, and DCIM energy accounting.
No model math runs — this exercises the platform layer at full scale.
"""

import random

from repro.core import (
    CHIPS_PER_NODE,
    Cluster,
    EnergyLedger,
    Job,
    JobState,
    PHASE2,
    QoS,
    Reservation,
    Scheduler,
    mw_check,
)


def main() -> None:
    rng = random.Random(0)
    cluster = Cluster(PHASE2)  # 1,320 nodes / 5,280 chips
    sched = Scheduler(cluster)
    ledger = EnergyLedger()

    # workload: 2 frontier training runs, a stream of fine-tunes/experiments,
    # a standing inference fleet, one calendar reservation
    sched.submit(Job("frontier-a", "lab-a", QoS.TRAINING, chips=2048, duration=72 * 3600, checkpoint_interval=1800))
    sched.submit(Job("frontier-b", "lab-b", QoS.TRAINING, chips=1024, duration=48 * 3600, checkpoint_interval=1800))
    sched.submit(Job("serve-fleet", "platform", QoS.INFERENCE, chips=512, duration=7 * 24 * 3600))
    sched.reserve(Reservation("ai-safety-eval", "aisi", chips=1024, start=24 * 3600, end=36 * 3600))

    horizon = 7 * 24 * 3600
    tick = 600.0  # 10-minute scheduler ticks
    t = 0.0
    failures = 0
    next_exp = 0
    while t < horizon:
        t += tick
        # random small jobs arriving (experimentation / fine-tuning)
        if rng.random() < 0.3:
            qos = rng.choice([QoS.EXPERIMENTATION, QoS.FINE_TUNING])
            chips = rng.choice([4, 8, 32, 128])
            sched.submit(Job(f"small-{next_exp}", "users", qos, chips=chips, duration=rng.uniform(600, 7200)))
            next_exp += 1
        # node failures: 50k-hour MTBF x 1,320 nodes ~ one failure / 38 h
        p_fail = tick / (50_000 * 3600) * len(cluster.nodes)
        if rng.random() < p_fail:
            victim = rng.choice(list(cluster.nodes))
            cluster.fail_node(victim)
            failures += 1
        # repairs: 4-hour turnaround
        for n in cluster.nodes.values():
            if n.state.value == "failed" and rng.random() < tick / (4 * 3600):
                cluster.repair_node(n.node_id, t)
        sched.tick(t)
        for job in sched.running.values():
            ledger.record(job.job_id, chips=len(job.nodes) * CHIPS_PER_NODE, seconds=tick, utilization=0.55)

    done = [j for j in sched.done.values() if j.state == JobState.COMPLETED]
    print(f"week simulated: {len(done)} jobs completed, {failures} node failures")
    print(f"final utilization: {sched.utilization():.1%}")
    restarted = [j for j in list(sched.done.values()) + list(sched.running.values()) if j.restarts]
    print(f"jobs that survived failures via flex-restart: {[j.job_id for j in restarted]}")
    rep = ledger.report()
    print(f"energy: {rep['facility_kwh']:,.0f} kWh facility (PUE {rep['effective_pue']}), "
          f"scope2 {rep['scope2_kgco2']:,.0f} kgCO2")
    print(f"peak facility power at full load: {mw_check(PHASE2.total_chips):.2f} MW (envelope: 5 MW)")


if __name__ == "__main__":
    main()
