#!/usr/bin/env python
"""Docs link checker: every relative link and anchor in the markdown tree
must resolve.

    python scripts/check_docs.py

Checks, stdlib-only (runs in CI's docs job before any pip install):

* inline markdown links ``[text](target)`` in README.md and docs/*.md —
  relative targets must exist on disk (external http(s)/mailto links are
  skipped: CI must not depend on the network);
* fragment links ``file.md#anchor`` (and in-page ``#anchor``) — the anchor
  must match a heading in the target file under GitHub's slugification
  (lowercase, punctuation stripped, spaces -> hyphens);
* backticked repo paths like ``src/repro/serving/engine.py`` or
  ``tests/test_paged.py`` — when a backtick span looks like a file path
  with a known source extension, it must exist (documentation naming a
  moved/deleted file is exactly the rot this job exists to catch).

Exits nonzero listing every broken reference.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN_RE = re.compile(r"`([^`\n]+)`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
# backticked spans must look like a committed file to be checked
PATH_EXTS = (".py", ".md", ".json", ".yml", ".yaml", ".txt", ".sh")
# gitignored output trees: docs legitimately name files that only exist
# after a benchmark/dry run, so they can't be required on a fresh clone
GENERATED_PREFIXES = ("benchmarks/results/",)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip formatting, lowercase, keep word chars,
    spaces and hyphens, then spaces -> hyphens."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # unwrap links
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    slugs: dict[str, int] = {}
    out = set()
    for m in HEADING_RE.finditer(path.read_text()):
        s = github_slug(m.group(1))
        n = slugs.get(s, 0)
        slugs[s] = n + 1
        out.add(s if n == 0 else f"{s}-{n}")
    return out


def strip_fences(text: str) -> str:
    """Remove fenced code blocks (``` ... ```): their contents are code,
    not prose links."""
    return re.sub(r"```.*?```", "", text, flags=re.DOTALL)


def check_file(doc: Path) -> list[str]:
    errors = []
    raw = doc.read_text()
    prose = strip_fences(raw)
    rel = doc.relative_to(ROOT)

    for m in LINK_RE.finditer(prose):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, frag = target.partition("#")
        if path_part:
            dest = (doc.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{rel}: broken link -> {target}")
                continue
        else:
            dest = doc
        if frag:
            if dest.suffix != ".md":
                errors.append(f"{rel}: fragment on non-markdown target -> {target}")
            elif frag not in anchors_of(dest):
                errors.append(f"{rel}: missing anchor -> {target}")

    for m in CODE_SPAN_RE.finditer(prose):
        span = m.group(1).strip()
        if " " in span or not span.endswith(PATH_EXTS) or "*" in span or "<" in span:
            continue
        if not re.match(r"^[\w./-]+$", span) or "/" not in span:
            continue  # bare filenames are module talk, not repo paths
        if span.startswith(GENERATED_PREFIXES):
            continue
        # docs shorthand: `serving/engine.py` means `src/repro/serving/...`
        if not (ROOT / span).exists() and not (ROOT / "src" / "repro" / span).exists():
            errors.append(f"{rel}: referenced path does not exist -> `{span}`")
    return errors


def main() -> int:
    errors = []
    for doc in DOC_FILES:
        if doc.exists():
            errors.extend(check_file(doc))
        else:
            errors.append(f"missing doc file: {doc.relative_to(ROOT)}")
    if errors:
        print(f"docs check: {len(errors)} broken reference(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    n_links = sum(len(LINK_RE.findall(strip_fences(d.read_text()))) for d in DOC_FILES)
    print(f"docs check: {len(DOC_FILES)} files, {n_links} links ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
