"""Stdlib SSE client smoke for the always-on HTTP front-end.

CI starts ``launch/serve.py --http`` in the background, then runs this
script against it.  It asserts the service contract end-to-end over a real
socket, with no dependencies beyond the standard library:

* ``GET /healthz`` answers (retried until the server finishes JAX init).
* ``POST /v1/generate`` with ``stream: true`` yields Server-Sent Events —
  at least two separate ``token`` frames (tokens must arrive
  *incrementally*, not as one batch) followed by exactly one ``done``
  frame whose summary is consistent with the streamed tokens.
* A second, non-streaming request returns the same tokens as one JSON
  object (same engine, greedy, so the completion is deterministic).
* Invalid knobs (``max_new_tokens: -1``) get a 400, not a hang.
* ``GET /metrics`` exposes the Prometheus registry with the request we
  just ran accounted for.

Exit code 0 on success; any assertion failure is fatal.

    python scripts/sse_smoke.py --port 8731
"""

from __future__ import annotations

import argparse
import http.client
import json
import sys
import time

PROMPT = [5, 9, 12, 7, 3]
MAX_NEW = 8


def wait_for_server(host: str, port: int, timeout_s: float) -> None:
    deadline = time.monotonic() + timeout_s
    last = None
    while time.monotonic() < deadline:
        try:
            conn = http.client.HTTPConnection(host, port, timeout=5)
            conn.request("GET", "/healthz")
            resp = conn.getresponse()
            body = resp.read()
            conn.close()
            assert resp.status == 200 and json.loads(body)["ok"], body
            return
        except (OSError, http.client.HTTPException) as e:
            last = e
            time.sleep(0.5)
    sys.exit(f"server never came up on {host}:{port}: {last}")


def sse_events(resp) -> list[tuple[str, dict]]:
    """Parse an SSE body into (event, data) pairs as frames complete."""
    events, event, data = [], None, []
    for raw in resp:
        line = raw.decode().rstrip("\n")
        if line.startswith("event: "):
            event = line[len("event: ") :]
        elif line.startswith("data: "):
            data.append(line[len("data: ") :])
        elif not line and event is not None:
            events.append((event, json.loads("".join(data))))
            event, data = None, []
    return events


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8731)
    ap.add_argument("--startup-timeout", type=float, default=300.0)
    args = ap.parse_args()

    wait_for_server(args.host, args.port, args.startup_timeout)
    print(f"[sse-smoke] /healthz ok on {args.host}:{args.port}")

    # streaming generate: incremental token frames, then one done frame
    conn = http.client.HTTPConnection(args.host, args.port, timeout=120)
    conn.request(
        "POST",
        "/v1/generate",
        body=json.dumps({"prompt": PROMPT, "max_new_tokens": MAX_NEW, "priority": 1}),
    )
    resp = conn.getresponse()
    assert resp.status == 200, (resp.status, resp.read())
    assert resp.getheader("Content-Type") == "text/event-stream"
    events = sse_events(resp)
    conn.close()
    kinds = [k for k, _ in events]
    assert kinds.count("done") == 1 and kinds[-1] == "done", kinds
    token_frames = [d for k, d in events if k == "token"]
    assert len(token_frames) >= 2, f"tokens arrived in {len(token_frames)} frame(s), want incremental"
    streamed = [t for d in token_frames for t in d["tokens"]]
    done = events[-1][1]
    assert done["n_tokens"] == len(streamed) == MAX_NEW, (done, streamed)
    assert done["reason"] in ("eos", "length") and done["ttft_s"] > 0, done
    assert [d["index"] for d in token_frames] == sorted(d["index"] for d in token_frames)
    print(f"[sse-smoke] streamed {len(streamed)} tokens over {len(token_frames)} frames")

    # non-streaming arm must agree (greedy => deterministic completion)
    conn = http.client.HTTPConnection(args.host, args.port, timeout=120)
    conn.request(
        "POST",
        "/v1/generate",
        body=json.dumps({"prompt": PROMPT, "max_new_tokens": MAX_NEW, "stream": False}),
    )
    resp = conn.getresponse()
    body = json.loads(resp.read())
    conn.close()
    assert resp.status == 200 and body["tokens"] == streamed, (body, streamed)
    print("[sse-smoke] non-streaming arm token-identical")

    # validation surfaces as 400
    conn = http.client.HTTPConnection(args.host, args.port, timeout=30)
    conn.request("POST", "/v1/generate", body=json.dumps({"prompt": PROMPT, "max_new_tokens": -1}))
    resp = conn.getresponse()
    err = json.loads(resp.read())
    conn.close()
    assert resp.status == 400 and "max_new_tokens" in err["error"], (resp.status, err)

    # the registry saw the traffic
    conn = http.client.HTTPConnection(args.host, args.port, timeout=30)
    conn.request("GET", "/metrics")
    resp = conn.getresponse()
    text = resp.read().decode()
    conn.close()
    assert resp.status == 200, resp.status
    # a router fleet exposes the engine registries prefixed replica<N>_ and
    # fleet totals under router_*; a single engine exposes them bare — the
    # smoke accepts either server shape
    fleet = "router_requests_total" in text
    if fleet:
        assert "router_requests_total 2" in text, "fleet must account for both requests"
        needles = ("replica0_engine_requests_finished_total",
                   "# TYPE replica0_engine_ttft_seconds histogram")
    else:
        needles = ("engine_requests_finished_total 2",
                   "engine_tokens_out_total 16",
                   "# TYPE engine_ttft_seconds histogram")
    for needle in needles:
        assert needle in text, f"missing {needle!r} in /metrics"
    print("[sse-smoke] /metrics accounted for both requests; all checks passed")


if __name__ == "__main__":
    main()
