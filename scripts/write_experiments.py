"""Render EXPERIMENTS.md from the dry-run JSONs + the §Perf iteration log."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.hlo_analysis import PEAK_FLOPS
from repro.launch.roofline import cell_rows, load, markdown_table, pick_hillclimb

ROOT = Path(__file__).resolve().parents[1]

PERF_LOG = """\
## §Perf — hypothesis → change → measure → validate

All numbers are the three roofline terms **per train/serve step** on the
single-pod 16×16 mesh (256 chips), from the final compiled artifacts.
Methodology: enumerate candidates, napkin-math the expected delta, implement
the biggest predicted win, re-lower, re-analyse, record confirmed/refuted.

### Memory-fitting iterations (pre-baseline engineering, all cells)

| # | hypothesis | change | before → after | verdict |
|---|---|---|---|---|
| M1 | inner scans (SSD chunks / q-chunks / WKV chunks / MoE groups) save every per-iteration intermediate for backward | `jax.checkpoint` on all inner-scan bodies | hymba train 22.2→4.7 GB temp/chip; vlm 42→33 GB | **confirmed** (−79% on hymba) |
| M2 | fp32 vocab tables are materialized unsharded around gather/logits | vocab→model sharding, embed dim of tables unsharded (`embed_v`) | vlm 4.2 GB ×4 copies eliminated | **confirmed** |
| M3 | logits inherit seq-sharding from the residual stream → XLA all-gathers the vocab table | explicit vocab-sharded constraint in `lm_logits` | mistral peak 17.9→11.3 GB; qwen 32.8→25.6 GB | **confirmed** |
| M4 | whole-tree bf16 pre-cast hoists an unsharded bf16 weight tree | per-use layer-slice casts (cast activations, not weights) | llama-90b ~33 GB of hoisted tree removed (combined with M2/M3) | **confirmed** |
| M5 | fp32 Adam chains on stacked 100B+ tensors dominate temps → scan the update per layer | `optimizer_layer_scan` | arctic 39.9→**57.2** GB (scan ys double-buffer the whole stacked tree on XLA:CPU) | **REFUTED** (feature kept, off by default) |
| M6 | fp32 microbatch accumulator + Adam temps shrink with bf16 moments | bf16 optimizer states + mb=8 for ≥90B archs | qwen 25.6→21.5 GB; vlm 26.6 GB | **confirmed** |

### Cell 1 — qwen3-moe-235b-a22b × train_4k (most representative: frontier MoE training)

Baseline (paper-faithful FSDP+TP+SP, GShard dispatch): compute 7.33 s,
memory 16.73 s, **collective 143.32 s** (dominant).

| # | hypothesis | change | collective term | verdict |
|---|---|---|---|---|
| 1.1 | seq-sharded K/V vs head-sharded scores forces "involuntary full rematerialization" reshards in every layer loop (XLA SPMD warning) | replicate K/V heads in attention internals (Megatron GQA duplication) | 143.3 → **109.4 s** | **confirmed** (−24%) |
| 1.2 | expert weights over the data axes (stationary experts, all-to-all tokens) beat FSDP-gathered experts | `expert→(data,)` param rule | 109.4 → **208.8 s** | **REFUTED**: dense GShard dispatch reduces a dense (E,C,D) tensor over data |
| 1.3 | shard expert hidden dim over data instead | `expert_mlp→(data,)` in train | 109.4 → **223.0 s** | **REFUTED** for train (accepted for decode, see cell 3) |
| 1.4 | fewer MoE group-scan iterations → fewer repeated gathers | group_size 2048→8192 / 16384 | 109.4 → 183.1 / 220.2 s | **REFUTED**: capacity C ∝ group ⇒ dispatch one-hot cost grows quadratically |
| 1.5 | saving dot outputs avoids re-gathering activations in backward | remat "dots" | 109.4 → **82.4 s** but peak 21→**204.5 GB** | **REFUTED on memory** ("dots_no_batch": 99.7 s @ 34 GB, < 10%, also rejected) |

Accepted: 1.1. Final: compute 7.33 s / memory 16.73 s / collective 109.4 s.
Residual analysis: the remaining term is Megatron-SP activation
all-gather/reduce-scatter + TP psums per layer, inflated ~2× by the CPU
backend upcasting bf16 dots to f32 before partitioning (verified: all dots
are bf16 at the jaxpr level) — TPU-modeled ≈ 55 s, further overlappable with
per-layer compute. Roofline fraction 1.9% → **2.5%** (6·N_active·D reference).

### Cell 2 — arctic-480b × decode_32k (worst roofline fraction)

Baseline: compute 0.20 ms, memory 13.65 ms, **collective 186.58 ms** —
*the serving step spent 93% of its time re-gathering FSDP weight shards*
(diagnosed: 205 MB all-gather of wo per layer per step; 35 layers = 7.2 GB).

| # | hypothesis | change | step bound | verdict |
|---|---|---|---|---|
| 2.1 | serving weights must be stationary: model-axis-only sharding removes per-step weight gathers; head_dim TP fallback (56 heads ∤ 16) keeps attention weights sharded; expert_mlp→data keeps the 937 GB expert bank fully sharded | decode runs: `fsdp=False` + `head_dim→model` + `expert_mlp→(data,)` | 186.6 → **13.65 ms** (now memory-bound) | **confirmed** (13.7× step time) |

Final: compute 0.20 / memory 13.65 / **collective 2.94 ms** — decode is now
HBM-bound on KV-cache + weight reads, the correct regime. Next lever,
implemented as the opt-in serving feature `repro.serving.kvquant` (KIVI-style
int8 KV, per-(token,head) scales): 1.9× KV-traffic reduction with attention
output within bf16-level error (tests/test_kvquant.py).

### Cell 3 — rwkv6-7b × decode_32k (most collective-bound)

Baseline: compute 0.04 ms, memory 0.10 ms, **collective 36.01 ms** —
per-layer TP all-reduces plus FSDP weight gathers on the D×D time-mix stack.

| # | hypothesis | change | collective term | verdict |
|---|---|---|---|---|
| 3.1 | same stationary-weights change as 2.1 (rwkv weights column-sharded on heads_x_dim; WKV per-head local; one psum per mix) | decode `fsdp=False` | 36.0 → **0.85 ms** | **confirmed** (42×) |

Final: compute 0.04 / memory 0.10 / collective 0.85 ms. The residual 0.85 ms
is 2 small psums per layer ((B,1,D) activations) — the canonical TP decode
cost; batching more requests amortizes it (the serving engine's job).

### Cross-cutting accepted changes (visible across the whole table)

* replicate-KV (1.1): mistral-nemo train collective 84.6 → 17.8 s (4.7×).
* stationary serving weights (2.1/3.1): every decode cell dropped 5–60×.

### Scoring note

`roofline frac` = (MODEL_FLOPS / chips / 197 TF) / max(term) — the fraction
of the modeled step spent doing irreducible model math. Training cells land
at 2.5–21%, bounded by SP/TP collectives (CPU-doubled) and remat recompute;
decode cells are intrinsically ≪1% on this metric because decode is
bandwidth-bound — for them the memory term vs. step bound is the score.
"""


def main() -> None:
    single = load("single")
    multi = load("multi")
    rows_s = cell_rows(single)
    rows_m = cell_rows(multi)
    ok_s = [r for r in rows_s if r.get("status") == "ok"]
    ok_m = [r for r in rows_m if r.get("status") == "ok"]
    skips = [r for r in rows_s if r.get("status", "").startswith("skip")]

    # fits summary
    fits = sum(1 for r in ok_s if r["fits"])
    over = [(r["arch"], r["shape"], r["peak_gb"]) for r in ok_s if not r["fits"]]

    # baseline vs optimized comparison for the three cells
    base = load("single") if not (ROOT / "benchmarks/results/baseline_single.json").exists() else json.loads(
        (ROOT / "benchmarks/results/baseline_single.json").read_text()
    )

    def cmp_cell(key):
        b = base.get(key, {})
        o = single.get(key, {})
        if "roofline" not in b or "roofline" not in o:
            return None
        return (
            key,
            max(b["roofline"].values()) * 1e3,
            max(o["roofline"].values()) * 1e3,
        )

    cmps = [cmp_cell(k) for k in (
        "qwen3-moe-235b-a22b|train_4k", "arctic-480b|decode_32k", "rwkv6-7b|decode_32k"
    )]

    doc = []
    doc.append("""# EXPERIMENTS

All artifacts are reproducible on this CPU-only image:

```bash
PYTHONPATH=src python -m repro.launch.dryrun --mesh both --arch all   # §Dry-run
PYTHONPATH=src python -m repro.launch.roofline --mesh single --pick   # §Roofline
PYTHONPATH=src python -m benchmarks.run                               # §Paper-figures
PYTHONPATH=src pytest tests/                                          # §Fault-tolerance et al.
```

Hardware constants (assignment): TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI; meshes 16×16 (single pod, 256 chips) and 2×16×16 (512).

## §Dry-run

Every runnable (arch × shape) cell lowers **and compiles** the real
`train_step` / `prefill` / `decode_step` with full-size ShapeDtypeStruct
inputs and the production sharding trees on forced host devices — both
meshes, zero errors:
""")
    doc.append(f"* single pod (16×16): **{len(ok_s)} cells compiled**, {len(skips)} documented skips\n")
    doc.append(f"* multi pod (2×16×16): **{len(ok_m)} cells compiled**, {len(skips)} documented skips\n")
    doc.append("""
Documented skips (assignment rules, DESIGN.md §Arch-applicability):
`long_500k` for the eight pure full-attention archs (no sub-quadratic
mechanism); `decode_32k`+`long_500k` for hubert-xlarge (encoder-only).
40 cells = 31 compiled + 9 principled skips.

Accounting notes (verified empirically, see tests/test_cost_models.py):
* XLA's `cost_analysis()` counts a `while` body ONCE — per-cell FLOPs/bytes
  therefore come from the jaxpr cost model (scan bodies × trip counts,
  remat recompute included); raw XLA numbers are stored as lower bounds.
* Collective bytes are parsed from the SPMD-partitioned HLO with the
  computation call graph, multiplying collectives inside while bodies by
  parsed trip counts.
* `memory_analysis()` is per-device. XLA:CPU double-buffers donated buffers
  through `while` loops and upcasts bf16 dots to f32 before partitioning —
  both inflate temp/collective numbers vs. a real TPU lowering (≤2×).
""")
    doc.append(f"\nPer-chip fit vs the 16 GB v5e HBM budget: **{fits}/{len(ok_s)}** cells fit on the single pod.\n")
    if over:
        doc.append("Over-budget cells (all fit the 512-chip multi-pod mesh or carry a documented lever):\n")
        for a, s_, gb in over:
            doc.append(f"* {a} × {s_}: {gb:.1f} GB/chip\n")

    doc.append("\n## §Roofline — single-pod baseline table (all 40 assigned cells)\n\n")
    doc.append(markdown_table(rows_s))
    doc.append("""
Columns: the assignment's three terms in ms/step; `6ND/HLO` = MODEL_FLOPS /
jaxpr-counted FLOPs (remat/attention/dispatch overhead detector — rwkv6 ≈ 1.0
means nearly all compiled compute is model math; qwen ≈ 0.38 exposes the
GShard dispatch einsums + remat recompute); `roofline frac` = model-math time
÷ dominant term (the §Perf score).

Hillclimb cell selection (per assignment; computed on the BASELINE table --
benchmarks/results/baseline_single.json -- the optimized table above
already reflects the hillclimb):
""")
    sel = pick_hillclimb(cell_rows(base))
    for why, r in sel.items():
        doc.append(f"* **{why}**: {r['arch']} × {r['shape']} (frac {r['roofline_frac']:.1%}, dominant {r['dominant']})\n")

    doc.append("\n### Multi-pod (2×16×16) highlights\n\n")
    doc.append("| arch | shape | compute ms | memory ms | coll. ms | dominant | peak GB/chip |\n|---|---|---|---|---|---|---|\n")
    for r in ok_m:
        if r["shape"] == "train_4k":
            doc.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
                f"| {r['collective_s']*1e3:.1f} | {r['dominant'].replace('_s','')} | {r['peak_gb']:.1f} |\n"
            )
    doc.append("""
The pod axis adds cross-DCN gradient sync (modeled in
`parallel/collectives.py`; `grad_compression="int8"` cuts its wire bytes 4×
with error feedback — convergence property-tested in tests/test_optim_data.py).

""")
    doc.append(PERF_LOG)

    if all(cmps):
        doc.append("\n### Before/after (step bound = max roofline term, single pod)\n\n")
        doc.append("| cell | baseline | optimized | speedup |\n|---|---|---|---|\n")
        for key, b, o in cmps:
            doc.append(f"| {key} | {b:.1f} ms | {o:.1f} ms | {b/o:.1f}× |\n")

    doc.append("""
## §Paper-figures (benchmarks/run.py)

| paper figure | harness | headline result |
|---|---|---|
| Fig. 8 MLPerf BERT-Large | `benchmarks/mlperf_train.py` | reduced-config CPU training loss decreases; full-config compute roofline derived per chip |
| Fig. 9 llama.cpp 70B | `benchmarks/llm_inference.py` | continuous-batching engine throughput (CPU) + mistral-nemo decode_32k pod roofline ≈ 2,300 tok/s/pod equivalent |
| Fig. 10 BabelStream | `benchmarks/babelstream.py` | Pallas copy/mul/add/triad/dot validated vs oracles; modeled v5e times at 819 GB/s |
| Fig. 11 CloverLeaf | `benchmarks/cloverleaf.py` | shard_map stencil with ppermute halos; halo/compute ratio ⇒ weak-scaling efficiency ≈ 0.999 |

## §Fault-tolerance & platform (tests, all green)

* **bit-exact flex-restart**: a node failure at step 7 of 12 rolls back to the
  step-5 checkpoint and replays to a state identical to the failure-free run
  (tests/test_fault_tolerance.py) — the paper's "guaranteed completion".
* **QoS scheduler**: inference preempts flex-trained batch jobs, which requeue
  and complete; calendar reservations auto-start/stop; property-tested
  invariants: no double-booking, rollback ≤ one checkpoint interval.
* **Tenancy/RBAC**: quota enforcement, node exclusivity, token expiry.
* **Checkpoint tiers**: a 480 B-param (bf16 ×3) checkpoint writes in < 2 s at
  the paper's 1,980 GB/s ClusterStor envelope; Young/Daly cadence for 1,320
  nodes ⇒ ~38 h job MTBF, < 5% checkpoint overhead.
* **Sustainability**: effective PUE 1.083 (< 1.1 paper target); phase-2 power
  model ≈ 1.9 MW at full load (5 MW envelope); per-job kWh + scope-2 kgCO₂.
""")

    (ROOT / "EXPERIMENTS.md").write_text("".join(doc))
    print("wrote EXPERIMENTS.md", len("".join(doc)), "chars")


if __name__ == "__main__":
    main()
