"""Benchmark harness: one module per paper figure, CSV output.

    Fig. 8  -> mlperf_train     (BERT-Large training)
    Fig. 9  -> llm_inference    (paged vs dense continuous-batching decode)
    Fig. 10 -> babelstream      (memory bandwidth, Pallas kernels)
    Fig. 11 -> cloverleaf       (stencil weak scaling, shard_map halos)
    §1      -> fp8_gemm         (bf16 vs FP8-path GEMM, 8-bit peak headline)
    §IV.F   -> paged_attention  (block-table decode kernel vs gather oracle)

Each prints ``name,us_per_call,derived`` rows.  On this CPU image the
wall-clock columns are CPU-measured (reduced configs / interpret mode); the
``derived`` columns carry the v5e-modeled numbers used in EXPERIMENTS.md.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        babelstream,
        cloverleaf,
        fp8_gemm,
        llm_inference,
        mlperf_train,
        paged_attention,
    )

    print("name,us_per_call,derived")
    failures = 0
    for mod in (mlperf_train, llm_inference, babelstream, cloverleaf, fp8_gemm, paged_attention):
        try:
            for r in mod.run():
                derived = r.get("derived") or f"modeled_v5e_us={r.get('modeled_tpu_us', r.get('modeled_v5e_us', 0)):.1f}"
                print(f"{r['name']},{r['us_per_call']:.1f},{derived}")
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
