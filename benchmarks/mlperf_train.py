"""MLPerf BERT-Large training (paper Fig. 8).

The paper reports single-node MLPerf BERT-Large time-to-train.  This harness
trains the bert-large config (reduced on CPU) and derives the full-config
per-step roofline time from the jaxpr cost model — the number a v5e pod is
expected to hit, reported next to measured CPU step time for the reduced run.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.config import ParallelConfig, RunConfig, TrainConfig
from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.data import make_batch_fn
from repro.launch.hlo_analysis import PEAK_FLOPS
from repro.launch.jaxpr_cost import estimate_cost
from repro.train.step import abstract_train_state, init_train_state, make_train_step
from repro.launch.specs import train_input_specs
from repro.config import ShapeConfig


def run(steps: int = 8) -> list[dict]:
    # measured: reduced config on CPU
    cfg = reduce_for_smoke(get_config("bert-large"))
    run_cfg = RunConfig(arch="bert-large", train=TrainConfig(global_batch=8, seq_len=128))
    state = init_train_state(cfg, run_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, run_cfg))
    batch_fn = make_batch_fn(cfg, global_batch=8, seq_len=128)
    state, m = step(state, batch_fn(0))  # compile
    t0 = time.perf_counter()
    losses = []
    for s in range(1, steps + 1):
        state, m = step(state, batch_fn(s))
        losses.append(float(m["loss"]))
    dt = (time.perf_counter() - t0) / steps

    # derived: full BERT-Large per-step time at MLPerf batch (448 seqs x 512)
    full = get_config("bert-large").replace(max_position=512)
    full_run = RunConfig(arch="bert-large", train=TrainConfig(global_batch=448, seq_len=512))
    astate = abstract_train_state(full, full_run)
    fstep = make_train_step(full, full_run)
    batch = train_input_specs(full, ShapeConfig("mlperf", 512, 448, "train"))
    est = estimate_cost(fstep, astate, batch)
    v5e_step_s = est["flops"] / PEAK_FLOPS  # single chip, compute roofline
    return [
        {
            "name": "mlperf_bert_reduced_cpu",
            "us_per_call": dt * 1e6,
            "derived": f"loss {losses[0]:.3f}->{losses[-1]:.3f}",
        },
        {
            "name": "mlperf_bert_full_roofline",
            "us_per_call": v5e_step_s * 1e6,
            "derived": f"global_flops={est['flops']:.3g} per-step @1 v5e chip",
        },
    ]


def main() -> None:
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
