"""Paged-attention decode microbenchmark (Pallas kernel vs jnp gather oracle).

Sweeps decode-batch / context-length points, checks the Pallas kernel
against the oracle at every point, and times both paths plus the dense
(contiguous-cache) attention equivalent.  Wall-clock columns are
CPU/interpret measured; the ``derived`` column carries the modeled HBM
traffic per decode step (the quantity the paged layout exists to bound —
decode attention is memory-bound, so bytes-touched is the roofline term).
Results land in ``benchmarks/results/paged_attention.json`` so the perf
trajectory picks the sweep up.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.kernels import paged_attention
from repro.kernels.paged_attention_ref import paged_attention_ref

RESULTS_DIR = Path(__file__).resolve().parent / "results"

# (B, nb, block_size, H, KV, hd)
CASES = [
    (4, 4, 16, 8, 2, 64),
    (8, 8, 16, 8, 2, 64),
    (4, 4, 32, 16, 4, 128),
]
HBM_GBPS = 819e9  # v5e per-chip HBM bandwidth


def _time(fn, *args, iters: int = 3) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run() -> list[dict]:
    rows = []
    for B, nb, bs, H, KV, hd in CASES:
        N = 1 + B * nb
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
        kp = jax.random.normal(ks[1], (N, bs, KV, hd), jnp.float32)
        vp = jax.random.normal(ks[2], (N, bs, KV, hd), jnp.float32)
        tbl = jnp.arange(1, 1 + B * nb, dtype=jnp.int32).reshape(B, nb)
        lens = jnp.full((B,), nb * bs, jnp.int32)

        ref = paged_attention_ref(q, kp, vp, tbl, lens)
        out = paged_attention(q, kp, vp, tbl, lens)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 2e-5, f"paged attention diverged from oracle: {err}"

        ref_us = _time(jax.jit(paged_attention_ref), q, kp, vp, tbl, lens) * 1e6
        pal_us = _time(paged_attention, q, kp, vp, tbl, lens, iters=2) * 1e6
        # decode reads each sequence's K+V once per step (2 bytes bf16 on HW)
        hbm_bytes = 2 * B * nb * bs * KV * hd * 2
        modeled_us = hbm_bytes / HBM_GBPS * 1e6
        name = f"paged_attn_b{B}_ctx{nb * bs}_kv{KV}x{hd}"
        rows.append(
            {
                "name": f"{name}_oracle",
                "us_per_call": ref_us,
                "derived": f"modeled_v5e_hbm_us={modeled_us:.3f} maxerr_vs_pallas={err:.1e}",
            }
        )
        rows.append(
            {
                "name": f"{name}_pallas_interp",
                "us_per_call": pal_us,
                "derived": f"modeled_v5e_hbm_us={modeled_us:.3f} kv_bytes={hbm_bytes}",
            }
        )

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / "paged_attention.json").write_text(json.dumps(rows, indent=1))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description="paged-attention microbenchmark")
    ap.add_argument(
        "--autotune", action="store_true",
        help="sweep kernel layout knobs over the benchmark CASES and write "
        "winners to the user autotune cache (see repro.kernels.autotune)",
    )
    ap.add_argument("--iters", type=int, default=5, help="timing reps per candidate")
    ap.add_argument("--dtype", default="bfloat16", help="pool dtype for the sweep")
    ap.add_argument("--out", default=None, help="autotune cache path override")
    args = ap.parse_args()
    if args.autotune:
        from repro.kernels.autotune import autotune

        autotune(CASES, dtype=args.dtype, iters=args.iters, out_path=args.out)
        return
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
