"""FP8 GEMM sweep: bf16 vs the FP8 path (paper §1's 21 ExaFLOP/s headline).

Isambard-AI quotes its AI capability in 8-bit FLOP/s — exactly double the
bf16 peak — so the benchmark that matters is the GEMM precision crossover.
For a square-ish sweep this measures, per size:

* ``bf16``     — plain jnp matmul in bf16 (the pre-FP8 compute path)
* ``fp8_ref``  — quantize (e4m3, per-tensor scales) + dequantizing GEMM via
  the jnp reference (what XLA lowers to the native FP8 MXU path on hardware)
* ``fp8_pallas`` — the tiled Pallas kernel (interpret mode on CPU), allclose-
  checked against the reference

Wall-clock columns are CPU-measured; the ``derived`` column carries the
v5e-modeled roofline times (2*M*N*K FLOPs against the bf16 vs fp8 peak) used
in EXPERIMENTS.md.  Results are also written to
``benchmarks/results/fp8_gemm.json`` alongside the dry-run suites.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.fp8 import E4M3, compute_scale, fp8_gemm, fp8_gemm_ref, quantize, tensor_amax
from repro.launch.hlo_analysis import PEAK_FLOPS, PEAK_FLOPS_FP8

RESULTS_DIR = Path(__file__).resolve().parent / "results"

SIZES = (256, 512)
PALLAS_CHECK_SIZE = 256  # interpret mode: keep the kernel run small


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run() -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    for n in SIZES:
        a = jax.random.normal(key, (n, n), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
        sa, sb = compute_scale(tensor_amax(a), E4M3), compute_scale(tensor_amax(b), E4M3)
        qa, qb = quantize(a, sa, E4M3), quantize(b, sb, E4M3)
        flops = 2.0 * n * n * n
        bf16_us = _time(jax.jit(lambda x, y: (x @ y)), a.astype(jnp.bfloat16), b.astype(jnp.bfloat16)) * 1e6
        ref_us = _time(jax.jit(fp8_gemm_ref), qa, qb, sa, sb) * 1e6

        v5e_bf16_us = flops / PEAK_FLOPS * 1e6
        v5e_fp8_us = flops / PEAK_FLOPS_FP8 * 1e6
        rows.append(
            {
                "name": f"fp8_gemm_bf16_{n}",
                "us_per_call": bf16_us,
                "derived": f"modeled_v5e_us={v5e_bf16_us:.3f}",
            }
        )
        rows.append(
            {
                "name": f"fp8_gemm_fp8ref_{n}",
                "us_per_call": ref_us,
                "derived": f"modeled_v5e_us={v5e_fp8_us:.3f} speedup_vs_bf16=2.0",
            }
        )

    # Pallas kernel: correctness vs oracle + one timed point (interpret mode)
    n = PALLAS_CHECK_SIZE
    a = jax.random.normal(key, (n, n), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
    sa, sb = compute_scale(tensor_amax(a), E4M3), compute_scale(tensor_amax(b), E4M3)
    qa, qb = quantize(a, sa, E4M3), quantize(b, sb, E4M3)
    ref = fp8_gemm_ref(qa, qb, sa, sb)
    pal = fp8_gemm(qa, qb, sa, sb)
    err = float(jnp.max(jnp.abs(pal - ref)))
    assert err < 1e-4, f"pallas fp8_gemm diverged from oracle: {err}"
    pal_us = _time(lambda x, y: fp8_gemm(x, y, sa, sb), qa, qb, iters=2) * 1e6
    rows.append(
        {
            "name": f"fp8_gemm_pallas_interp_{n}",
            "us_per_call": pal_us,
            "derived": f"allclose_vs_ref_maxerr={err:.2e}",
        }
    )

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / "fp8_gemm.json").write_text(json.dumps(rows, indent=1))
    return rows


def main() -> None:
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
