"""CloverLeaf weak scaling (paper Fig. 11).

The paper weak-scales the CloverLeaf hydrodynamics mini-app (structured-grid
stencil, memory-bandwidth-bound, MPI halo exchange) to 160 GH200s.  TPU
adaptation: the same 5-point stencil over a 2-D grid, sharded with
``shard_map``; halo exchange via ``jax.lax.ppermute`` along the mesh axis —
the JAX-native equivalent of the MPI halos.  On CPU this runs on 1 device
(the weak-scaling table derives per-size byte counts); on a pod the same code
scales across chips.
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.hlo_analysis import HBM_BW


def _stencil(u):
    """5-point Jacobi update (CloverLeaf's diffusion-like kernel shape)."""
    c = u[1:-1, 1:-1]
    n = u[:-2, 1:-1]
    s = u[2:, 1:-1]
    w = u[1:-1, :-2]
    e = u[1:-1, 2:]
    return 0.2 * (c + n + s + w + e)


def make_step(mesh: Mesh):
    """shard_map step: halo exchange (ppermute) + local stencil."""

    def step(u):  # u: local (H_local, W) block, sharded over axis "x"
        up = jax.lax.ppermute(u[-1:], "x", [(i, (i + 1) % mesh.shape["x"]) for i in range(mesh.shape["x"])])
        down = jax.lax.ppermute(u[:1], "x", [(i, (i - 1) % mesh.shape["x"]) for i in range(mesh.shape["x"])])
        padded = jnp.concatenate([up, u, down], axis=0)
        padded = jnp.pad(padded, ((0, 0), (1, 1)), mode="edge")
        new = _stencil(padded)
        return new

    return shard_map(step, mesh=mesh, in_specs=P("x", None), out_specs=P("x", None))


def run(sizes=(256, 512, 1024), iters: int = 5) -> list[dict]:
    devs = jax.devices()
    mesh = Mesh(np.array(devs).reshape(len(devs)), ("x",))
    rows = []
    for n in sizes:
        u = jnp.ones((n, n), jnp.float32)
        step = jax.jit(make_step(mesh))
        u2 = step(u)  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            u2 = step(u2)
        jax.block_until_ready(u2)
        dt = (time.perf_counter() - t0) / iters
        nbytes = 2 * n * n * 4  # read + write per cell
        rows.append(
            {
                "name": f"cloverleaf_{n}x{n}",
                "us_per_call": dt * 1e6,
                "bytes": nbytes,
                "modeled_v5e_us": nbytes / HBM_BW * 1e6,
                "halo_bytes_per_step": 2 * n * 4 * len(devs),
            }
        )
    # weak-scaling derivation: per-chip grid constant, halo/compute ratio
    for chips in (16, 64, 160, 256):
        n_local = 1024
        compute_bytes = 2 * n_local * n_local * 4
        halo_bytes = 2 * n_local * 4
        rows.append(
            {
                "name": f"cloverleaf_weakscale_{chips}chips",
                "us_per_call": compute_bytes / HBM_BW * 1e6,
                "derived": f"halo/compute bytes = {halo_bytes/compute_bytes:.2e} (weak-scaling efficiency ~ {1/(1+halo_bytes/compute_bytes):.4f})",
            }
        )
    return rows


def main() -> None:
    for r in run():
        d = r.get("derived", f"modeled_v5e_us={r.get('modeled_v5e_us', 0):.1f}")
        print(f"{r['name']},{r['us_per_call']:.1f},{d}")


if __name__ == "__main__":
    main()
