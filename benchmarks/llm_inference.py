"""llama.cpp-style LLM inference (paper Fig. 9).

The paper reports 70B llama.cpp decode throughput on the Grace CPU.  This
harness serves a reduced model through the continuous-batching engine
(measured tokens/s on CPU) and derives the full mistral-nemo-12b decode-step
roofline time on a v5e pod from the dry-run artifacts (HBM-bound KV reads).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.config.model import reduce_for_smoke
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InferenceEngine

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun_single.json"


def run() -> list[dict]:
    cfg = reduce_for_smoke(get_config("mistral-nemo-12b"))
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = InferenceEngine(cfg, params, max_batch=4, max_seq=128)
    for i in range(8):
        eng.submit([1 + i, 2, 3, 4], max_new_tokens=16, online=i % 2 == 0)
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    stats = eng.stats()
    rows = [
        {
            "name": "llm_inference_engine_cpu",
            "us_per_call": dt / max(stats["decode_steps"], 1) * 1e6,
            "derived": f"tokens_out={stats['tokens_out']} tok/s={stats['tokens_out']/dt:.1f}",
        }
    ]
    # derived decode-step time for the full 12B model from the dry-run
    if RESULTS.exists():
        rec = json.loads(RESULTS.read_text()).get("mistral-nemo-12b|decode_32k")
        if rec and rec.get("status") == "run":
            bound = max(rec["roofline"].values())
            rows.append(
                {
                    "name": "llm_inference_12b_decode32k_roofline",
                    "us_per_call": bound * 1e6,
                    "derived": f"batch128 -> {128/bound:.0f} tok/s/pod, dominant={rec['dominant']}",
                }
            )
    return rows


def main() -> None:
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
